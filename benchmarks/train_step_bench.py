"""Measured step benchmark (CPU, smoke scale): SR/DS variants end to end.

Wall-clock on CPU is NOT the perf deliverable (the roofline is), but this
harness proves the variant ladder runs and produces the QoS telemetry the
controller consumes; on a TPU deployment the same harness measures real
MFU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.optim import adamw


def bench(arch: str = "qwen3-1.7b", steps: int = 8,
          variants=((0, 1), (1, 1), (2, 1))) -> Dict:
    cfg = registry.smoke(arch)
    shape = dataclasses.replace(SHAPES["train_4k"], global_batch=4,
                                seq_len=128)
    mesh = make_host_mesh()
    out = {}
    with jax.set_mesh(mesh):
        params = M.init_model(jax.random.PRNGKey(0), cfg)
        opt_cfg = adamw.AdamWConfig()
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                      global_batch=4, seq_len=128))
        for depth, gran in variants:
            rc = RunConfig(model=cfg, shape=shape, mesh=MeshConfig(),
                           sr_prefetch_depth=depth, sr_granularity=gran)
            step = jax.jit(steps_lib.build_train_step(cfg, rc, opt_cfg))
            state = steps_lib.TrainState(params,
                                         adamw.init(params, opt_cfg), None)
            batch = {k: jax.numpy.asarray(v)
                     for k, v in data.batch(0).items()}
            state, m = step(state, batch)          # compile + warm
            float(m["loss"])
            t0 = time.time()
            for i in range(steps):
                state, m = step(state, batch)
            float(m["loss"])
            dt = (time.time() - t0) / steps
            out[(depth, gran)] = dt
            print(f"[train_bench] {arch} SR(depth={depth},gran={gran}): "
                  f"{dt*1e3:.1f} ms/step")
    return out


if __name__ == "__main__":
    bench()
