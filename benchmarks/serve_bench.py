"""Serving hot-path benchmark: legacy host path vs device-resident engine.

Measures, for the same CPU config and request mix:

 * prefill tokens/sec  — prompt ingestion (per-token decode_step dispatches
   on the legacy path vs chunked in-graph cache writes on the new path)
 * decode tokens/sec   — steady-state continuous-batching throughput
   (per-tick logits transfer + host sampling vs fused on-device sampling)
 * p50/p99 tick latency over decode-only engine ticks
 * prefix reuse        — a resubmitted rid must be served via page restore
   with zero prefill dispatches (new path)

``--cxl-tier`` additionally sweeps the CXL-timed memory tier: media bins
(dram / ssd-fast / ssd-slow x SR on/off), the multi-root-port
**topology axis** (1-port baseline vs 2-/3-port heterogeneous topologies
x placement policy), and the **scheduler axis** (blocking vs
completion-based async restores; FIFO vs preempt+swap under slot
pressure). The same serving traffic is charged against the simulated
endpoints; per-restore stall / SR hit rate / per-port stats land in a
``cxl_tier`` section with acceptance gates that SR-on beats SR-off per
bin, that multi-port overlap strictly reduces aggregate restore stall vs
the 1-port baseline, that async restore strictly reduces aggregate stall
vs blocking on identical traffic, that preempt+swap completes strictly
more requests per simulated second than FIFO under pressure, and that
every (port-tagged, async) op trace replays within 1% of the scalar
oracle.

``--load`` adds the open-loop load axis (closed vs continuous vs
preempt+swap admission on one seeded bursty trace, gated on goodput)
and, nested under it, the **fault axis**: a mixed-family fleet (MoE /
hybrid / xLSTM) runs one identical arrival trace healthy and under one
identical endpoint-fault trace (transient + degrade + hot-remove),
gated on zero lost requests, faulted goodput within a bounded factor of
healthy, bounded retries, and fault-annotated replay within 1%.

Emits BENCH_serve.json with both sides + speedups so the perf trajectory
has a serving datapoint. Run:

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke --cxl-tier \
      --load --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

# the --shard axis compares 1-rank vs 2-/4-rank sharded serving, which
# needs >= 4 host devices; XLA only reads the flag at first jax init, so
# (like repro.launch.dryrun) it must be set before any jax import — main
# runs far too late
if "--shard" in sys.argv and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_"
                                 "count=4").strip()

import numpy as np


def _load_by_path(modname: str, relpath: str):
    """Load one repo module standalone, by file path.

    ``repro.serving.stats`` / ``repro.serving.loadgen`` keep their
    module-level imports stdlib+numpy-only precisely so this works in
    the jax-free docs CI job: loading them by path skips the ``repro``
    package ``__init__`` (which pulls jax), letting SCHEMA_KEYS below
    derive from the dataclass field lists — the single source of truth.
    """
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        modname, os.path.join(root, relpath))
    mod = importlib.util.module_from_spec(spec)
    sys.modules[modname] = mod      # dataclasses resolves cls.__module__
    spec.loader.exec_module(mod)
    return mod


_STATS = _load_by_path("_serving_stats", "src/repro/serving/stats.py")
_LOADGEN = _load_by_path("_serving_loadgen", "src/repro/serving/loadgen.py")

# Canonical BENCH_serve.json schema, section by section. This is the
# single source of truth three consumers pin against:
#  * main() fails if the emitted JSON drifts from it (check_schema),
#  * tools/check_docs.py fails if the schema table in
#    docs/ARCHITECTURE.md drifts from it (the CI docs job),
#  * downstream artifact readers can import it.
# The engine_stats / load_config / load_scenario sections are *derived*
# from the owning dataclasses' field lists (repro.serving.stats /
# repro.serving.loadgen), so the engine's telemetry, the bench artifact
# and the docs tables cannot drift independently.
SCHEMA_KEYS = {
    "top": ("bench", "arch", "config", "legacy_host_path",
            "device_resident", "speedup", "acceptance", "cxl_tier",
            "load", "shard", "placement", "replay_gates"),
    "engine": ("prefill_tok_s", "decode_tok_s", "prefill_tok_s_best",
               "decode_tok_s_best", "prefill_tokens_per_run",
               "decode_tokens_per_run", "prefill_dispatches_per_run",
               "decode_dispatches_per_run", "p50_tick_ms", "p99_tick_ms",
               "runs", "store_bytes", "store_evictions"),
    "device_extra": ("resubmit_prefill_dispatches", "prefix_hits",
                     "prefix_hit_rate"),
    "cxl_tier": ("config", "media_bins", "topology", "scheduler",
                 "kv_quant", "acceptance"),
    "tier_scenario": ("restores", "restore_stall_ns_total",
                      "restore_stall_ns_per_restore", "sr_hit_rate",
                      "sr_prefetch_pages", "flush_write_ns_total",
                      "store_queue_occupancy", "flushes_deferred",
                      "gc_events", "trace_ops"),
    "topology_extra": ("ports", "promotions", "demotions",
                       "replay_within_1pct"),
    "scheduler": ("restore", "pressure"),
    "sched_scenario": ("completed", "sim_time_ns", "req_per_sim_s",
                       "restore_stall_ns_total", "restore_inflight_ns",
                       "overlap_ratio", "preemptions", "swap_out_bytes",
                       "swap_in_bytes", "inflight_peak", "prefix_hits",
                       "replay_within_1pct"),
    "kv_quant": ("config", "modes", "tokens", "acceptance"),
    "kvq_scenario": ("restores", "restore_stall_ns_total",
                     "restore_stall_ns_per_restore", "flush_write_ns_total",
                     "read_bytes", "write_bytes", "prefetch_bytes",
                     "store_bytes", "replay_within_1pct"),
    "engine_stats": _STATS.EngineStats.field_names(),
    "load": ("config", "batching", "scheduling", "fault", "acceptance"),
    "load_config": _LOADGEN.LoadConfig.field_names()
    + ("n_slots", "max_seq", "max_ticks"),
    "load_scenario": _STATS.LoadMetrics.field_names()
    + ("engine", "replay_within_1pct"),
    "fault": ("config", "fleet", "acceptance"),
    "fault_config_extra": ("fleet", "topology", "trace"),
    "shard": ("config", "ranks", "acceptance"),
    "shard_scenario": ("mesh_ranks", "completed", "lost_requests",
                       "prefix_hits", "restore_stall_ns_total",
                       "stall_ratio_vs_1rank", "tier_writes",
                       "peer_fetches", "peer_bytes", "peer_fetch_ns",
                       "mirror_writes", "rank_remaps",
                       "token_identity_vs_1rank", "replay_within_1pct"),
    "placement": ("config", "churn", "shared", "acceptance"),
    "placement_churn_scenario": ("restores", "restore_stall_ns_total",
                                 "promotions", "demotions",
                                 "replay_within_1pct"),
    "placement_shared_scenario": ("restores", "restore_stall_ns_total",
                                  "peer_bytes", "rehomes",
                                  "multi_source_reads",
                                  "replay_within_1pct"),
    "replay_gate": ("where", "engine", "ok", "wall_ratio"),
}


def check_schema(out) -> list:
    """Compare an emitted BENCH_serve.json dict against SCHEMA_KEYS.

    Returns a list of drift messages (empty when the artifact matches);
    every key set is compared exactly, both directions, so adding or
    removing an emitted key without updating SCHEMA_KEYS (and the docs
    table checked against it) fails the bench.
    """
    errs = []

    def diff(where, got, want):
        got, want = set(got), set(want)
        if got != want:
            errs.append(f"{where}: +{sorted(got - want)} "
                        f"-{sorted(want - got)}")

    top = set(SCHEMA_KEYS["top"])
    for optional in ("cxl_tier", "load", "shard", "placement",
                     "replay_gates"):
        if optional not in out:
            top.discard(optional)
    diff("top-level", out, top)
    if "legacy_host_path" in out:
        diff("legacy_host_path", out["legacy_host_path"],
             SCHEMA_KEYS["engine"])
    if "device_resident" in out:
        diff("device_resident", out["device_resident"],
             SCHEMA_KEYS["engine"] + SCHEMA_KEYS["device_extra"])
    tier = out.get("cxl_tier")
    if tier is not None:
        diff("cxl_tier", tier, SCHEMA_KEYS["cxl_tier"])
        for b, per in tier.get("media_bins", {}).items():
            for mode, scen in per.items():
                diff(f"media_bins[{b}][{mode}]", scen,
                     SCHEMA_KEYS["tier_scenario"])
        for t, per in tier.get("topology", {}).items():
            for mode, scen in per.items():
                diff(f"topology[{t}][{mode}]", scen,
                     SCHEMA_KEYS["tier_scenario"]
                     + SCHEMA_KEYS["topology_extra"])
        sched = tier.get("scheduler", {})
        diff("cxl_tier.scheduler", sched, SCHEMA_KEYS["scheduler"])
        for axis in ("restore", "pressure"):
            for mode, scen in sched.get(axis, {}).items():
                diff(f"scheduler[{axis}][{mode}]", scen,
                     SCHEMA_KEYS["sched_scenario"])
        kvq = tier.get("kv_quant")
        if kvq is not None:
            diff("cxl_tier.kv_quant", kvq, SCHEMA_KEYS["kv_quant"])
            for mode, scen in kvq.get("modes", {}).items():
                diff(f"kv_quant.modes[{mode}]", scen,
                     SCHEMA_KEYS["kvq_scenario"])
    load = out.get("load")
    if load is not None:
        load_keys = set(SCHEMA_KEYS["load"])
        if "fault" not in load:
            load_keys.discard("fault")
        diff("load", load, load_keys)
        diff("load.config", load.get("config", {}),
             SCHEMA_KEYS["load_config"])
        for axis in ("batching", "scheduling"):
            for mode, scen in load.get(axis, {}).items():
                diff(f"load[{axis}][{mode}]", scen,
                     SCHEMA_KEYS["load_scenario"])
                diff(f"load[{axis}][{mode}].engine", scen.get("engine", {}),
                     SCHEMA_KEYS["engine_stats"])
        fault = load.get("fault")
        if fault is not None:
            diff("load.fault", fault, SCHEMA_KEYS["fault"])
            diff("load.fault.config", fault.get("config", {}),
                 SCHEMA_KEYS["load_config"]
                 + SCHEMA_KEYS["fault_config_extra"])
            for arch, per in fault.get("fleet", {}).items():
                for mode, scen in per.items():
                    diff(f"load.fault[{arch}][{mode}]", scen,
                         SCHEMA_KEYS["load_scenario"])
                    diff(f"load.fault[{arch}][{mode}].engine",
                         scen.get("engine", {}),
                         SCHEMA_KEYS["engine_stats"])
    shard = out.get("shard")
    if shard is not None:
        diff("shard", shard, SCHEMA_KEYS["shard"])
        for mode, scen in shard.get("ranks", {}).items():
            diff(f"shard.ranks[{mode}]", scen,
                 SCHEMA_KEYS["shard_scenario"])
    placement = out.get("placement")
    if placement is not None:
        diff("placement", placement, SCHEMA_KEYS["placement"])
        for mode, scen in placement.get("churn", {}).items():
            diff(f"placement.churn[{mode}]", scen,
                 SCHEMA_KEYS["placement_churn_scenario"])
        for mode, scen in placement.get("shared", {}).items():
            diff(f"placement.shared[{mode}]", scen,
                 SCHEMA_KEYS["placement_shared_scenario"])
    for i, gate in enumerate(out.get("replay_gates", ())):
        diff(f"replay_gates[{i}]", gate, SCHEMA_KEYS["replay_gate"])
    return errs


def _build(arch: str, seed: int, vocab: int, dtype: str):
    import dataclasses

    import jax
    from repro.configs import registry
    from repro.configs.base import MeshConfig, RunConfig, SHAPES
    from repro.models import model as M

    cfg = registry.smoke(arch)
    if vocab:
        # the 256-token smoke vocab hides the per-tick [slots, V] logits
        # round-trip the rewrite removes; serve with a serving-scale vocab
        cfg = dataclasses.replace(cfg, vocab_size=vocab)
    if dtype:
        # on CPU bf16 matmuls are software-emulated, which inflates the
        # compute both engines share and buries the hot-path overheads this
        # bench isolates; default to the backend-native f32
        cfg = dataclasses.replace(cfg, dtype=dtype)
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(seed), cfg)
    return cfg, rc, params


def _drive(eng, requests, *, max_ticks: int = 10_000):
    """Run the engine to drain, recording per-tick wall times and whether
    the tick performed any prefill work (admission)."""
    for req in requests:
        eng.submit(req)
    ticks = []
    while (eng.queue or any(s is not None for s in eng.slots)
           or eng.scheduler.busy()) and len(ticks) < max_ticks:
        pf0 = eng.stats["prefill_dispatches"] + eng.stats["prefix_hits"]
        t0 = time.perf_counter()
        eng.step()
        dt = time.perf_counter() - t0
        admitted = (eng.stats["prefill_dispatches"]
                    + eng.stats["prefix_hits"]) != pf0
        ticks.append((dt, admitted))
    eng.flusher.maybe_flush()
    return ticks


def _reset_stats(eng):
    for k, v in eng.stats.items():
        eng.stats[k] = [] if isinstance(v, list) else \
            0.0 if isinstance(v, float) else 0


def _timed_pass(eng, reqs, n_requests, max_new):
    """One timed pass; returns (metrics, steady decode tick times).

    Phases, with explicit sync at each boundary so async dispatch is
    billed where the work belongs (identical accounting for both
    engines):

      admit    — submit all requests, one step() admits + prefills every
                 slot and runs the first decode tick
      steady   — full-occupancy decode ticks, strictly before the first
                 retirement: the "decode tokens/sec" window
      probe    — a few ticks with an explicit sync after each, so p50/p99
                 tick latency means tick *completion* for both engines
                 (the device-resident path otherwise only enqueues work)
      drain    — the remaining ticks + retirements + flushes (untimed)
    """
    import jax

    assert len(reqs) == eng.n_slots, "steady window needs full occupancy"
    _reset_stats(eng)
    for req in reqs:
        eng.submit(req)
    eng.step()
    jax.block_until_ready(eng.last_tokens)
    prefill_t = max(eng.stats["prefill_time_s"], 1e-9)

    probe = min(16, max(max_new - 4, 0))
    steady = max(max_new - 3 - probe, 1)   # + probe: before any retirement
    t0 = time.perf_counter()
    for _ in range(steady):
        eng.step()
    jax.block_until_ready(eng.last_tokens)
    decode_t = max(time.perf_counter() - t0, 1e-9)
    decode_tokens = steady * n_requests

    tick_times = []
    for _ in range(probe):
        t1 = time.perf_counter()
        eng.step()
        jax.block_until_ready(eng.last_tokens)
        tick_times.append(time.perf_counter() - t1)

    _drive(eng, [])                    # drain: retires + flushes, untimed
    return ({
        "prefill_tokens": eng.stats["prefill_tokens"],
        "prefill_time_s": prefill_t,
        "prefill_tok_s": eng.stats["prefill_tokens"] / prefill_t,
        "prefill_dispatches": eng.stats["prefill_dispatches"],
        "decode_tokens": int(decode_tokens),
        "decode_time_s": decode_t,
        "decode_tok_s": decode_tokens / decode_t,
        "decode_dispatches": eng.stats["decode_dispatches"],
    }, tick_times)


def _summarize(runs, all_ticks, eng):
    """Median-of-N per phase over interleaved repeats: the engines share
    the box tick-for-tick, so the median is robust to interference
    outliers on either side (per-run numbers and the best are recorded
    too)."""
    best_p = max(r["prefill_tok_s"] for r in runs)
    best_d = max(r["decode_tok_s"] for r in runs)
    med_p = sorted(r["prefill_tok_s"] for r in runs)[len(runs) // 2]
    med_d = sorted(r["decode_tok_s"] for r in runs)[len(runs) // 2]
    decode_ticks = np.asarray(all_ticks) * 1e3
    return {
        "prefill_tok_s": round(med_p, 2),
        "decode_tok_s": round(med_d, 2),
        "prefill_tok_s_best": round(best_p, 2),
        "decode_tok_s_best": round(best_d, 2),
        "prefill_tokens_per_run": runs[0]["prefill_tokens"],
        "decode_tokens_per_run": runs[0]["decode_tokens"],
        "prefill_dispatches_per_run": runs[0]["prefill_dispatches"],
        "decode_dispatches_per_run": runs[0]["decode_dispatches"],
        "p50_tick_ms": round(float(np.percentile(decode_ticks, 50)), 4)
        if decode_ticks.size else None,
        "p99_tick_ms": round(float(np.percentile(decode_ticks, 99)), 4)
        if decode_ticks.size else None,
        "runs": [{k: (round(v, 6) if isinstance(v, float) else v)
                  for k, v in r.items()} for r in runs],
        "store_bytes": eng.stats["store_bytes"],
        "store_evictions": eng.stats["store_evictions"],
    }


def bench_pair(params, cfg, rc, *, n_slots: int, max_seq: int,
               prompt_len: int, max_new: int, n_requests: int,
               prefill_chunk: int, temperature: float, seed: int,
               repeats: int = 4):
    """Bench legacy + device-resident engines with interleaved repeats on
    identical prompt sets (noise on a shared box hits both sides alike)."""
    from repro.serving.engine import Request, ServingEngine

    engines = {
        "legacy_host_path": ServingEngine(
            params, cfg, rc, n_slots=n_slots, max_seq=max_seq,
            temperature=temperature, seed=seed,
            prefill_chunk=prefill_chunk, legacy_host_path=True,
            sync_prefill=True),
        "device_resident": ServingEngine(
            params, cfg, rc, n_slots=n_slots, max_seq=max_seq,
            temperature=temperature, seed=seed,
            prefill_chunk=prefill_chunk, sync_prefill=True),
    }
    rng = np.random.default_rng(seed)

    def batch(rid0):
        # fresh rids AND fresh prompts per repeat so the device-resident
        # engine can never serve a timed pass from retired pages
        prompts = [rng.integers(1, cfg.vocab_size, prompt_len).tolist()
                   for _ in range(n_requests)]
        return lambda: [Request(rid=rid0 + i, prompt=p,
                                max_new_tokens=max_new)
                        for i, p in enumerate(prompts)]

    warm = batch(100_000)
    for eng in engines.values():
        _drive(eng, warm())      # compiles every hot-path trace

    runs = {k: [] for k in engines}
    ticks = {k: [] for k in engines}
    first_batch = None
    for rep in range(max(repeats, 1)):
        mk = batch(1000 * rep)
        if first_batch is None:
            first_batch = mk()
        for name, eng in engines.items():
            r, t = _timed_pass(eng, mk(), n_requests, max_new)
            runs[name].append(r)
            ticks[name].extend(t)

    out = {name: _summarize(runs[name], ticks[name], eng)
           for name, eng in engines.items()}

    # prefix-reuse probe: resubmit a timed rid + prompt to the new engine
    eng = engines["device_resident"]
    pf0 = eng.stats["prefill_dispatches"]
    hit0 = eng.stats["prefix_hits"]
    probe = first_batch[0]
    _drive(eng, [Request(rid=probe.rid, prompt=probe.prompt,
                         max_new_tokens=max_new)])
    dev = out["device_resident"]
    dev["resubmit_prefill_dispatches"] = (eng.stats["prefill_dispatches"]
                                          - pf0)
    dev["prefix_hits"] = eng.stats["prefix_hits"] - hit0
    dev["prefix_hit_rate"] = float(dev["prefix_hits"])
    return out


def _tier_scenario(params, cfg, rc, tier, prompts, *, n_slots, max_seq,
                   max_new, prefill_chunk, seed, step_ns, label):
    """Serve -> settle -> resubmit against one tier; return its metrics.

    Serve a batch (retire -> flush populates the tier), settle the
    staging ring into the cold tier (the EPs may defer flush admission
    around internal tasks), then resubmit the same prompts — every
    resubmit restores through a simulated cold-tier fetch whose stall is
    charged per request. Identical prompts across scenarios, so the only
    variables are the tier's topology/media/placement and the SR engine.
    """
    from repro.serving.engine import Request, ServingEngine

    eng = ServingEngine(params, cfg, rc, n_slots=n_slots, max_seq=max_seq,
                        temperature=0.0, seed=seed,
                        prefill_chunk=prefill_chunk, cxl_tier=tier)
    _drive(eng, [Request(rid=i, prompt=p, max_new_tokens=max_new)
                 for i, p in enumerate(prompts)])
    for _ in range(500):               # settle staging into the tier
        if not eng.flusher.pending:
            break
        tier.advance(step_ns)
        eng.stats["flushes"] += eng.flusher.maybe_flush()
    if eng.flusher.pending:
        # restores would hit the free staging path and the sweep would
        # measure the wrong regime — fail loudly instead
        sys.exit(f"FAIL: cxl-tier staging did not drain into the cold "
                 f"tier ({label}, {len(eng.flusher.pending)} pending)")
    _drive(eng, [Request(rid=1000 + i, prompt=p, max_new_tokens=max_new)
                 for i, p in enumerate(prompts)])
    snap = tier.snapshot()
    hits = eng.stats["prefix_hits"]
    return {
        "restores": hits,
        "restore_stall_ns_total":
            round(eng.stats["restore_stall_ns"], 1),
        "restore_stall_ns_per_restore":
            round(eng.stats["restore_stall_ns"] / max(hits, 1), 1),
        "sr_hit_rate": round(snap["sr_hit_rate"], 4),
        "sr_prefetch_pages": snap["prefetches"],
        "flush_write_ns_total": round(snap["write_ns"], 1),
        "store_queue_occupancy":
            round(eng.stats["tier_store_occupancy"], 4),
        "flushes_deferred": eng.stats["flushes_deferred"],
        "gc_events": snap["gc_events"],
        "trace_ops": snap["trace_ops"],
    }


# every replay gate priced this run: where it ran, which engine priced
# it, whether it held, and the scalar/vectorized wall-time ratio — main()
# emits the list as the artifact's "replay_gates" section
_REPLAY_GATES = []


def _trace_replay(ops, op_ns, *, media, topology=None, sr=True, ds=True,
                  req_bytes=256, dram_cache_bytes=64 << 10,
                  max_inflight=4, faults=None):
    """Price one recorded page trace; returns (ok, engine, wall_ratio).

    The scalar oracle (``replay_page_trace``) is always run — it is the
    ground truth the 1% gate compares against. When the trace is
    eligible for the vectorized closed form (DRAM-class media on every
    lane, no fault annotations — ``page_trace_closed_form`` rejects the
    rest), that engine prices the gate too and the ratio of the two
    wall times is recorded; ineligible traces fall back to the scalar
    pricing with ratio 1.0.
    """
    from repro.sim.engine import replay_page_trace

    t0 = time.perf_counter()
    oracle = replay_page_trace(
        ops, media=media, topology=topology, sr=sr, ds=ds,
        req_bytes=req_bytes, dram_cache_bytes=dram_cache_bytes,
        max_inflight=max_inflight, faults=faults)
    t_scalar = time.perf_counter() - t0
    engine, ratio, priced = "scalar", 1.0, oracle
    if faults is None:
        from repro.sim.vector import page_trace_closed_form
        try:
            t0 = time.perf_counter()
            priced = page_trace_closed_form(
                ops, topology if topology is not None else media,
                ds=ds, req_bytes=req_bytes, max_inflight=max_inflight)
            engine = "vectorized"
            ratio = t_scalar / max(time.perf_counter() - t0, 1e-9)
        except ValueError:
            priced = oracle
    ok = bool(np.allclose(np.asarray(op_ns), priced, rtol=0.01, atol=1e-6))
    if engine == "vectorized":
        # the closed form must itself sit on the oracle, not just on the
        # live charges — a drifting engine must not price gates
        ok = ok and bool(np.allclose(priced, oracle, rtol=0.01, atol=1e-6))
    return ok, engine, ratio


def _replay_gate(tier, where: str = "") -> bool:
    """Differential gate: replay every op trace the tier recorded within
    1% — the single rank trace of a ``CxlTier``, or every rank's
    port-tagged trace plus every peer-link lane of a ``ShardedTier``.
    Each priced trace appends a record to ``_REPLAY_GATES``."""
    tiers = getattr(tier, "ranks", [tier])
    ok = True
    for i, t in enumerate(tiers):
        if not t.ops:
            continue
        good, engine, ratio = _trace_replay(
            t.ops, t.op_ns, media=t.cfg.media_name,
            topology=t.cfg.port_medias if t.cfg.tagged else None,
            sr=t.cfg.sr_enabled, ds=t.cfg.ds_enabled,
            req_bytes=t.cfg.req_bytes,
            dram_cache_bytes=t.cfg.dram_cache_bytes,
            max_inflight=t.cfg.max_inflight, faults=t.cfg.faults)
        label = where if len(tiers) == 1 else f"{where}/rank{i}"
        _REPLAY_GATES.append({"where": label, "engine": engine,
                              "ok": good, "wall_ratio": round(ratio, 2)})
        ok &= good
    for r in range(getattr(tier, "n_ranks", 0)):
        if not tier.peer_ops[r]:
            continue
        good, engine, ratio = _trace_replay(
            tier.peer_ops[r], tier.peer_op_ns[r], media=tier.peer_media,
            sr=False, ds=False, req_bytes=tier.cfg.req_bytes,
            dram_cache_bytes=tier.cfg.dram_cache_bytes,
            max_inflight=tier.cfg.max_inflight)
        _REPLAY_GATES.append({"where": f"{where}/peer{r}", "engine": engine,
                              "ok": good, "wall_ratio": round(ratio, 2)})
        ok &= good
    return ok


# topology axis: 1-port baseline vs multi-port heterogeneous topologies
# (overlapping per-port lanes) x placement policy. Each scenario runs
# SR on and (for the striped set) SR off on identical traffic.
TOPOLOGIES = {
    "1-port": {"topology": ("ssd-fast",), "placement": "striped"},
    # homogeneous pair: same media as the baseline, so any stall
    # reduction is attributable to per-port overlap alone (the hetero
    # scenario below would also win just from the faster DRAM lane)
    "2-port-ssd": {"topology": ("ssd-fast", "ssd-fast"),
                   "placement": "striped"},
    "2-port-hetero": {"topology": ("dram", "ssd-fast"),
                      "placement": "striped"},
    "3-port-hetero": {"topology": ("dram", "ssd-fast", "ssd-slow"),
                      "placement": "striped"},
    "3-port-hashed": {"topology": ("dram", "ssd-fast", "ssd-slow"),
                      "placement": "hashed"},
    "3-port-hotness": {"topology": ("dram", "ssd-fast", "ssd-slow"),
                       "placement": "hotness"},
}


def _sched_metrics(eng, tier) -> dict:
    """Scheduler-axis metrics for one finished engine run."""
    sim_ns = max(tier.topo.now, 1e-9)
    return {
        "completed": len(eng.finished),
        "sim_time_ns": round(tier.topo.now, 1),
        "req_per_sim_s": round(len(eng.finished) / sim_ns * 1e9, 2),
        "restore_stall_ns_total": round(eng.stats["restore_stall_ns"], 1),
        "restore_inflight_ns": round(eng.stats["restore_inflight_ns"], 1),
        "overlap_ratio": round(eng.stats["restore_overlap_ratio"], 4),
        "preemptions": eng.stats["preemptions"],
        "swap_out_bytes": eng.stats["swap_out_bytes"],
        "swap_in_bytes": eng.stats["swap_in_bytes"],
        "inflight_peak": eng.stats["sched_inflight_peak"],
        "prefix_hits": eng.stats["prefix_hits"],
        "replay_within_1pct": _replay_gate(tier, "scheduler"),
    }


def bench_scheduler(params, cfg, rc, *, n_slots: int, max_seq: int,
                    prompt_len: int, max_new: int, prefill_chunk: int,
                    seed: int, step_ns: float = 100_000.0):
    """The async/preemption axis of the request-lifecycle scheduler.

    Axis 1 (``restore``) serves -> settles -> resubmits identical traffic
    with blocking vs completion-based async restores; the gate is that
    async mode's aggregate restore stall is strictly below blocking (the
    fetch overlaps decode instead of stalling the batch). Axis 2
    (``pressure``) pins long low-priority requests in every slot with a
    queue of short high-priority requests behind them, run for a fixed
    tick horizon under FIFO vs preempt+swap; the gate is that preemption
    completes strictly more requests per simulated second. Both gates
    also require every async op trace to replay within 1% of the scalar
    oracle. Returns ``(section, acceptance)``.
    """
    from repro.core.tier import CxlTier, TierConfig
    from repro.serving.engine import Request, ServingEngine

    rng = np.random.default_rng(seed)
    n_requests = n_slots * 2
    prompts = [rng.integers(1, cfg.vocab_size, prompt_len).tolist()
               for _ in range(n_requests)]
    kw = dict(n_slots=n_slots, max_seq=max_seq, temperature=0.0,
              seed=seed, prefill_chunk=prefill_chunk)

    restore = {}
    for mode in ("blocking", "async"):
        tier = CxlTier(TierConfig(media="ssd-fast"))
        eng = ServingEngine(params, cfg, rc, cxl_tier=tier,
                            cxl_async=(mode == "async"), **kw)
        _drive(eng, [Request(rid=i, prompt=p, max_new_tokens=max_new)
                     for i, p in enumerate(prompts)])
        for _ in range(500):           # settle staging into the cold tier
            if not eng.flusher.pending:
                break
            tier.advance(step_ns)
            eng.flusher.maybe_flush()
        if eng.flusher.pending:
            sys.exit(f"FAIL: scheduler staging did not drain ({mode})")
        _drive(eng, [Request(rid=1000 + i, prompt=p, max_new_tokens=max_new)
                     for i, p in enumerate(prompts)])
        restore[mode] = _sched_metrics(eng, tier)

    # pressure scenario: every slot pinned by a long low-priority decode
    # (admitted and running before the short high-priority work arrives),
    # then a fixed simulated horizon too short for any long to finish —
    # FIFO pays for head-of-line blocking in completed requests, the
    # preempting scheduler swaps the longs out and serves the shorts
    long_new = min(6 * max_new, max_seq - 2 - prompt_len)
    horizon = max(long_new - 16, 2 * max_new)
    n_short = n_slots * 2
    long_prompts = [rng.integers(1, cfg.vocab_size, prompt_len).tolist()
                    for _ in range(n_slots)]
    short_prompts = [rng.integers(1, cfg.vocab_size, prompt_len).tolist()
                     for _ in range(n_short)]
    pressure = {}
    for mode, policy in (("fifo", "none"), ("preempt_swap", "swap")):
        tier = CxlTier(TierConfig(media="ssd-fast"))
        eng = ServingEngine(params, cfg, rc, cxl_tier=tier, cxl_async=True,
                            preempt_policy=policy, **kw)
        for i, p in enumerate(long_prompts):
            eng.submit(Request(rid=i, prompt=p, priority=0,
                               max_new_tokens=long_new))
        eng.step(); eng.step()      # longs admitted and decoding
        for i, p in enumerate(short_prompts):
            eng.submit(Request(rid=100 + i, prompt=p, priority=1,
                               max_new_tokens=4))
        eng.run(max_ticks=horizon)
        pressure[mode] = _sched_metrics(eng, tier)

    acceptance = {
        "sched_async_stall_below_blocking":
            restore["async"]["restore_stall_ns_total"]
            < restore["blocking"]["restore_stall_ns_total"],
        "sched_async_all_resubmits_restored":
            restore["async"]["prefix_hits"] == n_requests,
        "sched_preempt_swap_higher_throughput":
            pressure["preempt_swap"]["req_per_sim_s"]
            > pressure["fifo"]["req_per_sim_s"],
        "sched_preempt_swap_preempted":
            pressure["preempt_swap"]["preemptions"] >= 1
            and pressure["preempt_swap"]["swap_in_bytes"] > 0,
        "sched_replay_within_1pct": all(
            scen["replay_within_1pct"]
            for per in (restore, pressure) for scen in per.values()),
    }
    return {"restore": restore, "pressure": pressure}, acceptance


def bench_cxl_tier(params, cfg, rc, *, n_slots: int, max_seq: int,
                   prompt_len: int, max_new: int, prefill_chunk: int,
                   seed: int, step_ns: float = 100_000.0):
    """Sweep the CXL-timed tier: media bins x SR, then the topology axis.

    Section 1 (``media_bins``) is the single-port sweep (dram / ssd-fast
    / ssd-slow x SR on/off). Section 2 (``topology``) sweeps multi-root-
    port topologies x placement policy on the same traffic, with the
    acceptance gate that multi-port overlap strictly reduces aggregate
    restore stall vs the 1-port baseline, and that every port-tagged op
    trace replays within 1% of the scalar oracle.
    """
    from repro.core.tier import CxlTier, TierConfig

    rng = np.random.default_rng(seed)
    n_requests = n_slots * 2
    prompts = [rng.integers(1, cfg.vocab_size, prompt_len).tolist()
               for _ in range(n_requests)]
    kw = dict(n_slots=n_slots, max_seq=max_seq, max_new=max_new,
              prefill_chunk=prefill_chunk, seed=seed, step_ns=step_ns)

    bins = {}
    for bin_name in ("dram", "ssd-fast", "ssd-slow"):
        per = {}
        for sr in (False, True):
            tier = CxlTier(TierConfig(media=bin_name, sr_enabled=sr))
            per["sr_on" if sr else "sr_off"] = _tier_scenario(
                params, cfg, rc, tier, prompts,
                label=f"{bin_name}/sr={sr}", **kw)
        bins[bin_name] = per

    topo = {}
    replay_within_1pct = True
    for name, spec in TOPOLOGIES.items():
        per = {}
        sr_modes = (False, True) if spec["placement"] == "striped" \
            else (True,)
        for sr in sr_modes:
            tier = CxlTier(TierConfig(topology=spec["topology"],
                                      placement=spec["placement"],
                                      sr_enabled=sr))
            res = _tier_scenario(params, cfg, rc, tier, prompts,
                                 label=f"{name}/sr={sr}", **kw)
            res["ports"] = [
                {k: p[k] for k in ("port", "media", "ep_reads",
                                   "ep_writes", "sr_hit_rate",
                                   "live_bytes", "gc_events")}
                for p in tier.port_stats()]
            res["promotions"] = tier.counters["promotions"]
            res["demotions"] = tier.counters["demotions"]
            res["replay_within_1pct"] = _replay_gate(
                tier, f"topology/{name}/sr={sr}")
            replay_within_1pct &= res["replay_within_1pct"]
            per["sr_on" if sr else "sr_off"] = res
        topo[name] = per

    acceptance = {
        f"sr_reduces_restore_stall[{b}]":
            bins[b]["sr_on"]["restore_stall_ns_total"]
            < bins[b]["sr_off"]["restore_stall_ns_total"]
        for b in ("ssd-fast", "ssd-slow")}
    acceptance["all_resubmits_restored"] = all(
        v["restores"] == n_requests
        for per in bins.values() for v in per.values()) and all(
        v["restores"] == n_requests
        for per in topo.values() for v in per.values())
    # the tentpole gates: per-port lanes overlapping inside each restore
    # must strictly beat the serialized single-port stream on the same
    # traffic. The homogeneous pair isolates overlap (identical media,
    # so only lane concurrency can reduce stall); the heterogeneous pair
    # is the paper's DRAM+SSD configuration (overlap + a faster lane).
    acceptance["multi_port_overlap_reduces_stall"] = (
        topo["2-port-ssd"]["sr_on"]["restore_stall_ns_total"]
        < topo["1-port"]["sr_on"]["restore_stall_ns_total"])
    acceptance["hetero_2port_beats_1port"] = (
        topo["2-port-hetero"]["sr_on"]["restore_stall_ns_total"]
        < topo["1-port"]["sr_on"]["restore_stall_ns_total"])
    acceptance["topology_replay_within_1pct"] = replay_within_1pct

    # the async/preemption axis: blocking vs async restores, FIFO vs
    # preempt+swap under pressure (gates merged into this acceptance)
    scheduler, sched_acceptance = bench_scheduler(
        params, cfg, rc, n_slots=n_slots, max_seq=max_seq,
        prompt_len=prompt_len, max_new=max_new,
        prefill_chunk=prefill_chunk, seed=seed, step_ns=step_ns)
    acceptance.update(sched_acceptance)
    return {
        "config": {"n_slots": n_slots, "n_requests": n_requests,
                   "prompt_len": prompt_len, "max_new_tokens": max_new,
                   "max_seq": max_seq, "tier_step_ns": step_ns,
                   "seed": seed},
        "media_bins": bins,
        "topology": topo,
        "scheduler": scheduler,
        "acceptance": acceptance,
    }


# Token-quality bound for the kv_quant axis: greedy decode with int8 KV
# should match bf16 token-for-token on the smoke configs; where int8
# rounding flips a near-tie logit the runs may diverge from that point,
# so the documented fallback gate is a positional match fraction over
# all generated tokens (see docs/ARCHITECTURE.md "KV page format").
KVQ_TOKEN_MATCH_MIN = 0.9


def bench_kv_quant(*, arch: str, vocab: int, n_slots: int, max_seq: int,
                   prompt_len: int, max_new: int, prefill_chunk: int,
                   seed: int, step_ns: float = 100_000.0):
    """The quantized-KV-page axis (``cxl_tier["kv_quant"]``).

    Runs the serve -> settle -> resubmit tier scenario twice on identical
    traffic against identical ``ssd-fast`` tiers: once with the bf16 page
    format (its own bf16 build — the ``--dtype`` default is the CPU-native
    f32, which would make "int8 vs bf16" a lie) and once with
    ``kv_quant="int8"``. Every flush/restore/SR fetch charges the tier the
    entry's actual byte count, so the int8 run's tier traffic is ~half.

    Acceptance gates (exit 1 from main on any failure):

     * int8 aggregate restore stall strictly below bf16,
     * flush+restore bytes ~ half of bf16 (ratio in [0.4, 0.6]; per-page
       fp32 scales add ~0.1% back),
     * greedy token identity vs bf16 — or the documented bounded-
       divergence fallback (match fraction >= ``KVQ_TOKEN_MATCH_MIN``),
     * both op traces replay within 1% of the scalar oracle.
    """
    from repro.core.tier import CxlTier, TierConfig
    from repro.serving.config import ServeConfig
    from repro.serving.engine import Request, ServingEngine

    cfg, rc, params = _build(arch, seed, vocab, "bfloat16")
    rng = np.random.default_rng(seed)
    n_requests = n_slots * 2
    prompts = [rng.integers(1, cfg.vocab_size, prompt_len).tolist()
               for _ in range(n_requests)]

    def run_one(kv_quant: str):
        tier = CxlTier(TierConfig(media="ssd-fast"))
        eng = ServingEngine(params, cfg, rc, cxl_tier=tier,
                            config=ServeConfig(
                                n_slots=n_slots, max_seq=max_seq,
                                temperature=0.0, seed=seed,
                                prefill_chunk=prefill_chunk,
                                kv_quant=kv_quant))
        _drive(eng, [Request(rid=i, prompt=p, max_new_tokens=max_new)
                     for i, p in enumerate(prompts)])
        for _ in range(500):           # settle staging into the cold tier
            if not eng.flusher.pending:
                break
            tier.advance(step_ns)
            eng.flusher.maybe_flush()
        if eng.flusher.pending:
            sys.exit(f"FAIL: kv_quant staging did not drain ({kv_quant})")
        _drive(eng, [Request(rid=1000 + i, prompt=p, max_new_tokens=max_new)
                     for i, p in enumerate(prompts)])
        tokens = {r.rid: list(r.generated) for r in eng.finished}
        hits = eng.stats["prefix_hits"]
        scen = {
            "restores": hits,
            "restore_stall_ns_total":
                round(eng.stats["restore_stall_ns"], 1),
            "restore_stall_ns_per_restore":
                round(eng.stats["restore_stall_ns"] / max(hits, 1), 1),
            "flush_write_ns_total": round(tier.counters["write_ns"], 1),
            "read_bytes": tier.counters["read_bytes"],
            "write_bytes": tier.counters["write_bytes"],
            "prefetch_bytes": tier.counters["prefetch_bytes"],
            "store_bytes": eng.stats["store_bytes"],
            "replay_within_1pct": _replay_gate(tier, f"kv_quant/{kv_quant}"),
        }
        return scen, tokens

    bf16, tok_bf16 = run_one("none")
    int8, tok_int8 = run_one("int8")

    total = matched = 0
    identity = True
    for rid in sorted(tok_bf16):
        a = tok_bf16[rid]
        b = tok_int8.get(rid, [])
        if a != b:
            identity = False
        total += max(len(a), len(b))
        matched += sum(x == y for x, y in zip(a, b))
    match_fraction = matched / max(total, 1)

    def traffic(scen) -> int:
        return scen["read_bytes"] + scen["write_bytes"]

    bytes_ratio = traffic(int8) / max(traffic(bf16), 1)
    acceptance = {
        "kvq_restore_stall_strictly_below_bf16":
            int8["restore_stall_ns_total"] < bf16["restore_stall_ns_total"],
        "kvq_flush_restore_bytes_near_half": 0.4 <= bytes_ratio <= 0.6,
        "kvq_all_resubmits_restored":
            int8["restores"] == n_requests
            and bf16["restores"] == n_requests,
        "kvq_token_quality":
            identity or match_fraction >= KVQ_TOKEN_MATCH_MIN,
        "kvq_replay_within_1pct":
            bf16["replay_within_1pct"] and int8["replay_within_1pct"],
    }
    return {
        "config": {"arch": arch, "dtype": "bfloat16",
                   "n_slots": n_slots, "n_requests": n_requests,
                   "prompt_len": prompt_len, "max_new_tokens": max_new,
                   "max_seq": max_seq, "prefill_chunk": prefill_chunk,
                   "tier_step_ns": step_ns, "seed": seed,
                   "bytes_ratio_int8_vs_bf16": round(bytes_ratio, 4),
                   "token_match_min": KVQ_TOKEN_MATCH_MIN},
        "modes": {"bf16": bf16, "int8": int8},
        "tokens": {"identity": identity,
                   "match_fraction": round(match_fraction, 4),
                   "compared": total},
        "acceptance": acceptance,
    }


def bench_load(params, cfg, rc, *, prefill_chunk: int, seed: int,
               smoke: bool):
    """Open-loop continuous-batching load harness (the ``load`` section).

    A seeded open-loop arrival trace (bursty inter-arrival at ~1.25x the
    continuous engine's service capacity, zipf prompt popularity over a
    shared catalog, mixed prompt/output lengths, a high-priority
    interactive class) is generated once and played against three
    engines on the simulated clock:

     * ``batching``   — closed (wave) admission vs continuous
       admit-on-retire slot recycling, FIFO both;
     * ``scheduling`` — FIFO (= the continuous run) vs preempt+swap on
       the same trace.

    Each scenario emits the full ``LoadMetrics`` SLO summary (TTFT/TPOT
    p50/p99, goodput at the latency targets, queue-depth and restore-
    stall percentiles) plus the engine's typed stats and the tier-trace
    replay gate. Acceptance: continuous goodput strictly above closed on
    the identical trace, every arrival completed, percentiles emitted,
    preemption engaged, every trace replaying within 1% of the oracle.
    Returns the section dict (acceptance included).
    """
    from repro.core.tier import CxlTier, TierConfig
    from repro.serving.config import ServeConfig
    from repro.serving.engine import ServingEngine

    n_slots = 16 if smoke else 256
    max_seq = 64
    max_ticks = 4_000 if smoke else 40_000
    tick_s = 100_000.0 * 1e-9
    new_choices = (4, 8, 16)
    # offered rate: ~1.25x the continuous engine's mean service capacity
    # (slots retire every mean(max_new) ticks), so queues form — the
    # regime where admission policy and preemption actually matter
    mean_new = sum(new_choices) / len(new_choices)
    rate_rps = round(1.25 * n_slots / (mean_new * tick_s))
    lc = _LOADGEN.LoadConfig(
        n_arrivals=48 if smoke else 600,
        rate_rps=float(rate_rps),
        arrival="bursty",
        zipf_s=1.2,
        n_prompts=12 if smoke else 64,
        prompt_len_choices=(8, 16, 24),
        max_new_choices=new_choices,
        vocab=cfg.vocab_size,
        hi_prio_frac=0.25,
        seed=seed,
        slo_ttft_ms=2.0,
        slo_tpot_ms=0.2)
    trace = _LOADGEN.make_trace(lc)

    def run_one(admit_mode, policy):
        tier = CxlTier(TierConfig(media="ssd-fast"))
        eng = ServingEngine(params, cfg, rc, cxl_tier=tier,
                            config=ServeConfig(
                                n_slots=n_slots, max_seq=max_seq,
                                prefill_chunk=prefill_chunk, seed=seed,
                                cxl_async=True, admit_mode=admit_mode,
                                preempt_policy=policy))
        handles, depths = _LOADGEN.drive_open_loop(eng, trace,
                                                   max_ticks=max_ticks)
        res = _LOADGEN.summarize(eng, handles, depths, lc).as_dict()
        res["engine"] = eng.stats.as_dict()
        res["replay_within_1pct"] = _replay_gate(
            tier, f"load/{admit_mode}/{policy}")
        return res

    batching = {"closed": run_one("closed", "none"),
                "continuous": run_one("continuous", "none")}
    scheduling = {"fifo": batching["continuous"],
                  "preempt_swap": run_one("continuous", "swap")}
    scens = (batching["closed"], batching["continuous"],
             scheduling["preempt_swap"])
    acceptance = {
        "load_continuous_goodput_above_closed":
            batching["continuous"]["goodput_req_s"]
            > batching["closed"]["goodput_req_s"],
        "load_all_arrivals_completed": all(
            s["completed"] == lc.n_arrivals for s in scens),
        "load_ttft_percentiles_emitted": all(
            s["ttft_ms_p99"] > 0 and s["tpot_ms_p99"] > 0 for s in scens),
        "load_preempt_engaged":
            scheduling["preempt_swap"]["preemptions"] >= 1,
        "load_replay_within_1pct": all(
            s["replay_within_1pct"] for s in scens),
    }
    config = {k: getattr(lc, k) for k in lc.field_names()}
    config.update(n_slots=n_slots, max_seq=max_seq, max_ticks=max_ticks)
    return {"config": config, "batching": batching,
            "scheduling": scheduling, "acceptance": acceptance}


# fault axis: the mixed-family fleet (one member per KV family shape —
# paged-KV moe, hybrid mamba2, pure-ssm xlstm) driven through one
# identical failure trace on a 2-port tier: a transient-error window on
# port 0, then a latency spike on port 1, then port 1 hot-removed for
# good — against the identical healthy arrival trace.
FAULT_FLEET = ("granite-moe-1b-a400m", "zamba2-2.7b", "xlstm-125m")
FAULT_TOPOLOGY = ("dram", "ssd-fast")
FAULT_TRACE = (
    ("transient", 0.5e6, 0, 0.85, 6.0e6),   # flaky CXL.mem window
    ("degrade", 1.0e6, 1, 300.0, 8.0e6),    # backend latency spike
    ("hot_remove", 3.0e6, 1),               # then the endpoint dies
)


def bench_fault(*, prefill_chunk: int, seed: int, smoke: bool,
                vocab: int, dtype: str):
    """Fault-injection axis of the load section (``load["fault"]``).

    Each fleet member runs the same seeded open-loop arrival trace twice
    — healthy, and under ``FAULT_TRACE`` (transient window -> degrade ->
    hot-remove on a 2-port tier) with ``preempt_policy="recompute"`` so
    page loss always has a resume path. Acceptance (the degraded-mode
    SLO gates): every submitted request completes under faults
    (``lost_requests == 0``), degraded goodput stays within 0.25x the
    healthy run on the identical trace, transient retries stay inside
    the per-op budget and recoveries inside the per-request force-
    prefill bound (no livelock), the faulted runs actually exercised the
    fault machinery, and every trace — fault-annotated kinds included —
    replays within 1% of the scalar oracle.
    """
    from repro.serving.config import ServeConfig
    from repro.serving.engine import ServingEngine
    from repro.serving.scheduler import RECOVERY_PREFILL_AFTER
    from repro.sim.engine import MAX_OP_RETRIES

    n_slots = 8
    max_seq = 64
    max_ticks = 4_000 if smoke else 40_000
    lc = _LOADGEN.LoadConfig(
        n_arrivals=24 if smoke else 192,
        rate_rps=8000.0,
        arrival="bursty",
        zipf_s=1.2,
        n_prompts=8 if smoke else 32,
        prompt_len_choices=(8, 16),
        max_new_choices=(4, 8),
        vocab=vocab or 256,
        seed=seed,
        slo_ttft_ms=2.0,
        slo_tpot_ms=0.5)
    trace = _LOADGEN.make_trace(lc)

    def run_one(params, cfg, rc, faults):
        eng = ServingEngine(params, cfg, rc, config=ServeConfig(
            n_slots=n_slots, max_seq=max_seq,
            prefill_chunk=prefill_chunk, seed=seed,
            cxl_async=True, preempt_policy="recompute",
            tier_topology=FAULT_TOPOLOGY, tier_faults=faults,
            fault_seed=seed))
        handles, depths = _LOADGEN.drive_open_loop(eng, trace,
                                                   max_ticks=max_ticks)
        res = _LOADGEN.summarize(eng, handles, depths, lc).as_dict()
        res["engine"] = eng.stats.as_dict()
        res["replay_within_1pct"] = _replay_gate(eng.tier, "fault")
        return res

    fleet = {}
    for arch in FAULT_FLEET:
        cfg, rc, params = _build(arch, seed, vocab, dtype)
        fleet[arch] = {"healthy": run_one(params, cfg, rc, ()),
                       "faulted": run_one(params, cfg, rc, FAULT_TRACE)}

    def goodput_ratio(per) -> float:
        h, f = per["healthy"], per["faulted"]
        if h["goodput_req_s"] > 0:
            return f["goodput_req_s"] / h["goodput_req_s"]
        if h["throughput_req_s"] > 0:      # degenerate SLO: fall back to
            return (f["throughput_req_s"]  # raw completion rate
                    / h["throughput_req_s"])
        return 1.0

    faulted = [per["faulted"] for per in fleet.values()]
    acceptance = {
        "fault_zero_lost_requests": all(
            s["lost_requests"] == 0 for s in faulted),
        "fault_goodput_within_bound": all(
            goodput_ratio(per) >= 0.25 for per in fleet.values()),
        "fault_retries_bounded": all(
            s["engine"]["tier_fault_retries"]
            <= max(s["engine"]["tier_fault_ops"], 1) * (MAX_OP_RETRIES + 1)
            and s["recoveries"]
            <= lc.n_arrivals * (RECOVERY_PREFILL_AFTER + 1)
            for s in faulted),
        "fault_injection_engaged": any(
            s["engine"]["tier_fault_ops"] > 0
            or s["engine"]["tier_lost_entries"] > 0 for s in faulted),
        "fault_replay_within_1pct": all(
            s["replay_within_1pct"]
            for per in fleet.values() for s in per.values()),
    }
    config = {k: getattr(lc, k) for k in lc.field_names()}
    config.update(n_slots=n_slots, max_seq=max_seq, max_ticks=max_ticks,
                  fleet=list(FAULT_FLEET), topology=list(FAULT_TOPOLOGY),
                  trace=[list(e) for e in FAULT_TRACE])
    return {"config": config, "fleet": fleet, "acceptance": acceptance}


def bench_shard(*, arch: str, vocab: int, dtype: str, seed: int,
                smoke: bool, prefill_chunk: int = 8):
    """The shard axis (``shard`` section): 1-rank vs 2-/4-rank serving.

    One seeded open-loop arrival trace (bursty, zipf-shared prompt
    catalog — the shared-prefix regime) is played against the engine at
    every rank count on identical traffic: the 1-rank baseline runs a
    plain ``CxlTier`` under the host mesh; the sharded runs build a
    (1, N) mesh, shard params + the paged KV cache over the model axis
    and attach a ``ShardedTier`` (one port set per rank + peer-link
    lanes). Restores are blocking so the restore stall is a real,
    deterministic simulated cost.

    Acceptance gates (exit 1 from main on any failure):

     * greedy token identity — every rank count reproduces the 1-rank
       token streams exactly;
     * sublinear restore-stall scaling — aggregate restore stall at N
       ranks stays strictly below N x the 1-rank stall on the same
       traffic (a hot shared prefix is fetched from media once and
       fanned out over the peer link, not cold-restored N times);
     * the peer link actually engaged (fetches > 0) and flush traffic
       did not multiply with ranks;
     * zero lost requests everywhere, every arrival completed;
     * every rank + peer-lane trace replays within 1% of the oracle.
    """
    import dataclasses

    import jax
    from repro.core.sharded_tier import ShardedTier
    from repro.launch.mesh import make_host_mesh
    from repro.serving.config import ServeConfig
    from repro.serving.engine import ServingEngine

    n_devices = len(jax.devices())
    rank_counts = [1] + [n for n in (2, 4) if n <= n_devices]
    if len(rank_counts) < 2:
        sys.exit(f"FAIL: --shard needs >= 2 devices, have {n_devices} "
                 "(set XLA_FLAGS=--xla_force_host_platform_device_"
                 "count=4)")
    if 4 not in rank_counts:
        print(f"[shard] only {n_devices} devices: 4-rank point dropped",
              file=sys.stderr)

    cfg, rc, params = _build(arch, seed, vocab, dtype)
    # sharded decode needs the page axis divisible by every rank count
    max_seq = 64
    rc = dataclasses.replace(rc, kv_page_size=16)
    n_slots = 4
    lc = _LOADGEN.LoadConfig(
        n_arrivals=24 if smoke else 96,
        rate_rps=8000.0,
        arrival="bursty",
        zipf_s=1.2,
        n_prompts=8 if smoke else 24,
        prompt_len_choices=(8, 16),
        max_new_choices=(4, 8),
        vocab=cfg.vocab_size,
        seed=seed,
        slo_ttft_ms=2.0,
        slo_tpot_ms=0.5)
    trace = _LOADGEN.make_trace(lc)
    max_ticks = 4_000 if smoke else 16_000

    def run_one(n_ranks):
        sc = ServeConfig(n_slots=n_slots, max_seq=max_seq,
                         prefill_chunk=prefill_chunk, seed=seed,
                         tp=n_ranks if n_ranks > 1 else 1,
                         tier_topology=("dram", "ssd-fast"))
        eng = ServingEngine(params, cfg, rc, config=sc)
        handles, depths = _LOADGEN.drive_open_loop(eng, trace,
                                                   max_ticks=max_ticks)
        metrics = _LOADGEN.summarize(eng, handles, depths, lc)
        tokens = {r.rid: list(r.generated) for r in eng.finished}
        tier = eng.tier
        sharded = isinstance(tier, ShardedTier)
        c = tier.counters
        scen = {
            "mesh_ranks": eng.stats["mesh_ranks"],
            "completed": metrics.completed,
            "lost_requests": metrics.lost_requests,
            "prefix_hits": eng.stats["prefix_hits"],
            "restore_stall_ns_total":
                round(eng.stats["restore_stall_ns"], 1),
            "tier_writes": c["writes"] + c["async_writes"],
            "peer_fetches": c.get("peer_fetches", 0),
            "peer_bytes": c.get("peer_bytes", 0),
            "peer_fetch_ns": round(c.get("peer_fetch_ns", 0.0), 1),
            "mirror_writes": c.get("mirror_writes", 0),
            "rank_remaps": c.get("rank_remaps", 0),
            "replay_within_1pct": _replay_gate(tier, f"shard/{n_ranks}-rank"),
        }
        return scen, tokens

    ranks = {}
    tokens = {}
    with jax.set_mesh(make_host_mesh()):
        ranks["1-rank"], tokens[1] = run_one(1)
    for n in rank_counts[1:]:
        ranks[f"{n}-rank"], tokens[n] = run_one(n)

    base_stall = max(ranks["1-rank"]["restore_stall_ns_total"], 1e-9)
    for name, scen in ranks.items():
        n = scen["mesh_ranks"]
        scen["stall_ratio_vs_1rank"] = round(
            scen["restore_stall_ns_total"] / base_stall, 4)
        scen["token_identity_vs_1rank"] = tokens[n] == tokens[1]

    sharded = [s for s in ranks.values() if s["mesh_ranks"] > 1]
    acceptance = {
        "shard_token_identity": all(
            s["token_identity_vs_1rank"] for s in ranks.values()),
        "shard_restore_stall_sublinear": all(
            s["stall_ratio_vs_1rank"] < s["mesh_ranks"] for s in sharded)
        and ranks["1-rank"]["restore_stall_ns_total"] > 0,
        "shard_peer_link_engaged": all(
            s["peer_fetches"] > 0 for s in sharded),
        "shard_flush_traffic_bounded": all(
            s["tier_writes"] <= 2 * ranks["1-rank"]["tier_writes"]
            for s in sharded),
        "shard_zero_lost_requests": all(
            s["lost_requests"] == 0 and s["completed"] == lc.n_arrivals
            for s in ranks.values()),
        "shard_replay_within_1pct": all(
            s["replay_within_1pct"] for s in ranks.values()),
    }
    config = {k: getattr(lc, k) for k in lc.field_names()}
    config.update(n_slots=n_slots, max_seq=max_seq, max_ticks=max_ticks,
                  kv_page_size=rc.kv_page_size,
                  rank_counts=rank_counts, n_devices=n_devices,
                  topology=["dram", "ssd-fast"])
    return {"config": config, "ranks": ranks, "acceptance": acceptance}


def _zipf_churn_trace(seed: int, *, n_keys: int = 24, steps: int = 900,
                      phases: int = 3, alpha: float = 1.4,
                      nbytes: int = 32 << 10, flush_p: float = 0.06):
    """Phase-rotated zipf churn traffic for the placement axis.

    The zipf head rotates across the key space every ``steps/phases``
    ops, so yesterday's hot entries go cold — the regime where a plain
    promotion counter keeps thrashing the fast port while the learned
    mixture re-classifies. Returns ``("read"|"write", key, nbytes)``
    tuples; writes model the occasional re-flush of a mutated entry.
    """
    import random
    rng = random.Random(seed)
    trace = []
    w = [1.0 / (r + 1) ** alpha for r in range(n_keys)]
    for ph in range(phases):
        shift = ph * (n_keys // phases)
        ids = [(i + shift) % n_keys for i in range(n_keys)]
        for _ in range(steps // phases):
            k = ids[rng.choices(range(n_keys), weights=w)[0]]
            trace.append(("read", f"k{k}", nbytes))
            if rng.random() < flush_p:
                trace.append(("write", f"k{k}", nbytes))
    return trace


def _zipf_shared_trace(seed: int, *, n_ranks: int = 2, n_keys: int = 12,
                       steps: int = 600, alpha: float = 1.4,
                       nbytes: int = 32 << 10, affinity: float = 0.85,
                       flush_p: float = 0.08):
    """Zipf-shared multi-rank traffic: requester-rank-tagged restores.

    Each shared prefix has a dominant requester rank (``affinity`` of
    its restores come from it) that the blake2b hash home ignores —
    exactly what learned re-homing exploits. Returns
    ``("read"|"write", key, nbytes, req_rank)`` tuples (rank None on
    writes).
    """
    import random
    rng = random.Random(seed)
    dom = {k: rng.randrange(n_ranks) for k in range(n_keys)}
    w = [1.0 / (i + 1) ** alpha for i in range(n_keys)]
    trace = []
    for _ in range(steps):
        k = rng.choices(range(n_keys), weights=w)[0]
        r = dom[k] if rng.random() < affinity else rng.randrange(n_ranks)
        trace.append(("read", f"p{k}", nbytes, r))
        if rng.random() < flush_p:
            trace.append(("write", f"p{k}", nbytes, None))
    return trace


def bench_placement(*, seed: int, smoke: bool):
    """The placement axis (``placement`` section + the standalone
    BENCH_serve_placement.json artifact): the learned GMM placement
    policy (``repro.sim.policy``) vs the heuristics it replaces, on
    identical traces driven straight at the tiers.

     * **churn** — zipf-churn traffic (the hot set rotates every phase)
       against a 3-port heterogeneous ``CxlTier``:
       ``placement="learned"`` vs the ``hotness`` counter. Gate:
       learned strictly lowers aggregate restore stall.
     * **shared** — zipf-shared requester-tagged traffic against a
       2-rank ``ShardedTier``: learned cross-rank homing (re-home +
       multi-source restores) vs the plain blake2b hash home. Gates:
       learned strictly lowers aggregate peer bytes AND aggregate
       restore stall.

    Every tier trace must replay within 1% of the scalar oracle
    (``_replay_gate``, which also records the pricing engine and the
    wall-time ratio in the artifact's ``replay_gates`` section).
    """
    from repro.core.sharded_tier import ShardedTier
    from repro.core.tier import CxlTier, TierConfig

    steps = 300 if smoke else 900
    shared_steps = 240 if smoke else 600
    nb = 32 << 10
    topo3 = ("dram", "ssd-fast", "ssd-slow")
    topo2 = ("dram", "ssd-slow")

    churn_tr = _zipf_churn_trace(seed + 11, steps=steps, nbytes=nb)
    churn = {}
    for placement in ("hotness", "learned"):
        tier = CxlTier(TierConfig(topology=topo3, placement=placement))
        for k in sorted({k for _, k, _ in churn_tr}):
            tier.write_entry(k, nb)
        stall, reads = 0.0, 0
        for op, k, n in churn_tr:
            if op == "read":
                stall += tier.read_entry(k, n)
                reads += 1
            else:
                tier.write_entry(k, n)
            tier.advance(2000.0)
        c = tier.counters
        churn[placement] = {
            "restores": reads,
            "restore_stall_ns_total": round(stall, 1),
            "promotions": c["promotions"],
            "demotions": c["demotions"],
            "replay_within_1pct": _replay_gate(
                tier, f"placement/churn/{placement}"),
        }

    shared_tr = _zipf_shared_trace(seed + 17, steps=shared_steps,
                                   nbytes=nb)
    shared = {}
    for placement in ("hashed", "learned"):
        tier = ShardedTier(2, TierConfig(topology=topo2,
                                         placement=placement))
        for k in sorted({e[1] for e in shared_tr}):
            tier.write_entry(k, nb)
        stall, reads = 0.0, 0
        for op, k, n, r in shared_tr:
            if op == "read":
                stall += tier.read_entry(k, n, req_rank=r)
                reads += 1
            else:
                tier.write_entry(k, n)
            tier.advance(2000.0)
        c = tier.counters
        shared[placement] = {
            "restores": reads,
            "restore_stall_ns_total": round(stall, 1),
            "peer_bytes": c["peer_bytes"],
            "rehomes": c["rehomes"],
            "multi_source_reads": c["multi_source_reads"],
            "replay_within_1pct": _replay_gate(
                tier, f"placement/shared/{placement}"),
        }

    acceptance = {
        "learned_beats_hotness_on_churn_stall":
            churn["learned"]["restore_stall_ns_total"]
            < churn["hotness"]["restore_stall_ns_total"],
        "learned_home_beats_hash_home_stall":
            shared["learned"]["restore_stall_ns_total"]
            < shared["hashed"]["restore_stall_ns_total"],
        "learned_home_beats_hash_home_peer_bytes":
            shared["learned"]["peer_bytes"] < shared["hashed"]["peer_bytes"],
        "replay_within_1pct": all(
            s["replay_within_1pct"]
            for axis in (churn, shared) for s in axis.values()),
    }
    return {
        "config": {"seed": seed, "smoke": bool(smoke), "entry_bytes": nb,
                   "churn_steps": steps, "shared_steps": shared_steps,
                   "churn_topology": list(topo3),
                   "shared_topology": list(topo2), "shared_ranks": 2},
        "churn": churn,
        "shared": shared,
        "acceptance": acceptance,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI-sized matrix")
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--max-seq", type=int, default=None)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--vocab", type=int, default=1024,
                    help="vocab override for the smoke config (0 keeps the "
                         "256-token smoke vocab)")
    ap.add_argument("--dtype", default="float32",
                    help="param dtype override ('' keeps the config dtype; "
                         "default float32 = CPU-native)")
    ap.add_argument("--temperature", type=float, default=0.7,
                    help="0 = greedy; default exercises the sampling path "
                         "the rewrite moves on-device")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=5,
                    help="interleaved timed repetitions per engine "
                         "(median reported; per-run numbers recorded)")
    ap.add_argument("--cxl-tier", action="store_true",
                    help="also sweep the CXL-timed tier (media bins "
                         "dram/ssd-fast/ssd-slow x SR on/off) and emit "
                         "a cxl_tier section")
    ap.add_argument("--load", action="store_true",
                    help="also run the open-loop continuous-batching load "
                         "harness (seeded bursty arrivals at ~1.25x "
                         "capacity; continuous-vs-closed and FIFO-vs-"
                         "preempt sweeps) and emit a load section")
    ap.add_argument("--shard", action="store_true",
                    help="also run the shard axis (1-rank vs 2-/4-rank "
                         "sharded serving on identical zipf traffic, "
                         "gated on token identity and sublinear restore-"
                         "stall scaling) and emit a shard section; "
                         "forces 4 host devices when XLA_FLAGS doesn't "
                         "already")
    ap.add_argument("--placement", action="store_true",
                    help="also run the placement axis (learned GMM "
                         "placement vs the hotness counter on zipf-churn "
                         "traffic; learned cross-rank homing vs the hash "
                         "home on zipf-shared 2-rank traffic) and emit a "
                         "placement section plus the standalone "
                         "--placement-out artifact")
    ap.add_argument("--placement-out", default="BENCH_serve_placement.json")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        defaults = dict(n_slots=4, prompt_len=48, max_new=72, max_seq=128)
    else:
        defaults = dict(n_slots=8, prompt_len=256, max_new=128, max_seq=512)
    n_slots = args.slots or defaults["n_slots"]
    prompt_len = args.prompt_len or defaults["prompt_len"]
    max_new = args.max_new or defaults["max_new"]
    max_seq = args.max_seq or defaults["max_seq"]
    if prompt_len + max_new + 1 >= max_seq:
        ap.error("prompt_len + max_new must fit max_seq (steady decode "
                 "window would hit the position bound)")

    import jax
    from repro.launch.mesh import make_host_mesh

    cfg, rc, params = _build(args.arch, args.seed, args.vocab, args.dtype)
    kw = dict(n_slots=n_slots, max_seq=max_seq, prompt_len=prompt_len,
              max_new=max_new, n_requests=n_slots,
              prefill_chunk=args.prefill_chunk,
              temperature=args.temperature, seed=args.seed,
              repeats=args.repeats)
    with jax.set_mesh(make_host_mesh()):
        pair = bench_pair(params, cfg, rc, **kw)
        cxl_tier = bench_cxl_tier(
            params, cfg, rc, n_slots=n_slots, max_seq=max_seq,
            prompt_len=prompt_len, max_new=min(max_new, 16),
            prefill_chunk=args.prefill_chunk, seed=args.seed) \
            if args.cxl_tier else None
        if cxl_tier is not None:
            cxl_tier["kv_quant"] = bench_kv_quant(
                arch=args.arch, vocab=args.vocab, n_slots=n_slots,
                max_seq=max_seq, prompt_len=prompt_len,
                max_new=min(max_new, 16),
                prefill_chunk=args.prefill_chunk, seed=args.seed)
        load = bench_load(params, cfg, rc, prefill_chunk=8,
                          seed=args.seed, smoke=bool(args.smoke)) \
            if args.load else None
        if load is not None:
            load["fault"] = bench_fault(
                prefill_chunk=8, seed=args.seed, smoke=bool(args.smoke),
                vocab=args.vocab, dtype=args.dtype)
    # outside the host-mesh context: the sharded runs build their own
    # (1, N) meshes; only the 1-rank baseline activates the host mesh
    shard = bench_shard(arch=args.arch, vocab=args.vocab,
                        dtype=args.dtype, seed=args.seed,
                        smoke=bool(args.smoke)) if args.shard else None
    placement = bench_placement(seed=args.seed, smoke=bool(args.smoke)) \
        if args.placement else None
    legacy = pair["legacy_host_path"]
    device = pair["device_resident"]

    speedup = {
        "prefill": round(device["prefill_tok_s"]
                         / max(legacy["prefill_tok_s"], 1e-9), 2),
        "decode": round(device["decode_tok_s"]
                        / max(legacy["decode_tok_s"], 1e-9), 2),
    }
    acceptance = {
        "prefill_ge_5x": speedup["prefill"] >= 5.0,
        "decode_ge_2x": speedup["decode"] >= 2.0,
        "prefix_restore_zero_prefill":
            device["resubmit_prefill_dispatches"] == 0
            and device["prefix_hits"] >= 1,
    }
    out = {
        "bench": "serve",
        "arch": args.arch,
        "config": {"n_slots": n_slots, "prompt_len": prompt_len,
                   "max_new_tokens": max_new, "max_seq": max_seq,
                   "prefill_chunk": args.prefill_chunk,
                   "vocab_size": cfg.vocab_size, "dtype": cfg.dtype,
                   "temperature": args.temperature, "seed": args.seed,
                   "smoke": bool(args.smoke),
                   "backend": jax.default_backend(),
                   "jax": jax.__version__},
        "legacy_host_path": legacy,
        "device_resident": device,
        "speedup": speedup,
        "acceptance": acceptance,
    }
    if cxl_tier is not None:
        out["cxl_tier"] = cxl_tier
    if load is not None:
        out["load"] = load
    if shard is not None:
        out["shard"] = shard
    if placement is not None:
        out["placement"] = placement
    if _REPLAY_GATES:
        out["replay_gates"] = _REPLAY_GATES
    schema_drift = check_schema(out)
    if schema_drift:
        print("FAIL: BENCH_serve.json schema drifted from "
              "serve_bench.SCHEMA_KEYS:\n  " + "\n  ".join(schema_drift),
              file=sys.stderr)
        return 1
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    if placement is not None:
        # the placement gates also ship standalone (CI extracts/uploads
        # this artifact and fails the job on any acceptance violation)
        with open(args.placement_out, "w") as f:
            json.dump({"bench": "serve_placement", **placement},
                      f, indent=2)
    summary = {"speedup": speedup, "acceptance": acceptance,
               "out": args.out}
    if cxl_tier is not None:
        summary["cxl_tier_acceptance"] = cxl_tier["acceptance"]
        summary["cxl_tier_restore_stall_ns_per_restore"] = {
            b: {k: v["restore_stall_ns_per_restore"]
                for k, v in per.items()}
            for b, per in cxl_tier["media_bins"].items()}
        summary["cxl_tier_topology_stall_ns"] = {
            t: per["sr_on"]["restore_stall_ns_total"]
            for t, per in cxl_tier["topology"].items()}
        summary["cxl_tier_scheduler"] = {
            "restore_stall_ns": {
                m: s["restore_stall_ns_total"]
                for m, s in cxl_tier["scheduler"]["restore"].items()},
            "pressure_req_per_sim_s": {
                m: s["req_per_sim_s"]
                for m, s in cxl_tier["scheduler"]["pressure"].items()}}
        kvq = cxl_tier["kv_quant"]
        summary["kv_quant_acceptance"] = kvq["acceptance"]
        summary["kv_quant_restore_stall_ns"] = {
            m: s["restore_stall_ns_total"]
            for m, s in kvq["modes"].items()}
        summary["kv_quant_tier_bytes"] = {
            m: s["read_bytes"] + s["write_bytes"]
            for m, s in kvq["modes"].items()}
        summary["kv_quant_token_match_fraction"] = \
            kvq["tokens"]["match_fraction"]
    if load is not None:
        summary["load_acceptance"] = load["acceptance"]
        summary["load_goodput_req_s"] = {
            "closed": load["batching"]["closed"]["goodput_req_s"],
            "continuous": load["batching"]["continuous"]["goodput_req_s"],
            "preempt_swap":
                load["scheduling"]["preempt_swap"]["goodput_req_s"]}
        summary["load_ttft_ms_p99"] = {
            "closed": load["batching"]["closed"]["ttft_ms_p99"],
            "continuous": load["batching"]["continuous"]["ttft_ms_p99"],
            "preempt_swap":
                load["scheduling"]["preempt_swap"]["ttft_ms_p99"]}
        fault = load["fault"]
        summary["fault_acceptance"] = fault["acceptance"]
        summary["fault_goodput_req_s"] = {
            arch: {m: per[m]["goodput_req_s"] for m in per}
            for arch, per in fault["fleet"].items()}
        summary["fault_recoveries"] = {
            arch: per["faulted"]["recoveries"]
            for arch, per in fault["fleet"].items()}
    if placement is not None:
        summary["placement_acceptance"] = placement["acceptance"]
        summary["placement_churn_stall_ns"] = {
            m: s_["restore_stall_ns_total"]
            for m, s_ in placement["churn"].items()}
        summary["placement_shared_stall_ns"] = {
            m: s_["restore_stall_ns_total"]
            for m, s_ in placement["shared"].items()}
        summary["placement_shared_peer_bytes"] = {
            m: s_["peer_bytes"] for m, s_ in placement["shared"].items()}
    if shard is not None:
        summary["shard_acceptance"] = shard["acceptance"]
        summary["shard_restore_stall_ns"] = {
            m: s["restore_stall_ns_total"]
            for m, s in shard["ranks"].items()}
        summary["shard_stall_ratio_vs_1rank"] = {
            m: s["stall_ratio_vs_1rank"]
            for m, s in shard["ranks"].items()}
        summary["shard_token_identity"] = {
            m: s["token_identity_vs_1rank"]
            for m, s in shard["ranks"].items()}
    print(json.dumps(summary, indent=2))
    if not acceptance["prefix_restore_zero_prefill"]:
        print("FAIL: resubmitted rid was not served via prefix restore",
              file=sys.stderr)
        return 1
    if cxl_tier is not None and not all(cxl_tier["acceptance"].values()):
        print("FAIL: cxl_tier acceptance "
              f"{cxl_tier['acceptance']}", file=sys.stderr)
        return 1
    if cxl_tier is not None \
            and not all(cxl_tier["kv_quant"]["acceptance"].values()):
        print("FAIL: kv_quant acceptance "
              f"{cxl_tier['kv_quant']['acceptance']}", file=sys.stderr)
        return 1
    if load is not None and not all(load["acceptance"].values()):
        print(f"FAIL: load acceptance {load['acceptance']}",
              file=sys.stderr)
        return 1
    if load is not None and "fault" in load \
            and not all(load["fault"]["acceptance"].values()):
        print("FAIL: fault acceptance "
              f"{load['fault']['acceptance']}", file=sys.stderr)
        return 1
    if shard is not None and not all(shard["acceptance"].values()):
        print(f"FAIL: shard acceptance {shard['acceptance']}",
              file=sys.stderr)
        return 1
    if placement is not None and not all(placement["acceptance"].values()):
        print(f"FAIL: placement acceptance {placement['acceptance']}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
