"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

For each (arch x shape) cell on the single-pod mesh:
  compute term    = corrected_FLOPs_per_device / peak_FLOP/s
  memory term     = corrected_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI_bw
plus MODEL_FLOPS = (6 or 2) * N_active * tokens and the useful-compute
ratio. The dominant term is the bottleneck the perf loop iterates on.

Artifacts come from ``python -m repro.launch.dryrun --cost``; variants
written with ``--tag`` land in the same directory and can be compared
with ``--tag``.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs.base import HBM_PER_CHIP, HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(mesh: str = "sp", tag: Optional[str] = None) -> List[Dict]:
    cells = []
    for f in sorted(ART.glob(f"*__{mesh}{'__' + tag if tag else ''}.json")):
        d = json.loads(f.read_text())
        if tag is None and len(f.stem.split("__")) != 3:
            continue
        cells.append(d)
    return cells


def terms(cell: Dict) -> Optional[Dict]:
    """The three roofline terms (seconds/step/device) for one cell."""
    if cell.get("status") != "ok":
        return None
    cost = cell.get("corrected") or cell.get("module")
    coll = cost["collective_bytes"]
    coll_b = sum(v for k, v in coll.items() if k != "count")
    t_comp = cost["flops"] / PEAK_FLOPS_BF16
    t_mem = cost["bytes_accessed"] / HBM_BW
    t_coll = coll_b / ICI_BW
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    n_dev = cell["n_devices"]
    model_flops = cell.get("model_flops") or 0.0
    hlo_total = cost["flops"] * n_dev
    bound = max(t_comp, t_mem, t_coll)
    # roofline fraction: useful model FLOP/s achieved at the bound vs peak
    frac = (model_flops / n_dev / PEAK_FLOPS_BF16) / bound if bound else 0.0
    mem = cell.get("memory_analysis") or {}
    fits = None
    if mem.get("temp_size_in_bytes") is not None:
        resident = (mem.get("argument_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0))
        fits = resident <= HBM_PER_CHIP
    return {"arch": cell["arch"], "shape": cell["shape"],
            "kind": cell.get("kind"),
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dominant, "bound_s": bound,
            "roofline_frac": frac,
            "useful_ratio": model_flops / hlo_total if hlo_total else 0.0,
            "model_flops": model_flops, "fits_hbm": fits,
            "corrected": bool(cell.get("corrected"))}


def table(mesh: str = "sp", tag: Optional[str] = None) -> List[Dict]:
    rows = [t for c in load_cells(mesh, tag) if (t := terms(c))]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def print_table(rows: List[Dict]) -> None:
    hdr = (f"{'arch':24s} {'shape':11s} {'comp_ms':>8s} {'mem_ms':>8s} "
           f"{'coll_ms':>8s} {'dominant':>10s} {'roofl%':>7s} "
           f"{'useful':>7s} {'fits':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:11s} "
              f"{r['compute_s']*1e3:8.2f} {r['memory_s']*1e3:8.2f} "
              f"{r['collective_s']*1e3:8.2f} {r['dominant']:>10s} "
              f"{100*r['roofline_frac']:6.1f}% "
              f"{r['useful_ratio']:7.3f} "
              f"{str(r['fits_hbm'])[:5]:>5s}")


def pick_hillclimb_cells(rows: List[Dict]) -> Dict[str, Dict]:
    """worst roofline fraction, most collective-bound, most representative
    (the MoE train cell — the paper's pooled-expansion showcase)."""
    trainable = [r for r in rows if r["kind"] == "train"]
    worst = min(rows, key=lambda r: r["roofline_frac"])
    coll = max(rows, key=lambda r: (r["collective_s"]
                                    / max(r["bound_s"], 1e-12)))
    rep = next((r for r in trainable
                if r["arch"] == "qwen3-moe-235b-a22b"), trainable[0]
               if trainable else rows[0])
    return {"worst_fraction": worst, "most_collective": coll,
            "representative": rep}


def main() -> None:
    rows = table()
    print_table(rows)
    picks = pick_hillclimb_cells(rows)
    print("\nhillclimb cells:")
    for why, r in picks.items():
        print(f"  {why:16s}: {r['arch']} x {r['shape']} "
              f"(dominant={r['dominant']}, roofline={r['roofline_frac']:.1%})")


if __name__ == "__main__":
    main()
