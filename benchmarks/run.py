"""Benchmark aggregator: one section per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig9a roofline
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

SECTIONS = ["table1b", "fig9a", "fig9b", "fig9c", "fig9d", "fig9e",
            "roofline", "train_bench"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    todo = args.only or SECTIONS
    results = {}
    art = Path(__file__).resolve().parents[1] / "artifacts"
    art.mkdir(exist_ok=True)

    from benchmarks import paper_figs
    for name in ("table1b", "fig9a", "fig9b", "fig9c", "fig9d", "fig9e"):
        if name not in todo:
            continue
        t0 = time.time()
        print(f"===== {name} " + "=" * 50)
        results[name] = getattr(paper_figs, name)()
        print(f"      ({time.time()-t0:.1f}s)")

    if "roofline" in todo:
        print("===== roofline " + "=" * 47)
        from benchmarks import roofline
        rows = roofline.table()
        roofline.print_table(rows)
        if rows:
            picks = roofline.pick_hillclimb_cells(rows)
            print("hillclimb cells:")
            for why, r in picks.items():
                print(f"  {why:16s}: {r['arch']} x {r['shape']} "
                      f"(dominant={r['dominant']}, "
                      f"roofline={r['roofline_frac']:.1%})")
            results["roofline"] = rows

    if "train_bench" in todo:
        print("===== train_bench " + "=" * 44)
        from benchmarks import train_step_bench
        tb = train_step_bench.bench()
        results["train_bench"] = {str(k): v for k, v in tb.items()}

    (art / "bench_results.json").write_text(
        json.dumps(results, indent=1, default=str))
    print(f"\n[benchmarks] wrote {art/'bench_results.json'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
