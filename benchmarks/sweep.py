"""CXL scenario-sweep benchmark — produces the BENCH_sim.json artifact.

Replays a declarative scenario matrix (config x workload x media x GPU
queue shape) on the vectorized engine, verifies it against the scalar
reference oracle, and writes a perf/accuracy artifact:

  PYTHONPATH=src python benchmarks/sweep.py --smoke --out BENCH_sim.json
  PYTHONPATH=src python benchmarks/sweep.py --set fig9 --ops 12000

Sets:
  smoke  — small CI matrix covering all 8 configs, 4 media classes, a
           scaled media bin and a narrow queue shape (~30 scenarios)
  fig9   — the paper's Figure-9 evaluation set (~100 scenarios)
  full   — fig9 plus the MLP/store-queue-depth axis
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.sim import sweep as sw  # noqa: E402
from repro.sim.workloads import ORDER  # noqa: E402


def build_matrix(name: str, n_ops: int):
    if name == "smoke":
        return sw.smoke_matrix(n_ops)
    if name == "fig9":
        return sw.fig9_matrix(n_ops)
    if name == "full":
        m = sw.fig9_matrix(n_ops)
        m += sw.matrix(("cxl-sr", "cxl-ds"), ("vadd", "bfs"), ("znand",),
                       n_ops=n_ops, mlps=(16, 64), store_qs=(4, 16))
        m += sw.matrix(("gds",), ORDER, ("znand", "nand"), n_ops=n_ops)
        return list(dict.fromkeys(m))
    raise SystemExit(f"unknown scenario set: {name}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--set", default="fig9",
                    choices=("smoke", "fig9", "full"),
                    help="scenario matrix to replay")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for --set smoke --ops 4000")
    ap.add_argument("--ops", type=int, default=None,
                    help="ops per trace (default 12000; smoke 4000)")
    ap.add_argument("--out", default="BENCH_sim.json")
    ap.add_argument("--no-compare", action="store_true",
                    help="skip the scalar-oracle replay (perf only)")
    ap.add_argument("--equivalence-sample", type=int, default=None,
                    help="verify only the first N scenarios vs the oracle")
    args = ap.parse_args()

    set_name = "smoke" if args.smoke else args.set
    n_ops = args.ops or (4000 if set_name == "smoke" else 12000)
    scenarios = build_matrix(set_name, n_ops)
    print(f"[sweep] set={set_name} scenarios={len(scenarios)} "
          f"n_ops={n_ops}")

    payload = sw.bench(scenarios, compare=not args.no_compare,
                       equivalence_sample=args.equivalence_sample)
    # async page-trace closed form vs the scalar oracle (every set,
    # including --smoke): rel-err and wall-time-speedup gated
    payload["page_trace"] = sw.page_trace_bench()
    payload["matrix"]["set"] = set_name
    payload["matrix"]["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                                   time.gmtime())

    perf, acc = payload["perf"], payload["accuracy"]
    print(f"[sweep] vector: {perf['vector_s']}s"
          + (f"  fanout({perf['fanout_workers']}): "
             f"{perf['vector_fanout_s']}s"
             if perf["vector_fanout_s"] is not None else ""))
    if perf["scalar_s"] is not None:
        print(f"[sweep] scalar oracle: {perf['scalar_s']}s"
              + (f"  engine speedup: {perf['engine_speedup']}x"
                 if perf["engine_speedup"] else " (sampled)"))
    if acc["compared"]:
        print(f"[sweep] equivalence: {acc['compared']} scenarios, "
              f"max rel err {acc['max_rel_err']:.2e} "
              f"(tol {acc['tolerance']}) -> "
              f"{'PASS' if acc['pass'] else 'FAIL'}")
    pt = payload["page_trace"]
    for name, s in pt["scenarios"].items():
        print(f"[sweep] page-trace {name}: max rel err "
              f"{s['max_rel_err']:.2e}, closed form {s['speedup']}x "
              f"vs oracle -> {'PASS' if s['pass'] else 'FAIL'}")

    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"[sweep] wrote {args.out}")

    return 0 if (acc["pass"] is not False and pt["pass"]) else 1


if __name__ == "__main__":
    raise SystemExit(main())
