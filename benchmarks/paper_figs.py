"""Reproduce the paper's Figure 9 (a-e) and Table 1b with the simulator.

Each function regenerates one figure's numbers and prints them next to
the paper's reported values. The returned dicts feed EXPERIMENTS.md
§Paper-validation.

Runs on the vectorized sweep engine (repro.sim.vector) by default — set
REPRO_SIM_ENGINE=scalar to replay on the scalar reference oracle instead
(the two agree within 1%; see benchmarks/sweep.py).
"""
from __future__ import annotations

import os
from typing import Dict

import numpy as np

from repro.sim import run_vectorized
from repro.sim.engine import run as run_scalar
from repro.sim.workloads import ORDER, TABLE_1B

run = (run_scalar if os.environ.get("REPRO_SIM_ENGINE") == "scalar"
       else run_vectorized)
N_OPS = int(os.environ.get("REPRO_SIM_OPS", "12000"))
CATS = {"compute": ["rsum", "stencil", "sort"],
        "load": ["gemm", "vadd", "saxpy", "conv3", "path"],
        "store": ["cfd", "gauss", "bfs"],
        "real": ["gnn", "mri"]}

_cache: Dict = {}


def _run(cfg, w, m):
    key = (cfg, w, m)
    if key not in _cache:
        _cache[key] = run(cfg, w, m, n_ops=N_OPS)
    return _cache[key]


def fig9a() -> Dict:
    """DRAM expander: UVM / CXL vs GPU-DRAM, normalized exec time."""
    rows = {}
    for w in ORDER:
        base = _run("gpu-dram", w, "dram").exec_ns
        rows[w] = {"uvm": _run("uvm", w, "dram").exec_ns / base,
                   "cxl": _run("cxl", w, "dram").exec_ns / base}
    uvm_mean = float(np.mean([r["uvm"] for r in rows.values()]))
    cxl_mean = float(np.mean([r["cxl"] for r in rows.values()]))
    out = {"rows": rows, "uvm_mean": uvm_mean,
           "uvm_over_cxl": uvm_mean / cxl_mean,
           "paper": {"uvm_mean": 52.7, "uvm_over_cxl": 44.2,
                     "cxl_gap_pct": {"compute": 2.3, "load": 19.7,
                                     "store": 6.8}},
           "cxl_gap_pct": {c: 100 * (np.mean([rows[w]["cxl"]
                                              for w in names]) - 1)
                           for c, names in CATS.items() if c != "real"}}
    print("[fig9a] UVM mean %.1fx (paper 52.7) | UVM/CXL %.1fx (44.2)"
          % (out["uvm_mean"], out["uvm_over_cxl"]))
    for c, v in out["cxl_gap_pct"].items():
        print("        CXL-vs-ideal %s: %+.1f%% (paper +%.1f%%)"
              % (c, v, out["paper"]["cxl_gap_pct"][c]))
    return out


def fig9b() -> Dict:
    """SSD (Z-NAND) expander: CXL / CXL-SR / CXL-DS."""
    rows = {}
    for w in ORDER:
        c = _run("cxl", w, "znand").exec_ns
        s = _run("cxl-sr", w, "znand").exec_ns
        d = _run("cxl-ds", w, "znand").exec_ns
        rows[w] = {"sr_gain": c / s, "ds_over_sr": s / d}
    sr_mean = float(np.mean([r["sr_gain"] for r in rows.values()]))
    ds = {c: 100 * (np.mean([rows[w]["ds_over_sr"] for w in names]) - 1)
          for c, names in CATS.items() if c != "real"}
    out = {"rows": rows, "sr_mean": sr_mean, "ds_over_sr_pct": ds,
           "paper": {"sr_mean": 7.4,
                     "ds_over_sr_pct": {"compute": 20.9, "load": 8.7,
                                        "store": 62.8}}}
    print("[fig9b] SR-over-CXL mean %.2fx (paper 7.4x)" % sr_mean)
    for c, v in ds.items():
        print("        DS-over-SR %s: %+.1f%% (paper +%.1f%%)"
              % (c, v, out["paper"]["ds_over_sr_pct"][c]))
    return out


def fig9c() -> Dict:
    """Backend-media sweep: SR/DS gains on Optane / Z-NAND / NAND."""
    out = {"paper": {"sr_gain_by_media": {"optane": 7.1, "znand": 8.8,
                                          "nand": 10.1},
                     "bfs_ds_up_to": 4.0}}
    for med in ("optane", "znand", "nand"):
        gains = {}
        for w in ("vadd", "path", "bfs"):
            c = _run("cxl", w, med).exec_ns
            gains[w] = {"sr": c / _run("cxl-sr", w, med).exec_ns,
                        "ds": c / _run("cxl-ds", w, med).exec_ns}
        out[med] = gains
        print("[fig9c] %-6s SR gains vadd/path/bfs: %.1f/%.1f/%.1fx  "
              "DS: %.1f/%.1f/%.1fx" % (
                  med, gains["vadd"]["sr"], gains["path"]["sr"],
                  gains["bfs"]["sr"], gains["vadd"]["ds"],
                  gains["path"]["ds"], gains["bfs"]["ds"]))
    return out


def fig9d() -> Dict:
    """SR ablation ladder: CXL -> NAIVE -> DYN -> SR hit rates (Z-NAND)."""
    paper = {"Seq": (47.4, 88.4, 99.0, 99.0),
             "Around": (31.2, 56.0, 57.4, 75.8),
             "Rand": (10.0, 32.1, 34.0, 34.0)}
    reps = {"Seq": "vadd", "Around": "sort", "Rand": "path"}
    out = {"paper": paper}
    for pat, w in reps.items():
        hits = tuple(100 * _run(c, w, "znand").ep_hit_rate
                     for c in ("cxl", "cxl-naive", "cxl-dyn", "cxl-sr"))
        speeds = tuple(_run("cxl", w, "znand").exec_ns
                       / _run(c, w, "znand").exec_ns
                       for c in ("cxl-naive", "cxl-dyn", "cxl-sr"))
        out[pat] = {"hits": hits, "speedups": speeds}
        print("[fig9d] %-6s hits %s (paper %s)  speedups "
              "naive/dyn/sr %.2f/%.2f/%.2fx"
              % (pat, "/".join(f"{h:.0f}" for h in hits),
                 "/".join(f"{h:.0f}" for h in paper[pat]), *speeds))
    return out


def fig9e() -> Dict:
    """DS time series under GC: load/store latency, CXL-SR vs CXL-DS."""
    out = {}
    for cfg in ("cxl-sr", "cxl-ds"):
        r = run(cfg, "bfs", "znand", n_ops=N_OPS, record_samples=True)
        lat = np.array([(t, l, k) for t, l, k in r.samples])
        loads = lat[lat[:, 2] == 1][:, 1]
        stores = lat[lat[:, 2] == 2][:, 1]
        out[cfg] = {
            "p50_load_us": float(np.percentile(loads, 50)) / 1e3,
            "p99_load_us": float(np.percentile(loads, 99)) / 1e3,
            "p50_store_us": float(np.percentile(stores, 50)) / 1e3,
            "p99_store_us": float(np.percentile(stores, 99)) / 1e3,
            "exec_ms": r.exec_ns / 1e6}
        print("[fig9e] %-6s p50/p99 load %.1f/%.1f us  store %.1f/%.1f us"
              % (cfg, out[cfg]["p50_load_us"], out[cfg]["p99_load_us"],
                 out[cfg]["p50_store_us"], out[cfg]["p99_store_us"]))
    # DS must collapse the store tail
    assert out["cxl-ds"]["p99_store_us"] <= out["cxl-sr"]["p99_store_us"]
    return out


def table1b() -> Dict:
    """Workload characterization: generated traces vs Table 1b."""
    out = {}
    from repro.sim import workloads as wl
    for name in ORDER:
        tr = wl.generate(name, 30_000)
        kinds = tr["kind"]
        comp = float((kinds == 0).mean())
        load = float((kinds == 1).sum()) / max(int((kinds > 0).sum()), 1)
        spec = TABLE_1B[name]
        out[name] = {"compute": comp, "load": load,
                     "paper": (spec.compute_ratio, spec.load_ratio)}
    print("[table1b] max |compute_ratio err| = %.3f, |load_ratio err| = %.3f"
          % (max(abs(v["compute"] - v["paper"][0]) for v in out.values()),
             max(abs(v["load"] - v["paper"][1]) for v in out.values())))
    return out
