"""Deterministic sharded token pipeline with background prefetch.

Design constraints from the runtime:
  * determinism: batch t is a pure function of (seed, step) — restart or
    elastic reshard replays the exact stream from the checkpointed step,
    with no data-order drift between replicas;
  * sharding: each process materializes only its addressable slice of the
    global batch (jax.make_array_from_process_local_data);
  * prefetch: a background thread keeps `depth` batches ahead, so host
    input never sits on the step's critical path (the data-loading face of
    the paper's speculative read).

Sources: SyntheticLM (seeded zipfian tokens — default for examples/tests)
or a binary token file (np.memmap).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

import jax


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    n_codebooks: int = 0          # audio family
    vision_tokens: int = 0        # vlm family (stub embeddings)
    d_model: int = 0
    token_file: Optional[str] = None


class SyntheticLM:
    """Seeded zipf-ish token stream; batch t is a pure function of t."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self._cdf = np.cumsum(probs / probs.sum())

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        shape = (cfg.global_batch, cfg.seq_len + 1)
        if cfg.n_codebooks:
            shape = (cfg.global_batch, cfg.n_codebooks, cfg.seq_len + 1)
        u = rng.random(shape)
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        out = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        if cfg.vision_tokens:
            out["vision_embeds"] = rng.standard_normal(
                (cfg.global_batch, cfg.vision_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
        return out


class FileLM:
    """Contiguous windows over a binary int32 token file (np.memmap)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(cfg.token_file, dtype=np.int32, mode="r")

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        span = cfg.seq_len + 1
        n_windows = len(self.tokens) // span
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        idx = rng.integers(0, n_windows, cfg.global_batch)
        rows = np.stack([self.tokens[i * span:(i + 1) * span] for i in idx])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


class Pipeline:
    """Background-prefetching iterator over a deterministic source."""

    def __init__(self, cfg: DataConfig, *, start_step: int = 0,
                 depth: int = 2, shardings=None):
        self.cfg = cfg
        self.source = FileLM(cfg) if cfg.token_file else SyntheticLM(cfg)
        self.step = start_step
        self.depth = depth
        self.shardings = shardings
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _device_put(self, batch: Dict[str, np.ndarray]):
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, self.shardings[k])
                for k, v in batch.items()}

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, self._device_put(batch)

    def __iter__(self) -> Iterator:
        return self

    def state(self) -> Dict:
        """Checkpointable position (next step to be consumed)."""
        return {"step": self.step}

    def close(self):
        self._stop.set()
