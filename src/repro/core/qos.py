"""QoS telemetry — the DevLoad state machine and address-window control.

The CXL flit's DevLoad field (2 bits) classifies endpoint load into four
states; the paper's queue logic uses it to modulate speculative-read
granularity/volume and to gate deterministic-store flushes. This module is
shared by (a) the discrete-event simulator (cycle-level fidelity) and (b)
the JAX runtime, where the controller observes per-step telemetry and picks
among pre-compiled step variants between steps (XLA programs are static, so
adaptation is inter-step — DESIGN.md §4.4).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Sequence, Tuple


class DevLoad(enum.IntEnum):
    """Two-bit endpoint load state, CXL r3.1 QoS telemetry."""

    LIGHT = 0      # "ll" — spare bandwidth: raise SR granularity
    OPTIMAL = 1    # "ol" — at capacity: hold
    MODERATE = 2   # "mo" — congested: lower granularity, pause DS flushes
    SEVERE = 3     # "so" — saturated: halt SR until LIGHT returns


# SR request granularity ladder (bytes) — MemSpecRd aggregates 1..4 memory
# requests via the 2 repurposed LSBs: 256B base unit up to 1KB.
SR_GRANULARITIES = (256, 512, 768, 1024)


@dataclasses.dataclass
class QoSController:
    """Maps DevLoad telemetry to SR/DS control decisions.

    Used verbatim by the simulator; the training/serving runtime feeds it
    synthesized telemetry (queue occupancy = staging-ring fill, service
    latency = step-time EWMA vs roofline expectation).
    """

    granularity: int = 512            # current MemSpecRd bytes
    sr_halted: bool = False
    flush_enabled: bool = True
    # runtime-mode knobs (layer-level analogues)
    prefetch_depth: int = 1
    max_prefetch_depth: int = 2

    ewma: float = 0.0
    ewma_alpha: float = 0.25
    _last: DevLoad = DevLoad.OPTIMAL

    # ------------------------------------------------------------ classify
    def classify(self, occupancy: float, service_ratio: float) -> DevLoad:
        """occupancy: queue/ring fill in [0,1]; service_ratio: observed
        latency / expected latency (>=1 means slower than roofline)."""
        self.ewma = (1 - self.ewma_alpha) * self.ewma \
            + self.ewma_alpha * max(occupancy, (service_ratio - 1.0))
        if occupancy >= 0.95:
            return DevLoad.SEVERE
        if self.ewma > 0.60:
            return DevLoad.MODERATE
        if self.ewma > 0.25:
            return DevLoad.OPTIMAL
        return DevLoad.LIGHT

    # -------------------------------------------------------------- update
    def update(self, devload: DevLoad) -> None:
        """Paper's control actions (OPTIMIZATION section)."""
        self._last = devload
        if devload == DevLoad.LIGHT:
            self.sr_halted = False
            self.flush_enabled = True
            self._step_granularity(+1)
            self.prefetch_depth = min(self.prefetch_depth + 1,
                                      self.max_prefetch_depth)
        elif devload == DevLoad.OPTIMAL:
            self.flush_enabled = True
        elif devload == DevLoad.MODERATE:
            self._step_granularity(-1)
            self.flush_enabled = False   # divert writes to staging (Fig. 8)
            self.prefetch_depth = max(self.prefetch_depth - 1, 1)
        else:  # SEVERE
            self.sr_halted = True
            self.flush_enabled = False
            self.granularity = SR_GRANULARITIES[0]
            self.prefetch_depth = 0

    def _step_granularity(self, d: int) -> None:
        i = SR_GRANULARITIES.index(self.granularity)
        self.granularity = SR_GRANULARITIES[
            max(0, min(len(SR_GRANULARITIES) - 1, i + d))]

    @property
    def sr_enabled(self) -> bool:
        """True while the SR engine may issue MemSpecRd (not halted)."""
        return not self.sr_halted

    @property
    def last_devload(self) -> DevLoad:
        """Most recent DevLoad sample observed (telemetry read-back)."""
        return self._last


# ---------------------------------------------------------------------------
# Address-window control (paper Fig. 7)
# ---------------------------------------------------------------------------

MEM_REQ_BYTES = 64       # CXL.mem request granularity
SR_OFFSET_UNIT = 256     # MemSpecRd offset unit


def address_window(addr: int, granularity: int,
                   memory_queue: Sequence[int],
                   sr_queue: Sequence[int]) -> Tuple[int, int]:
    """Compute the SR address window for a request at ``addr``.

    Initial window = [addr - g, addr + g). Each *past* request (memory
    queue) shifts the start up by 64B — history that already covered low
    addresses; each *future* request (SR queue) shifts the end down by 64B —
    demand that SR requests will cover anyway. The result is rounded to the
    256B offset unit. Returns (start, end) with end-start == granularity.
    """
    start = addr - granularity
    end = addr + granularity
    start += MEM_REQ_BYTES * len(memory_queue)
    end -= MEM_REQ_BYTES * len(sr_queue)
    start = max(start, 0)
    end = max(end, start + SR_OFFSET_UNIT)
    # window length is capped at the current granularity
    if end - start > granularity:
        # keep the side the queues weighted toward the access point
        if addr - start > end - addr:
            start = end - granularity
        else:
            end = start + granularity
    # finalize: round the shifted range to the 256B offset unit (window
    # length stays within the MemSpecRd granularity, itself a multiple of
    # the offset unit)
    start = (max(start, 0) // SR_OFFSET_UNIT) * SR_OFFSET_UNIT
    length = ((end - start + SR_OFFSET_UNIT - 1)
              // SR_OFFSET_UNIT) * SR_OFFSET_UNIT
    g_cap = max((granularity // SR_OFFSET_UNIT) * SR_OFFSET_UNIT,
                SR_OFFSET_UNIT)
    return start, start + max(min(length, g_cap), SR_OFFSET_UNIT)


# ---------------------------------------------------------------------------
# Runtime telemetry record (training/serving loops)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepTelemetry:
    """One training/serving step's observed load (wall times in seconds;
    ``staging_occupancy`` is the DS ring fill fraction in [0, 1])."""

    step: int
    wall_time_s: float
    expected_time_s: float      # roofline expectation for the variant
    staging_occupancy: float    # DS ring fill fraction
    devload: Optional[DevLoad] = None


class RuntimeQoS:
    """Between-step adaptation loop: telemetry -> DevLoad -> variant choice.

    The train/serve drivers pre-compile step variants for (prefetch_depth,
    granularity) combinations; this picks the active one (DESIGN.md §4.4).
    """

    def __init__(self, variants: Sequence[Tuple[int, int]]):
        self.ctl = QoSController()
        self.variants = list(variants)  # [(depth, granularity), ...]
        self.history: List[StepTelemetry] = []

    def observe(self, t: StepTelemetry) -> Tuple[int, int]:
        """Fold one step's telemetry into the ladder; returns the
        (prefetch_depth, granularity) variant to run next."""
        ratio = (t.wall_time_s / t.expected_time_s
                 if t.expected_time_s > 0 else 1.0)
        dl = self.ctl.classify(t.staging_occupancy, ratio)
        t.devload = dl
        self.ctl.update(dl)
        self.history.append(t)
        return self.active_variant()

    def active_variant(self) -> Tuple[int, int]:
        """Pre-compiled (depth, granularity) variant closest to the
        controller's current prefetch depth."""
        depth = 0 if self.ctl.sr_halted else self.ctl.prefetch_depth
        best = min(self.variants,
                   key=lambda v: (abs(v[0] - depth),))
        return best
