"""Deterministic store — fire-and-forget writes with staged writeback.

Paper mechanism (Fig. 8): a store to a slow EP completes immediately by
writing concurrently to GPU memory (a reserved, stack-organized staging
region indexed from SRAM) and the EP; under tail latency (GC) the write is
diverted to the staging region only and flushed in the background; reads
consult the staging index first.

JAX realization (DESIGN.md §4.3):

* Training gradients: ``ds_grads`` pins gradient out-shardings to the pool
  (FSDP) spec so the backward emits **reduce-scatter** — each device
  completes its shard immediately and the full tensor is never
  materialized. Disabling DS yields the all-reduce-then-slice baseline used
  for the ablation.

* Host-tier writeback (optimizer states, KV pages): a ``StagingRing`` of
  bounded HBM slots written in-graph (dynamic_update_slice — the "stack"),
  flushed between steps by the host runtime only while the QoS state allows
  (DevLoad <= OPTIMAL). ``read_through`` serves reads from the ring first,
  exactly the paper's read path during GC windows.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qos import DevLoad, QoSController
from repro.parallel import sharding as shlib


# ---------------------------------------------------------------------------
# Gradient path (training)
# ---------------------------------------------------------------------------


def ds_grad_specs(param_specs: Any, enabled: bool) -> Any:
    """Shardings the backward must deliver gradients in.

    enabled  -> pool specs (reduce-scatter; deterministic store).
    disabled -> gathered specs (all-reduce of the full gradient; the
                baseline a conventional data-parallel step uses).
    """
    if enabled:
        return param_specs
    return shlib.gathered_specs(param_specs)


def apply_ds(grads: Any, param_specs: Any, enabled: bool = True) -> Any:
    """Constrain gradients to their DS placement inside the step."""
    return shlib.constrain(grads, ds_grad_specs(param_specs, enabled))


# ---------------------------------------------------------------------------
# Staging ring (serving / host-tier writeback)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RingState:
    """In-graph state: fixed slot buffer + metadata (all jnp arrays)."""

    slots: Any              # pytree, each leaf [n_slots, ...]
    keys: jnp.ndarray       # [n_slots] int32 logical address, -1 = empty
    head: jnp.ndarray       # scalar int32: next write position
    count: jnp.ndarray      # scalar int32: occupied slots


def ring_init(n_slots: int, item_shape: Any) -> RingState:
    """Fresh ring of ``n_slots`` zeroed slots shaped like ``item_shape``."""
    slots = jax.tree_util.tree_map(
        lambda s: jnp.zeros((n_slots,) + tuple(s.shape), s.dtype), item_shape)
    return RingState(slots=slots,
                     keys=jnp.full((n_slots,), -1, jnp.int32),
                     head=jnp.zeros((), jnp.int32),
                     count=jnp.zeros((), jnp.int32))


def ring_write(state: RingState, key: jnp.ndarray, item: Any) -> RingState:
    """Fire-and-forget store: O(1) write at head (stack push, Fig. 8 (2))."""
    i = state.head
    slots = jax.tree_util.tree_map(
        lambda buf, x: jax.lax.dynamic_update_index_in_dim(
            buf, x.astype(buf.dtype)[None] if x.ndim == buf.ndim - 1
            else x.astype(buf.dtype), i, axis=0),
        state.slots, item)
    n = state.keys.shape[0]
    return RingState(
        slots=slots,
        keys=state.keys.at[i].set(key.astype(jnp.int32)),
        head=jnp.mod(i + 1, n),
        count=jnp.minimum(state.count + 1, n))


def ring_lookup(state: RingState, key: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                             jnp.ndarray]:
    """Staging-index probe: (hit, slot_idx). Latest write wins."""
    matches = state.keys == key.astype(jnp.int32)
    n = state.keys.shape[0]
    # recency rank: distance behind head (smaller = newer)
    age = jnp.mod(state.head - 1 - jnp.arange(n), n)
    slot = jnp.argmin(jnp.where(matches, age, n + 1))
    return matches.any(), slot


def read_through(state: RingState, key: jnp.ndarray, backing: Any) -> Any:
    """Read path: staging ring first, else the backing (EP) value."""
    hit, slot = ring_lookup(state, key)
    return jax.tree_util.tree_map(
        lambda buf, b: jnp.where(
            hit, jax.lax.dynamic_index_in_dim(buf, slot, 0, keepdims=False
                                              ).astype(b.dtype), b),
        state.slots, backing)


def ring_occupancy(state: RingState) -> jnp.ndarray:
    """Ring fill fraction in [0, 1] (the QoS occupancy signal)."""
    return state.count.astype(jnp.float32) / state.keys.shape[0]


# ---------------------------------------------------------------------------
# Host-side flusher (between steps; the background drain of Fig. 8 (3))
# ---------------------------------------------------------------------------


class StagingFlusher:
    """Drains staged items to the backing tier between steps.

    The sink is a callable (e.g. checkpointer write, host-memory pool
    insert). Flushing is suppressed while DevLoad >= MODERATE, mirroring the
    controller's divert-on-congestion behaviour; suspended writes are kept
    (the ring keeps absorbing) and resumed when load drops — reads remain
    correct throughout because of ``read_through``.

    ``admit`` is the endpoint-side half of the same discipline: when the
    backing tier is a simulated CXL EP (``repro.core.tier.CxlTier``), the
    device pre-announces internal tasks / congestion through it and the
    flush window stays shut until the EP recovers (``deferred`` counts
    those windows); staged items keep absorbing meanwhile.
    """

    def __init__(self, sink: Callable[[int, Any], None],
                 qos: Optional[QoSController] = None,
                 admit: Optional[Callable[[], bool]] = None):
        self.sink = sink
        self.qos = qos or QoSController()
        self.admit = admit
        self.pending: List[Tuple[int, Any]] = []
        self.flushed = 0
        self.suppressed = 0
        self.deferred = 0

    def stage(self, key: int, value: Any) -> None:
        """Park one item for the next admitted flush window."""
        self.pending.append((key, value))

    def maybe_flush(self) -> int:
        """Drain pending items to the sink if QoS + admission allow;
        returns how many items were flushed (0 on a closed window)."""
        if not self.qos.flush_enabled:
            self.suppressed += 1
            return 0
        if self.pending and self.admit is not None and not self.admit():
            self.deferred += 1
            return 0
        n = len(self.pending)
        for key, value in self.pending:
            self.sink(key, value)
        self.pending.clear()
        self.flushed += n
        return n
