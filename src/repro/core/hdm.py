"""HDMStore — host-managed device memory over a parameter pytree.

The paper's HDM decoder maps each CXL root port's endpoint into one system
address space so compute units issue plain loads/stores against expanded
memory (DESIGN.md §4.1). Here the "address map" is a per-leaf *tier*
assignment plus the sharding that realizes it on the mesh:

  DEVICE : replicated across the data axis — always resident in local HBM.
  POOL   : FSDP-sharded across the data axis — the DRAM-EP expander. A layer
           is *materialized* (all-gathered) on use; the speculative-read
           pipeline issues that gather ahead of the consumer.
  HOST   : POOL sharding + pinned_host memory kind — the SSD-EP expander
           (TPU only; XLA:CPU cannot compile the placement custom-call).

`HDMStore` is deliberately thin: it owns *placement*, while the SR/DS modules
own *movement*. That split mirrors the paper (HDM decoder vs root-port queue
logic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel import sharding as shlib

DEVICE, POOL, HOST = "device", "pool", "host"


@dataclasses.dataclass
class HDMStore:
    """Tiered placement for a param (or optimizer-state) pytree."""

    mesh: Mesh
    tier: str = POOL                 # default tier for large leaves
    enable_host_tier: bool = False   # SSD-EP analogue; TPU only
    multi_pod_fsdp: bool = False     # ZeRO across pods as well

    # ------------------------------------------------------------- specs
    def specs(self, params_shape: Any) -> Any:
        """PartitionSpec tree for the resident (expanded) form."""
        return shlib.param_specs(params_shape, tier=self.tier,
                                 multi_pod_fsdp=self.multi_pod_fsdp)

    def gathered_specs(self, params_shape: Any) -> Any:
        """Specs after a speculative-read gather (FSDP axis stripped)."""
        return shlib.gathered_specs(self.specs(params_shape))

    def shardings(self, params_shape: Any) -> Any:
        """NamedShardings realizing the tier map on the mesh (HOST tier
        adds the pinned_host memory kind when enabled)."""
        mk = None
        if self.tier == HOST and self.enable_host_tier:
            mk = "pinned_host"
        return shlib.shardings_from_specs(self.mesh, self.specs(params_shape),
                                          memory_kind=mk)

    # --------------------------------------------------------- movement
    def materialize(self, layer_params: Any, layer_specs: Any) -> Any:
        """Gather one layer from the pool into the resident form.

        This is the load path of the HDM map: a sharding constraint that
        forces the FSDP axis to be gathered. The SR pipeline decides *when*
        this runs relative to compute (repro.core.speculative_read).
        """
        gathered = shlib.gathered_specs(layer_specs)
        return shlib.constrain(layer_params, gathered)

    def writeback(self, layer_params: Any, layer_specs: Any) -> Any:
        """Scatter (reduce-scatter for grads) back into pool placement —
        the deterministic-store path: shards complete immediately."""
        return shlib.constrain(layer_params, layer_specs)


def bytes_per_device(params_shape: Any, store: HDMStore) -> int:
    """Static estimate of resident bytes/device under the tier map."""
    specs = store.specs(params_shape)
    n_dev = store.mesh.devices.size
    mesh_sizes = dict(zip(store.mesh.axis_names, store.mesh.devices.shape))

    def leaf_bytes(leaf, spec):
        total = leaf.size * leaf.dtype.itemsize
        shard = 1
        for s in jax.tree_util.tree_leaves(tuple(spec)) if spec else []:
            if s in mesh_sizes:
                shard *= mesh_sizes[s]
        return total // max(shard, 1)

    leaves = jax.tree_util.tree_leaves(params_shape)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    return sum(leaf_bytes(l, s) for l, s in zip(leaves, spec_leaves))
