"""CXL-timed KV memory tier — serving page traffic through the simulator.

Until now the serving engine's host page tier (``HostPageStore`` + the
staging flusher) and the siliconized-controller simulator (``repro.sim``)
lived in separate worlds: the engine moved real KV pages with no latency
model, the simulator timed synthetic traces with no real traffic. This
module bridges them: a :class:`CxlTier` owns one simulated CXL endpoint
(media bin + internal DRAM cache) behind one root port and charges every
page movement the serving engine performs against it —

 * **flush** (retired pages -> cold tier): ``write_entry`` decomposes the
   entry into CXL.mem stores through the controller's deterministic-store
   path — fire-and-forget at GPU-memory speed, diverted to staging under
   congestion, exactly Fig. 8;
 * **restore** (prefix reuse): ``read_entry`` is the demand fetch the
   restored slot stalls on; ``speculative_read`` is the MemSpecRd stream
   the engine issues at lookup time so the EP's internal DRAM already
   holds the pages when the demand reads arrive (Fig. 6);
 * **admission**: ``admit_store`` gates the engine's QoS flusher on the
   endpoint's announced state (DevLoad ladder + pending internal tasks) —
   the divert-on-congestion discipline applied at page granularity.

The tier records every op it charges (``ops``/``op_ns``); replaying that
trace through ``repro.sim.engine.replay_page_trace`` from a fresh stream
must reproduce the charged latencies — the differential harness in
``tests/test_tier.py``. Addresses come from an append-only page-aligned
bump allocator: entry keys map to stable ranges, so a re-flushed entry
overwrites its previous range (warm EP cache) instead of migrating.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.sim.engine import (PAGE_ADVANCE, PAGE_PREFETCH, PAGE_READ,
                              PAGE_WRITE, PageStream)

# Serving media bins -> simulator media parts (Table 1a). "ssd-fast" is the
# Z-NAND part, "ssd-slow" commodity TLC NAND; any resolve_media spec
# ("optane", "znand@2", ...) is also accepted verbatim.
MEDIA_BINS = {"dram": "dram", "ssd-fast": "znand", "ssd-slow": "nand"}


@dataclasses.dataclass(frozen=True)
class TierConfig:
    media: str = "ssd-fast"          # bin name or raw media spec
    sr_enabled: bool = True          # speculative read (MemSpecRd prefetch)
    ds_enabled: bool = True          # deterministic store (divert + flush)
    req_bytes: int = 256             # bytes per CXL.mem request in a page op
    # EP internal DRAM cache. Like media.gc_every_bytes, calibrated to the
    # simulated working set (a serving run flushes tens-hundreds of KB, vs
    # GBs through a real EP): small enough that flushed entries age out
    # before their restore — the regime where SR matters, per the paper.
    dram_cache_bytes: int = 64 << 10
    page_bytes: int = 4 << 10        # allocation alignment
    # op-trace bound: the recorded trace exists for differential replay
    # (tests/benches, ~100s of ops); a long-lived serving process charges
    # one advance op per tick, so recording must not grow unboundedly.
    # Past the cap, ops are still charged but no longer recorded.
    trace_cap: int = 200_000

    @property
    def media_name(self) -> str:
        return MEDIA_BINS.get(self.media, self.media)


class CxlTier:
    """Per-page latency accounting for the serving engine's tiered pages."""

    def __init__(self, config: TierConfig = TierConfig()):
        self.cfg = config
        self.stream = PageStream(config.media_name, sr=config.sr_enabled,
                                 ds=config.ds_enabled,
                                 req_bytes=config.req_bytes,
                                 dram_cache_bytes=config.dram_cache_bytes)
        self._alloc: Dict[object, Tuple[int, int]] = {}  # key -> (base, len)
        self._base = 0
        self.ops: List[Tuple[int, int, int]] = []        # (kind, addr, bytes)
        self.op_ns: List[float] = []                     # charged latencies
        self.trace_truncated = False     # ops past trace_cap went unrecorded
        self.counters = {"reads": 0, "writes": 0, "prefetches": 0,
                         "read_ns": 0.0, "write_ns": 0.0,
                         "deferred_admits": 0}

    # ------------------------------------------------------------ helpers
    @staticmethod
    def entry_bytes(entry) -> int:
        """Payload bytes of a page-store entry (any pytree-ish value)."""
        import jax

        return sum(a.nbytes for a in jax.tree_util.tree_leaves(entry)
                   if hasattr(a, "nbytes"))

    def _range(self, key, nbytes: int) -> Tuple[int, int]:
        """Stable page-aligned range for ``key`` (grown ranges relocate)."""
        nbytes = max(int(nbytes), 1)
        cur = self._alloc.get(key)
        if cur is not None and cur[1] >= nbytes:
            return cur[0], nbytes
        pg = self.cfg.page_bytes
        length = -(-nbytes // pg) * pg
        base = self._base
        self._base += length
        self._alloc[key] = (base, length)
        return base, nbytes

    def _charge(self, kind: int, addr: int, nbytes: int) -> float:
        lat = self.stream.op(kind, addr, nbytes)
        if len(self.ops) < self.cfg.trace_cap:
            self.ops.append((kind, addr, nbytes))
            self.op_ns.append(lat)
        else:
            self.trace_truncated = True   # replay would diverge: say so
        return lat

    # ----------------------------------------------------------- page ops
    def write_entry(self, key, nbytes: int) -> float:
        """Flush an entry's pages to the EP; returns writer-held ns."""
        base, n = self._range(key, nbytes)
        lat = self._charge(PAGE_WRITE, base, n)
        self.counters["writes"] += 1
        self.counters["write_ns"] += lat
        return lat

    def read_entry(self, key, nbytes: int) -> float:
        """Demand-fetch an entry's pages; returns the restore stall ns."""
        base, n = self._range(key, nbytes)
        lat = self._charge(PAGE_READ, base, n)
        self.counters["reads"] += 1
        self.counters["read_ns"] += lat
        return lat

    def speculative_read(self, key, nbytes: int) -> None:
        """MemSpecRd the entry's range ahead of the demand fetch."""
        if not self.cfg.sr_enabled:
            return
        base, n = self._range(key, nbytes)
        self._charge(PAGE_PREFETCH, base, n)
        self.counters["prefetches"] += 1

    def advance(self, dt_ns: float) -> None:
        """Idle engine-tick time: background flush / GC windows open."""
        self._charge(PAGE_ADVANCE, 0, int(dt_ns))

    # ---------------------------------------------------------------- QoS
    def admit_store(self) -> bool:
        """Deterministic-store admission for the engine's QoS flusher.

        Flushes wait while the endpoint has announced an imminent internal
        task or the DevLoad ladder has closed the flush window — the pages
        keep absorbing into the engine's staging ring (reads stay correct
        via the staging-index path) and drain once the EP recovers.
        """
        ok = self.stream.ctl.qos.flush_enabled \
            and not self.stream.ep.gc_pending()
        if not ok:
            self.counters["deferred_admits"] += 1
        return ok

    # --------------------------------------------------------------- stats
    def sr_hit_rate(self) -> float:
        return self.stream.ep.hit_rate()

    def snapshot(self) -> Dict[str, float]:
        ep, ctl = self.stream.ep, self.stream.ctl
        return {
            "media": ep.media.name,
            "sr_enabled": self.cfg.sr_enabled,
            "ds_enabled": self.cfg.ds_enabled,
            "now_ns": self.stream.now,
            "reads": self.counters["reads"],
            "writes": self.counters["writes"],
            "prefetches": self.counters["prefetches"],
            "read_ns": self.counters["read_ns"],
            "write_ns": self.counters["write_ns"],
            "deferred_admits": self.counters["deferred_admits"],
            "sr_hit_rate": ep.hit_rate(),
            "ep_prefetches": ep.stats["prefetches"],
            "gc_events": ep.stats["gc_events"],
            "staging_occupancy": len(ctl.staging) / ctl.staging_capacity,
            "ds": dict(ctl.ds_stats),
            "trace_ops": len(self.ops),
            "trace_truncated": self.trace_truncated,
        }
