"""CXL-timed KV memory tier — serving page traffic through the simulator.

Until now the serving engine's host page tier (``HostPageStore`` + the
staging flusher) and the siliconized-controller simulator (``repro.sim``)
lived in separate worlds: the engine moved real KV pages with no latency
model, the simulator timed synthetic traces with no real traffic. This
module bridges them: a :class:`CxlTier` owns a simulated CXL **topology**
— one or more root ports, each fronting its own endpoint (media bin +
internal DRAM cache) — and charges every page movement the serving
engine performs against it:

 * **flush** (retired pages -> cold tier): ``write_entry`` decomposes the
   entry into CXL.mem stores through each port controller's
   deterministic-store path — fire-and-forget at GPU-memory speed,
   diverted to staging under congestion, exactly Fig. 8;
 * **restore** (prefix reuse): ``read_entry`` is the demand fetch the
   restored slot stalls on; ``speculative_read`` is the MemSpecRd stream
   the engine issues at lookup time so the EP's internal DRAM already
   holds the pages when the demand reads arrive (Fig. 6);
 * **admission**: ``admit_store`` gates the engine's QoS flusher on the
   endpoints' announced state (DevLoad ladder + pending internal tasks) —
   the divert-on-congestion discipline applied at page granularity.

**Multi-root-port topology** (the paper's headline system design —
"multiple CXL root ports for integrating diverse storage media"): with
``TierConfig.topology`` set to N media bins, a *placement policy* maps
each entry onto the ports:

 * ``striped`` — pages round-robin across every port, so one entry's
   demand fetch fans out and the restore stalls only for the slowest
   lane (per-port clocks overlap in simulated time; the topology drains
   at engine-tick barriers);
 * ``hashed``  — whole entries pinned to one port by a stable key hash
   (overlap comes from concurrent entries landing on distinct ports);
 * ``hotness`` — restore-frequency-weighted: entries start on the
   capacity (SSD) ports and hot entries promote to the DRAM port, with
   budget-driven demotion of the coldest resident back to the slowest
   port;
 * ``learned`` — same promote/demote mechanics, but the hot/cold verdict
   comes from :class:`repro.sim.policy.LearnedPlacement` — an
   ICGMM-style Gaussian mixture fit over per-entry reuse features
   (reuse distance, recency, restore frequency, entry bytes) instead of
   the fixed ``hot_promote_after`` counter; demotion victims rank by
   posterior hot-probability.

Both heat-driven policies optionally age their state
(``TierConfig.heat_half_life_ns``): restore counts decay with a
half-life, and fast-port residents whose decayed heat has cooled are
demoted even without budget pressure — a once-hot entry cannot pin the
DRAM port forever under churn.

The tier records every op it charges (``ops``/``op_ns``); replaying that
trace through ``repro.sim.engine.replay_page_trace`` from a fresh stream
(or fresh :class:`~repro.sim.engine.Topology` for port-tagged traces)
must reproduce the charged latencies — the differential harness in
``tests/test_tier.py`` / ``tests/test_topology.py``. Addresses come from
per-port append-only page-aligned bump allocators: entry keys map to
stable port segments, so a re-flushed entry overwrites its previous
ranges (warm EP caches) instead of migrating; only the ``hotness``
policy relocates entries, explicitly, charging the migration traffic.

All times are simulated nanoseconds.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

from repro.sim.engine import (MAX_INFLIGHT_OPS, PAGE_ADVANCE, PAGE_PREFETCH,
                              PAGE_READ, PAGE_READ_ASYNC,
                              PAGE_READ_ASYNC_FAULT, PAGE_READ_FAULT,
                              PAGE_WRITE, PAGE_WRITE_ASYNC,
                              PAGE_WRITE_ASYNC_FAULT, PAGE_WRITE_FAULT,
                              FaultSchedule, OpHandle, Topology)
from repro.sim.media import resolve_media
from repro.sim.policy import LearnedPlacement

# Serving media bins -> simulator media parts (Table 1a). "ssd-fast" is the
# Z-NAND part, "ssd-slow" commodity TLC NAND; any resolve_media spec
# ("optane", "znand@2", ...) is also accepted verbatim.
MEDIA_BINS = {"dram": "dram", "ssd-fast": "znand", "ssd-slow": "nand"}

PLACEMENTS = ("striped", "hashed", "hotness", "learned")

# placements whose restores feed heat state and can trigger migration
HEAT_PLACEMENTS = ("hotness", "learned")


def resolve_bin(spec: str) -> str:
    """Map a serving bin name to a simulator media spec.

    Accepts a bin name (``"ssd-fast"``), a raw media spec (``"znand"``),
    or either with a latency multiplier (``"ssd-fast@2"`` -> ``"znand@2"``)
    — the multiplier survives the bin mapping so scaled bins time
    consistently end to end.
    """
    name, sep, mult = spec.partition("@")
    base = MEDIA_BINS.get(name, name)
    return f"{base}@{mult}" if sep else base


def _stable_hash(key) -> int:
    """Deterministic (cross-run) placement hash: blake2b of ``repr(key)``.

    Not the builtin ``hash`` (salted per process — placement would move
    between runs) and not crc32 (badly biased modulo small port counts
    for short keys like small ints).
    """
    return int.from_bytes(
        hashlib.blake2b(repr(key).encode(), digest_size=8).digest(), "big")


@dataclasses.dataclass(frozen=True)
class TierConfig:
    """Configuration for a :class:`CxlTier` (all latencies simulated ns).

    ``media`` names the single-port media bin; setting ``topology`` to a
    tuple of bins instead builds a multi-root-port tier and activates the
    ``placement`` policy. An empty ``topology`` is exactly the
    pre-topology single-port tier (same op trace format, same timing).
    """

    media: str = "ssd-fast"          # single-port bin name or media spec
    sr_enabled: bool = True          # speculative read (MemSpecRd prefetch)
    ds_enabled: bool = True          # deterministic store (divert + flush)
    req_bytes: int = 256             # bytes per CXL.mem request in a page op
    # EP internal DRAM cache. Like media.gc_every_bytes, calibrated to the
    # simulated working set (a serving run flushes tens-hundreds of KB, vs
    # GBs through a real EP): small enough that flushed entries age out
    # before their restore — the regime where SR matters, per the paper.
    dram_cache_bytes: int = 64 << 10
    page_bytes: int = 4 << 10        # allocation + striping granule
    # op-trace bound: the recorded trace exists for differential replay
    # (tests/benches, ~100s of ops); a long-lived serving process charges
    # one advance op per tick, so recording must not grow unboundedly.
    # Past the cap, ops are still charged but no longer recorded.
    trace_cap: int = 200_000
    # per-port cap on outstanding async page ops: an async entry op whose
    # port is saturated stalls at issue until a slot frees (the stall is
    # the only latency charged at issue — see read_entry_async)
    max_inflight: int = MAX_INFLIGHT_OPS
    # ---- multi-root-port topology -------------------------------------
    topology: Tuple[str, ...] = ()   # per-port media bins; () = single-port
    placement: str = "striped"       # striped | hashed | hotness | learned
    hot_promote_after: int = 2       # restores before promotion (hotness)
    hot_budget_bytes: int = 256 << 10   # fast-port residency budget
    # heat aging (hotness + learned): restore counts decay with this
    # half-life (simulated ns) and cooled fast-port residents demote even
    # without budget pressure. 0.0 = no aging (heat is a plain counter).
    heat_half_life_ns: float = 0.0
    # ---- fault injection ----------------------------------------------
    # a repro.sim.engine.FaultSchedule the topology's ports consult:
    # degrade windows scale media service time, transient windows fail op
    # attempts into bounded retry-with-backoff, hot_remove kills a port
    # (every entry with a segment on it is lost — see CxlTier.poll_faults)
    faults: Optional[FaultSchedule] = None

    def __post_init__(self):
        """Validate the placement policy and async cap early."""
        if self.placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {self.placement!r} "
                             f"(expected one of {PLACEMENTS})")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1 "
                             f"(got {self.max_inflight})")

    @property
    def media_name(self) -> str:
        """Resolved simulator media spec for the single-port bin."""
        return resolve_bin(self.media)

    @property
    def port_medias(self) -> Tuple[str, ...]:
        """Resolved per-port media specs (one entry per root port)."""
        return tuple(resolve_bin(m) for m in (self.topology or (self.media,)))

    @property
    def tagged(self) -> bool:
        """True when the op trace is port-tagged (multi-port mode)."""
        return bool(self.topology)


@dataclasses.dataclass
class TierHandle:
    """Completion handle for one async entry op (flush or restore fetch).

    ``lanes`` holds one :class:`repro.sim.engine.OpHandle` per port
    segment the entry spans; the op is complete once *every* lane's port
    clock passes its completion (``CxlTier.poll``). ``issue_wait_ns`` is
    the total in-flight-cap stall charged to the issuer (usually 0.0);
    ``in_flight_ns`` the issue-to-slowest-lane-completion span — the
    latency the scheduler gets to hide behind decode.
    """

    key: object
    kind: int                     # PAGE_READ_ASYNC or PAGE_WRITE_ASYNC
    nbytes: int
    lanes: List[OpHandle]
    issued_ns: float
    done_ns: float                # slowest lane's completion timestamp
    issue_wait_ns: float
    retired: bool = False

    @property
    def in_flight_ns(self) -> float:
        """Simulated ns the entry op was outstanding (issue -> done)."""
        return self.done_ns - self.issued_ns

    @property
    def failed(self) -> bool:
        """True if any lane failed — its port was hot-removed or the
        transient-retry budget was exhausted. A failed fetch means the
        entry's pages never landed; the serving layer must recover."""
        return any(lane.failed for lane in self.lanes)


class CxlTier:
    """Per-page latency accounting for the serving engine's tiered pages.

    One instance owns a :class:`repro.sim.engine.Topology` (a single port
    in legacy mode) plus the placement state mapping entry keys onto port
    segments. All returned latencies are simulated nanoseconds.

    Entry ops come in two disciplines: blocking (``read_entry`` /
    ``write_entry`` — the caller stalls for the slowest lane) and async
    (``read_entry_async`` / ``write_entry_async`` — the media work rides
    the port service cursors and the returned :class:`TierHandle` retires
    as :meth:`advance` passes simulated time; only in-flight-cap issue
    stalls are charged to the caller).
    """

    def __init__(self, config: TierConfig = TierConfig()):
        self.cfg = config
        self.topo = Topology(config.port_medias, sr=config.sr_enabled,
                             ds=config.ds_enabled,
                             req_bytes=config.req_bytes,
                             dram_cache_bytes=config.dram_cache_bytes,
                             max_inflight=config.max_inflight,
                             faults=config.faults)
        n = self.topo.n_ports
        # key -> [(port, base, capacity_bytes)] segments, striping order
        self._segments: Dict[object, List[Tuple[int, int, int]]] = {}
        self._base = [0] * n             # per-port bump allocators
        self._live_bytes = [0] * n       # bytes currently mapped per port
        # per-port exact-fit free lists: npages -> LIFO of reusable bases.
        # Only free_entry feeds these (relocations leak their old ranges,
        # as the bump allocator always did) — under open-loop load the
        # page store evicts constantly, and without recycling the bump
        # cursors run away while live_bytes stays flat.
        self._free: List[Dict[int, List[int]]] = [dict() for _ in range(n)]
        self._entry_counter = 0          # rotates the striping start port
        # heat-policy state (hotness + learned)
        self._heat: Dict[object, float] = {}         # (decayed) restores
        self._heat_t: Dict[object, float] = {}       # decay timestamps
        self._fast_resident: Dict[object, int] = {}  # key -> bytes, LRU-ish
        self._policy: Optional[LearnedPlacement] = (
            LearnedPlacement(half_life_ns=config.heat_half_life_ns)
            if config.placement == "learned" else None)
        self._down_ports: set = set()    # hot-removed (detected) ports
        self.lost_keys: List[object] = []  # invalidated, pending takeout
        self.last_entry_failed = False   # latest blocking entry op's fate
        self._port_mults: Tuple[float, ...] = (1.0,) * n
        self._fast_port = 0
        self._slow_port = 0
        self._recompute_hot_ports()
        self.ops: List[tuple] = []       # (kind,addr,nbytes) or port-tagged
        self.op_ns: List[float] = []     # charged latencies (ns)
        self.trace_truncated = False     # ops past trace_cap went unrecorded
        self._port_stat_dicts: Optional[List[Dict[str, object]]] = None
        self.counters = {"reads": 0, "writes": 0, "prefetches": 0,
                         "read_bytes": 0, "write_bytes": 0,
                         "prefetch_bytes": 0,
                         "read_ns": 0.0, "write_ns": 0.0,
                         "async_reads": 0, "async_writes": 0,
                         "async_read_ns": 0.0, "async_write_ns": 0.0,
                         "issue_wait_ns": 0.0,
                         "deferred_admits": 0,
                         "promotions": 0, "demotions": 0,
                         "migrate_ns": 0.0,
                         "frees": 0, "freed_bytes": 0,
                         "reused_segments": 0,
                         "fault_ops": 0,        # fault-annotated page ops
                         "lost_entries": 0,     # entries torn by hot-remove
                         "lost_bytes": 0,
                         "noop_frees": 0,       # double/unknown frees
                         "dead_segment_frees": 0}  # frees on removed ports

    # ------------------------------------------------------------ helpers
    @property
    def stream(self):
        """Port 0's :class:`PageStream` (the whole tier in legacy mode)."""
        return self.topo.ports[0]

    @staticmethod
    def entry_bytes(entry) -> int:
        """Payload bytes of a page-store entry (any pytree-ish value)."""
        import jax

        return sum(a.nbytes for a in jax.tree_util.tree_leaves(entry)
                   if hasattr(a, "nbytes"))

    def _alive_ports(self) -> List[int]:
        """Ports still serviceable (not hot-removed); raises once the
        whole topology is gone — there is nothing left to place on."""
        alive = [p for p in range(self.topo.n_ports)
                 if p not in self._down_ports]
        if not alive:
            raise RuntimeError("every root port has been hot-removed; "
                               "the tier has no serviceable media left")
        return alive

    def _recompute_hot_ports(self) -> None:
        """Pick the hotness policy's fast/slow ports among *alive* ports,
        weighting each media's read service time by its current degrade
        multiplier — a degraded DRAM port can lose fast status to a
        healthy SSD port, which is what steers placement away from it."""
        alive = [p for p in range(self.topo.n_ports)
                 if p not in self._down_ports]
        if not alive:
            return
        medias = self.cfg.port_medias

        def eff_read_ns(p: int) -> float:
            return (resolve_media(medias[p]).read_ns *
                    self.topo.ports[p].degrade_mult)

        self._fast_port = int(min(alive, key=eff_read_ns))
        self._slow_port = int(max(alive, key=eff_read_ns))

    # --------------------------------------------------------- placement
    def _stripe_order(self, key) -> List[int]:
        """Port visit order for a new entry under the active placement.

        Hot-removed ports never appear: striping, hashing and hotness all
        run over the alive set, so new and re-placed entries re-stripe
        around dead ports automatically.
        """
        alive = self._alive_ports()
        n = len(alive)
        if n == 1:
            return [alive[0]]
        if self.cfg.placement == "hashed":
            return [alive[_stable_hash(key) % n]]
        if self.cfg.placement in HEAT_PLACEMENTS:
            # entries start on the capacity ports; the fast (DRAM) port is
            # reserved for promoted-hot entries (unless it is the only one)
            cands = [p for p in alive if p != self._fast_port] or [alive[0]]
            return [cands[_stable_hash(key) % len(cands)]]
        start = self._entry_counter % n          # striped round-robin
        return [alive[(start + j) % n] for j in range(n)]

    def _allocate(self, key, nbytes: int,
                  ports: Optional[List[int]] = None
                  ) -> List[Tuple[int, int, int]]:
        """Bump-allocate page-aligned segments for ``key`` over ``ports``."""
        pg = self.cfg.page_bytes
        npages = -(-nbytes // pg)
        if ports is None:
            ports = self._stripe_order(key)
            self._entry_counter += 1
        pages = {p: 0 for p in ports}
        for j in range(npages):
            pages[ports[j % len(ports)]] += 1
        segs = []
        for p in ports:
            if not pages[p]:
                continue
            length = pages[p] * pg
            bucket = self._free[p].get(pages[p])
            if bucket:
                # exact-fit recycle of a freed segment: same port, same
                # page count — the EP sees a stable, bounded address space
                # instead of an ever-growing bump cursor
                base = bucket.pop()
                self.counters["reused_segments"] += 1
            else:
                base = self._base[p]
                self._base[p] += length
            segs.append((p, base, length))
            self._live_bytes[p] += length
        old = self._segments.get(key)
        if old is not None:
            for p, _, length in old:
                self._live_bytes[p] -= length
        self._segments[key] = segs
        # fast-port residency bookkeeping must follow the segments: a
        # grown entry relocating off the fast port (stripe order picks a
        # capacity port) is no longer resident there, and leaving it in
        # _fast_resident would make a later demotion charge its reads on
        # the wrong port's address space
        if any(p != self._fast_port for p, _, _ in segs):
            self._fast_resident.pop(key, None)
        return segs

    def _place(self, key, nbytes: int) -> List[Tuple[int, int, int]]:
        """Charged (port, addr, raw_bytes) splits for an entry access.

        Reuses the stored segments when their capacity still covers
        ``nbytes`` (stable ranges — a re-flushed entry overwrites, warm EP
        caches); a grown entry relocates. Raw bytes walk the segments in
        page-granule round-robin so the per-port split is deterministic.
        """
        nbytes = max(int(nbytes), 1)
        segs = self._segments.get(key)
        if segs is None or sum(c for _, _, c in segs) < nbytes:
            segs = self._allocate(key, nbytes)
        pg = self.cfg.page_bytes
        npages = -(-nbytes // pg)
        raw = {i: 0 for i in range(len(segs))}
        cap = {i: c // pg for i, (_, _, c) in enumerate(segs)}
        j = 0
        for page in range(npages):
            size = min(pg, nbytes - page * pg)
            for _ in range(len(segs)):           # next segment with room
                if cap[j % len(segs)]:
                    break
                j += 1
            i = j % len(segs)
            cap[i] -= 1
            raw[i] += size
            j += 1
        return [(p, a, raw[i]) for i, (p, a, _) in enumerate(segs)
                if raw[i]]

    # ----------------------------------------------------------- charging
    def _charge(self, port: int, kind: int, addr: int, nbytes: int) -> float:
        """Execute one op on its port and record it in the trace (ns).

        Blocking reads/writes that crossed the fault path (retried under
        a transient window, or failed on a downed port) are recorded
        under their fault-annotated kind, so the trace is self-describing
        — replaying it demands the run's :class:`FaultSchedule`."""
        lat = self.topo.op(port, kind, addr, nbytes)
        if kind in (PAGE_READ, PAGE_WRITE) and self.cfg.faults is not None:
            ps = self.topo.ports[max(port, 0)]
            self.last_entry_failed = (self.last_entry_failed
                                      or ps.last_op_failed)
            if ps.last_op_retries or ps.last_op_failed:
                kind = (PAGE_READ_FAULT if kind == PAGE_READ
                        else PAGE_WRITE_FAULT)
                self.counters["fault_ops"] += 1
        if len(self.ops) < self.cfg.trace_cap:
            self.ops.append((port, kind, addr, nbytes) if self.cfg.tagged
                            else (kind, addr, nbytes))
            self.op_ns.append(lat)
        else:
            self.trace_truncated = True   # replay would diverge: say so
        return lat

    def _charge_async(self, port: int, kind: int, addr: int,
                      nbytes: int) -> OpHandle:
        """Issue one async op on its port; the recorded latency is the
        issue-slot wait (what the caller actually paid at issue). Ops
        that crossed the fault path record under their fault-annotated
        kind, like :meth:`_charge`."""
        handle = self.topo.issue(port, kind, addr, nbytes)
        rec = kind
        if (handle.retries or handle.failed) and self.cfg.faults is not None:
            rec = (PAGE_READ_ASYNC_FAULT if kind == PAGE_READ_ASYNC
                   else PAGE_WRITE_ASYNC_FAULT)
            self.counters["fault_ops"] += 1
        if len(self.ops) < self.cfg.trace_cap:
            self.ops.append((port, rec, addr, nbytes) if self.cfg.tagged
                            else (rec, addr, nbytes))
            self.op_ns.append(handle.wait_ns)
        else:
            self.trace_truncated = True
        return handle

    def _issue_entry(self, key, nbytes: int, kind: int) -> TierHandle:
        """Issue one async entry op across the entry's port segments."""
        lanes = []
        for port, addr, n in self._place(key, nbytes):
            lanes.append(self._charge_async(port, kind, addr, n))
        handle = TierHandle(
            key=key, kind=kind, nbytes=int(nbytes), lanes=lanes,
            issued_ns=min(h.issued_ns for h in lanes),
            done_ns=max(h.done_ns for h in lanes),
            issue_wait_ns=sum(h.wait_ns for h in lanes))
        self.counters["issue_wait_ns"] += handle.issue_wait_ns
        return handle

    # ----------------------------------------------------------- page ops
    def write_entry(self, key, nbytes: int) -> float:
        """Flush an entry's pages to its port EPs; returns writer-held ns.

        Segments on distinct ports overlap in simulated time, so the hold
        is the *slowest lane's* time, not the sum — this is where flushes
        to distinct ports stop serializing.
        """
        self.last_entry_failed = False
        held = 0.0
        for port, addr, n in self._place(key, nbytes):
            held = max(held, self._charge(port, PAGE_WRITE, addr, n))
        self.counters["writes"] += 1
        self.counters["write_bytes"] += int(nbytes)
        self.counters["write_ns"] += held
        return held

    def read_entry(self, key, nbytes: int) -> float:
        """Demand-fetch an entry's pages; returns the restore stall (ns).

        The stall is the slowest lane's demand-read time (per-port lanes
        overlap; each lane serializes on its own port clock). Under the
        ``hotness`` policy the restore also bumps the entry's heat and may
        trigger promotion/demotion (charged separately, see
        :meth:`_rebalance`).
        """
        self.last_entry_failed = False
        stall = 0.0
        for port, addr, n in self._place(key, nbytes):
            stall = max(stall, self._charge(port, PAGE_READ, addr, n))
        self.counters["reads"] += 1
        self.counters["read_bytes"] += int(nbytes)
        self.counters["read_ns"] += stall
        failed = self.last_entry_failed
        if self._note_restore(key, nbytes):
            self._rebalance(key, nbytes)
        self.last_entry_failed = failed  # migration charges don't mask it
        return stall

    def write_entry_async(self, key, nbytes: int) -> TierHandle:
        """Background flush: issue the entry's page writes without holding
        the writer. Returns a :class:`TierHandle`; the writer is charged
        only the issue-slot wait (``handle.issue_wait_ns``), the media
        work completes on the port cursors as simulated time passes.
        """
        handle = self._issue_entry(key, nbytes, PAGE_WRITE_ASYNC)
        self.counters["async_writes"] += 1
        self.counters["write_bytes"] += int(nbytes)
        self.counters["async_write_ns"] += handle.in_flight_ns
        return handle

    def read_entry_async(self, key, nbytes: int) -> TierHandle:
        """Non-blocking demand fetch: issue the entry's lane reads and
        return the completion handle instead of stalling for them.

        The caller pays only the issue-slot wait; the fetch itself is
        outstanding until every lane's port clock passes its completion
        (:meth:`poll` after :meth:`advance` ticks) — the window a
        scheduler hides behind decode. Hotness heat/rebalancing applies
        exactly as for the blocking read.
        """
        handle = self._issue_entry(key, nbytes, PAGE_READ_ASYNC)
        self.counters["async_reads"] += 1
        self.counters["read_bytes"] += int(nbytes)
        self.counters["async_read_ns"] += handle.in_flight_ns
        if self._note_restore(key, nbytes):
            self._rebalance(key, nbytes)
        return handle

    def poll(self, handle: TierHandle) -> bool:
        """True once every lane of an async entry op has completed."""
        if handle.retired:
            return True
        done = True
        for lane in handle.lanes:
            if not self.topo.poll(lane):
                done = False
        handle.retired = done
        return done

    def inflight_ops(self) -> int:
        """Async page ops still outstanding across the topology."""
        return self.topo.inflight_depth()

    def free_entry(self, key) -> int:
        """Release ``key``'s port segments for reuse; returns freed bytes.

        The address ranges go back to their ports' exact-fit free lists
        (a later same-shape allocation recycles them — see
        :meth:`_allocate`), and the hotness state for the key is dropped.
        Freeing charges nothing: deallocation is metadata, only page
        *movement* costs simulated time. Unknown keys — including a
        second free of the same key, since the first pops its segments —
        are a counted no-op (``counters["noop_frees"]``, returns 0) so
        callers can free unconditionally on eviction without ever
        corrupting the free lists. Segments on a hot-removed port are
        dropped, not recycled (their address space died with the port —
        ``counters["dead_segment_frees"]``), and a base resurfacing in a
        bucket it already sits in raises rather than poisoning the
        allocator.
        """
        segs = self._segments.pop(key, None)
        if segs is None:
            self.counters["noop_frees"] += 1
            return 0
        pg = self.cfg.page_bytes
        freed = 0
        for p, base, length in segs:
            self._live_bytes[p] -= length
            if p in self._down_ports:
                self.counters["dead_segment_frees"] += 1
            else:
                bucket = self._free[p].setdefault(length // pg, [])
                if base in bucket:
                    raise RuntimeError(
                        f"free-list corruption: port {p} base {base:#x} "
                        "already sits in its free bucket")
                bucket.append(base)
            freed += length
        self._heat.pop(key, None)
        self._heat_t.pop(key, None)
        self._fast_resident.pop(key, None)
        if self._policy is not None:
            self._policy.forget(key)
        self.counters["frees"] += 1
        self.counters["freed_bytes"] += freed
        return freed

    def has_entry(self, key) -> bool:
        """True while ``key`` still maps to live segments on this tier.

        The serving layer's recovery path uses this to tell a transient
        fetch failure (entry intact — retry the read) apart from page
        loss (entry invalidated by a hot-remove — the copy is gone and
        the request must fall back to the host store or recompute).
        """
        return key in self._segments

    def speculative_read(self, key, nbytes: int) -> None:
        """MemSpecRd the entry's port ranges ahead of the demand fetch."""
        if not self.cfg.sr_enabled:
            return
        for port, addr, n in self._place(key, nbytes):
            self._charge(port, PAGE_PREFETCH, addr, n)
        self.counters["prefetches"] += 1
        self.counters["prefetch_bytes"] += int(nbytes)

    def advance(self, dt_ns: float) -> None:
        """Idle engine-tick time (ns): the topology drains (barrier) and
        every port sees the idle window — background flush / GC windows
        open, the QoS ladders stay live, and (under a fault schedule)
        newly-fired fault events are folded in via :meth:`poll_faults`."""
        if self.cfg.tagged:
            self._charge(-1, PAGE_ADVANCE, 0, int(dt_ns))
        else:
            self._charge(0, PAGE_ADVANCE, 0, int(dt_ns))
        if self.cfg.faults is not None:
            self.poll_faults()

    # ------------------------------------------------------ fault handling
    def _invalidate_port(self, port: int) -> List[object]:
        """Tear down every entry with a segment on a hot-removed port.

        A torn entry is a lost entry: partial lanes are useless for a
        restore, so the whole mapping goes. Segments on still-alive ports
        recycle through their free lists; the dead port's address space
        (segments, free lists, bump cursor) is abandoned wholesale.
        Returns the lost keys.
        """
        pg = self.cfg.page_bytes
        lost = []
        for key, segs in list(self._segments.items()):
            if not any(p == port for p, _, _ in segs):
                continue
            del self._segments[key]
            nbytes = 0
            for p, base, length in segs:
                self._live_bytes[p] -= length
                nbytes += length
                if p not in self._down_ports:
                    self._free[p].setdefault(length // pg, []).append(base)
            self._heat.pop(key, None)
            self._heat_t.pop(key, None)
            self._fast_resident.pop(key, None)
            if self._policy is not None:
                self._policy.forget(key)
            lost.append(key)
            self.counters["lost_entries"] += 1
            self.counters["lost_bytes"] += nbytes
        self._free[port] = {}
        self._live_bytes[port] = 0
        return lost

    def poll_faults(self) -> List[object]:
        """Fold newly-fired fault events into placement state.

        Newly hot-removed ports invalidate every entry mapped onto them
        (the lost keys are returned and queued on ``lost_keys`` until
        :meth:`take_lost_keys` drains them — the serving layer's recovery
        entry point), and any down/degrade change re-derives the hotness
        policy's fast/slow ports over the alive set. If the fast port
        loses its status to a degrade window, resident hot entries are
        demoted off it (charged migrations) — the DevLoad-visible latency
        spike steers future placement *and* evacuates current residents.
        """
        if self.cfg.faults is None:
            return []
        newly: List[object] = []
        for p in self.topo.ports_down():
            if p not in self._down_ports:
                self._down_ports.add(p)
                newly.extend(self._invalidate_port(p))
        mults = tuple(p.degrade_mult for p in self.topo.ports)
        if newly or mults != self._port_mults:
            self._port_mults = mults
            old_fast = self._fast_port
            self._recompute_hot_ports()
            if (self.cfg.placement in HEAT_PLACEMENTS
                    and self._fast_port != old_fast
                    and old_fast not in self._down_ports):
                self._demote_all_fast(old_fast)
        self.lost_keys.extend(newly)
        return newly

    def take_lost_keys(self) -> List[object]:
        """Drain the pending lost-entry queue (serving recovery hook)."""
        out, self.lost_keys = self.lost_keys, []
        return out

    def _demote_all_fast(self, old_fast: int) -> None:
        """Evacuate heat-policy residents off a demoted (degraded) fast
        port: each is read off its current segments and rewritten onto
        the (healthy) slow port — standard demotion, charged like any
        other migration; the entries re-earn promotion onto the new fast
        port through restore heat."""
        for victim in list(self._fast_resident):
            self._demote(victim)

    # --------------------------------------------- heat state (rebalancing)
    def _now_ns(self) -> float:
        """Topology-wide simulated clock (the slowest port's stream)."""
        return max(p.now for p in self.topo.ports)

    def _decayed_heat(self, key, now_ns: Optional[float] = None) -> float:
        """Restore heat aged by ``heat_half_life_ns`` (0 = plain count)."""
        h = self._heat.get(key, 0.0)
        hl = self.cfg.heat_half_life_ns
        if h <= 0.0 or hl <= 0.0:
            return h
        if now_ns is None:
            now_ns = self._now_ns()
        dt = max(0.0, now_ns - self._heat_t.get(key, 0.0))
        return h * 0.5 ** (dt / hl)

    def _note_restore(self, key, nbytes: int) -> bool:
        """Fold one restore into the heat state; True when the active
        placement rebalances on restores (hotness/learned, multi-port)."""
        if self.topo.n_ports <= 1 \
                or self.cfg.placement not in HEAT_PLACEMENTS:
            return False
        now = self._now_ns()
        self._heat[key] = self._decayed_heat(key, now) + 1.0
        self._heat_t[key] = now
        if self._policy is not None:
            self._policy.observe(key, now, int(nbytes))
        return True

    def _victim_rank(self, key, now_ns: float) -> float:
        """Demotion ranking — coldest first. Learned placement ranks by
        posterior hot-probability, the counter policy by decayed heat."""
        if self._policy is not None and self._policy.fitted:
            return self._policy.score(key, now_ns)
        return self._decayed_heat(key, now_ns)

    def _demote(self, victim) -> None:
        """Migrate one fast-port resident back to the slow port.

        Charges a read off the segments' actual ports (belt and braces
        with the ``_allocate`` bookkeeping: a segment address is only
        meaningful on its own port's bump space) plus a write onto the
        slowest port; the key keeps a valid mapping at all times."""
        vbytes = self._fast_resident.pop(victim)
        for p, addr, cap in self._segments.get(victim, []):
            self.counters["migrate_ns"] += self._charge(
                p, PAGE_READ, addr, min(cap, vbytes))
        moved = self._allocate(victim, vbytes, ports=[self._slow_port])
        for _, addr, cap in moved:
            self.counters["migrate_ns"] += self._charge(
                self._slow_port, PAGE_WRITE, addr, min(cap, vbytes))
        self._heat[victim] = 0.0         # demoted: re-earn promotion
        self.counters["demotions"] += 1

    def _cool_fast_residents(self, now_ns: float, exclude=None) -> None:
        """Aging sweep: demote fast residents whose heat has decayed cold
        — a once-hot entry cannot pin the fast port forever under churn.
        Only runs with ``heat_half_life_ns`` set (otherwise heat never
        cools and the sweep would be a per-restore no-op scan)."""
        if self.cfg.heat_half_life_ns <= 0.0:
            return
        for k in list(self._fast_resident):
            if k == exclude:
                continue
            cold = self._decayed_heat(k, now_ns) < 1.0
            if cold and self._policy is not None:
                cold = not self._policy.is_hot(k, now_ns)
            if cold:
                self._demote(k)

    def _rebalance(self, key, nbytes: int) -> None:
        """Promote a hot entry to the fast port; demote over-budget cold.

        The hot verdict is the active policy's: decayed heat against
        ``hot_promote_after`` (hotness) or the learned GMM's posterior
        (:meth:`repro.sim.policy.LearnedPlacement.is_hot`). Promotion
        charges only the write onto the fast port (the entry's pages
        were just demand-read into GPU memory); each demotion charges a
        read off the fast port plus a write onto the slowest port.
        Segments are swapped atomically after the charges, so every key
        keeps a valid mapping at all times — no entry is ever stranded
        mid-migration. With heat aging enabled, every rebalance also
        sweeps cooled residents off the fast port.
        """
        if self._fast_port == self._slow_port:
            return                       # homogeneous topology: nothing to do
        now = self._now_ns()
        segs = self._segments.get(key, [])
        on_fast = all(p == self._fast_port for p, _, _ in segs)
        if on_fast:
            self._fast_resident[key] = max(self._fast_resident.get(key, 0),
                                           int(nbytes))
            self._cool_fast_residents(now, exclude=key)
            return
        if self._policy is not None:
            hot = self._policy.is_hot(key, now)
        else:
            hot = self._decayed_heat(key, now) >= self.cfg.hot_promote_after
        if not hot:
            self._cool_fast_residents(now, exclude=key)
            return
        new = self._allocate(key, nbytes, ports=[self._fast_port])
        for _, addr, cap in new:
            self.counters["migrate_ns"] += self._charge(
                self._fast_port, PAGE_WRITE, addr, min(cap, int(nbytes)))
        self.counters["promotions"] += 1
        self._fast_resident[key] = int(nbytes)
        budget = self.cfg.hot_budget_bytes
        while sum(self._fast_resident.values()) > budget \
                and len(self._fast_resident) > 1:
            victim = min((k for k in self._fast_resident if k != key),
                         key=lambda k: self._victim_rank(k, now))
            self._demote(victim)
        self._cool_fast_residents(now, exclude=key)

    # ---------------------------------------------------------------- QoS
    def admit_store(self) -> bool:
        """Deterministic-store admission for the engine's QoS flusher.

        Flushes wait while *any* endpoint has announced an imminent
        internal task or closed its flush window via the DevLoad ladder —
        placement may target any port, so admission is the conservative
        AND across the topology. The pages keep absorbing into the
        engine's staging ring (reads stay correct via the staging-index
        path) and drain once every EP recovers.
        """
        ok = all(p.ctl.qos.flush_enabled and not p.ep.gc_pending()
                 for p in self.topo.ports)
        if not ok:
            self.counters["deferred_admits"] += 1
        return ok

    # --------------------------------------------------------------- stats
    def sr_hit_rate(self) -> float:
        """Aggregate EP internal-DRAM hit rate over the topology's reads."""
        reads = sum(p.ep.stats["reads"] for p in self.topo.ports)
        hits = sum(p.ep.stats["hits"] for p in self.topo.ports)
        return hits / reads if reads else 0.0

    def store_occupancy(self) -> float:
        """Worst-port DS staging-stack fill fraction (0..1)."""
        return max(len(p.ctl.staging) / p.ctl.staging_capacity
                   for p in self.topo.ports)

    def port_stats(self) -> List[Dict[str, object]]:
        """Per-port telemetry: occupancy, queue depth, DevLoad, SR hits,
        async in-flight depth.

        Cheap and live: the per-port dicts are allocated once and updated
        in place, so this is safe to call every decode tick (no drain
        barrier, no per-tick allocation churn) — the scheduler and the
        ``launch/serve.py`` stats line read it mid-run.
        """
        if self._port_stat_dicts is None:
            self._port_stat_dicts = [{"port": i,
                                      "media": p.ep.media.name}
                                     for i, p in enumerate(self.topo.ports)]
        for i, p in enumerate(self.topo.ports):
            ep, ctl = p.ep, p.ctl
            reads = ep.stats["reads"]
            d = self._port_stat_dicts[i]
            d["now_ns"] = p.now
            d["live_bytes"] = self._live_bytes[i]
            d["free_bytes"] = self.cfg.page_bytes * sum(
                npg * len(bases) for npg, bases in self._free[i].items())
            d["ep_reads"] = reads
            d["ep_writes"] = ep.stats["writes"]
            d["ep_prefetches"] = ep.stats["prefetches"]
            d["sr_hit_rate"] = ep.stats["hits"] / reads if reads else 0.0
            d["gc_events"] = ep.stats["gc_events"]
            d["staging_occupancy"] = len(ctl.staging) / ctl.staging_capacity
            d["queue_depth"] = len(ctl.memory_queue)
            d["devload"] = int(ctl.qos.last_devload)
            d["inflight"] = p.inflight_depth()
            d["down"] = p.down
            d["degrade_mult"] = p.degrade_mult
            d["fault_retries"] = p.fault_retries
            d["fault_failures"] = p.fault_failures
        return self._port_stat_dicts

    def snapshot(self) -> Dict[str, object]:
        """One flat dict of tier state for stats lines / bench artifacts.

        Cheap and callable mid-run: reads live clocks and counters (via
        the in-place :meth:`port_stats` view) — no drain barrier.
        """
        ports = self.port_stats()
        return {
            "media": "+".join(p["media"] for p in ports)
            if self.cfg.tagged else ports[0]["media"],
            "topology": list(self.cfg.port_medias),
            "placement": self.cfg.placement if self.cfg.tagged else None,
            "sr_enabled": self.cfg.sr_enabled,
            "ds_enabled": self.cfg.ds_enabled,
            "now_ns": self.topo.now,
            "reads": self.counters["reads"],
            "writes": self.counters["writes"],
            "prefetches": self.counters["prefetches"],
            "read_ns": self.counters["read_ns"],
            "write_ns": self.counters["write_ns"],
            "deferred_admits": self.counters["deferred_admits"],
            "promotions": self.counters["promotions"],
            "demotions": self.counters["demotions"],
            "migrate_ns": self.counters["migrate_ns"],
            "frees": self.counters["frees"],
            "freed_bytes": self.counters["freed_bytes"],
            "segment_reuses": self.counters["reused_segments"],
            "async_reads": self.counters["async_reads"],
            "async_writes": self.counters["async_writes"],
            "issue_wait_ns": self.counters["issue_wait_ns"],
            "inflight_ops": self.inflight_ops(),
            "sr_hit_rate": self.sr_hit_rate(),
            "ep_prefetches": sum(p["ep_prefetches"] for p in ports),
            "gc_events": sum(p["gc_events"] for p in ports),
            "staging_occupancy": self.store_occupancy(),
            "ds": dict(self.stream.ctl.ds_stats) if not self.cfg.tagged
            else [dict(p.ctl.ds_stats) for p in self.topo.ports],
            "ports": ports,
            "trace_ops": len(self.ops),
            "trace_truncated": self.trace_truncated,
            "fault_ops": self.counters["fault_ops"],
            "fault_retries": sum(p.fault_retries for p in self.topo.ports),
            "fault_failures": sum(p.fault_failures
                                  for p in self.topo.ports),
            "fault_backoff_ns": sum(p.fault_backoff_ns
                                    for p in self.topo.ports),
            "lost_entries": self.counters["lost_entries"],
            "lost_bytes": self.counters["lost_bytes"],
            "ports_down": sorted(self._down_ports),
            "noop_frees": self.counters["noop_frees"],
            "dead_segment_frees": self.counters["dead_segment_frees"],
        }
