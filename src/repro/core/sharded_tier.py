"""Per-rank CXL root-port sets for multi-rank (sharded) serving.

The paper's headline system design — multiple CXL root ports fronting
diverse media — composes with tensor-parallel serving by giving **each
mesh rank its own root-port set**: a :class:`ShardedTier` owns one
:class:`repro.core.tier.CxlTier` (and therefore one
:class:`repro.sim.engine.Topology`) per model-axis rank, plus one
dedicated **peer-link lane** per rank (a DRAM-class
:class:`repro.sim.engine.PageStream`) modeling the inter-rank CXL
fabric hop.

Placement becomes a cross-rank decision:

 * **flush once, not N times** — an entry is written to its *home
   rank* (stable key hash modulo rank count), so a zipf-shared hot
   prefix lands on one rank's DRAM/SSD ports exactly once instead of
   being duplicated across every rank;
 * **peer fetch instead of duplicate cold restores** — when the entry
   is restored, the home rank performs the single media fetch and the
   other ``N - 1`` ranks receive their KV shards over the home rank's
   peer-link lane (charged ``nbytes * (N - 1) / N`` at DRAM-class
   link speed) — strictly cheaper than ``N`` independent SSD
   restores of the same pages;
 * **mirror on first share** — the first cross-rank restore also
   writes a mirror copy to the next rank over, so a later hot-remove
   of the home rank's port recovers from the peer's copy instead of
   losing the entry (see :meth:`ShardedTier.take_lost_keys`);
 * **learned re-homing** (``placement="learned"``) — a shared
   :class:`repro.sim.policy.LearnedPlacement` watches per-rank restore
   demand (callers tag restores with the requesting rank). Hot shared
   entries with more than one live copy serve **multi-source**: every
   holder rank fetches locally and the missing shards split across the
   holders' outbound lanes in parallel (two holders of a 2-rank tier
   move *zero* peer bytes), and on the next flush the entry *re-homes*
   to the rank whose requests restore it most — a restore-frequency-
   weighted override on top of the blake2b hash home, charged as the
   flush write onto the new rank with the stale copies freed
   (``shard_counters["rehomes"]``). Faults stay consistent: the
   override target falls over to the next live rank, dead holders drop
   out of the multi-source set, and mirror bookkeeping is unchanged.

Every rank's page trace stays independently replayable: rank ``r``'s
``CxlTier`` records its own (port-tagged) op trace against its own
topology, and the rank's peer lane records a single-stream trace —
both must replay within 1% of the scalar oracle
(``repro.sim.engine.replay_page_trace``), exactly like the single-rank
tier. The serving engine consumes a ``ShardedTier`` through the same
surface as a ``CxlTier`` (``write_entry`` / ``read_entry`` / async
handles / ``advance`` / ``port_stats`` / ``counters``), so the
scheduler, flusher and fault-recovery paths compose unchanged.

All times are simulated nanoseconds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.core.tier import CxlTier, TierConfig, TierHandle, _stable_hash
from repro.sim.engine import (PAGE_ADVANCE, PAGE_READ, PAGE_READ_ASYNC,
                              FaultSchedule, OpHandle, PageStream)
from repro.sim.policy import LearnedPlacement

# media spec for the inter-rank peer-link lane: the hop crosses the CXL
# fabric into the owning rank's memory, so it times like a DRAM-class
# endpoint, not like the backing SSD media the fetch avoids
PEER_LINK_MEDIA = "dram"


class _ShardedTopoView:
    """Read-only topology facade over every rank's ports + peer lanes.

    The serving engine reads ``tier.topo.now`` / ``tier.topo.ports`` /
    ``tier.topo.ports_down()`` for telemetry; this view aggregates the
    per-rank topologies (and the peer-link lanes) behind the same three
    names so the engine's tick loop works unchanged on a sharded tier.
    """

    def __init__(self, tiers: List[CxlTier], peers: List[PageStream]):
        self._tiers = tiers
        self._peers = peers

    @property
    def ports(self) -> List[PageStream]:
        """Every rank's ports (rank-major) followed by the peer lanes."""
        out = [p for t in self._tiers for p in t.topo.ports]
        out.extend(self._peers)
        return out

    @property
    def now(self) -> float:
        """Furthest simulated clock across all ranks and peer lanes."""
        t = max(t.topo.now for t in self._tiers)
        if self._peers:
            t = max(t, max(p.now for p in self._peers))
        return t

    def ports_down(self) -> List[int]:
        """Globally-indexed down ports (rank-major port numbering)."""
        out, base = [], 0
        for t in self._tiers:
            out.extend(base + p for p in t.topo.ports_down())
            base += t.topo.n_ports
        return out


class ShardedTier:
    """N per-rank ``CxlTier`` port sets + peer-link lanes, one facade.

    Implements the ``CxlTier`` surface the serving engine and scheduler
    consume, with entry placement lifted to a cross-rank decision: each
    entry has a *home rank* (stable hash), is flushed once to that
    rank's ports, and is served to the other ranks over the home rank's
    peer-link lane on restore. The first cross-rank restore mirrors the
    entry to the neighboring rank, so losing the home copy (fault
    hot-remove) recovers from the mirror instead of reporting the key
    lost.

    Args:
        n_ranks: model-axis size (>= 2; use a plain ``CxlTier`` for 1).
        config: the per-rank :class:`TierConfig` (every rank gets an
            identical port set; the fault schedule is stripped and
            re-applied to ``fault_rank`` only).
        faults: optional :class:`FaultSchedule` applied to
            ``fault_rank``'s port set (port indices are rank-local).
        fault_rank: which rank's ports the schedule hits (default 0).
        peer_media: media spec for the peer-link lanes.
    """

    def __init__(self, n_ranks: int, config: TierConfig = TierConfig(),
                 *, faults: Optional[FaultSchedule] = None,
                 fault_rank: int = 0, peer_media: str = PEER_LINK_MEDIA):
        if n_ranks < 2:
            raise ValueError(f"ShardedTier needs n_ranks >= 2 (got "
                             f"{n_ranks}); use CxlTier for a single rank")
        if not 0 <= fault_rank < n_ranks:
            raise ValueError(f"fault_rank {fault_rank} out of range for "
                             f"{n_ranks} ranks")
        if faults is None:
            faults = config.faults
        self.n_ranks = int(n_ranks)
        self.fault_rank = int(fault_rank)
        base_cfg = dataclasses.replace(config, faults=None)
        self.ranks: List[CxlTier] = [
            CxlTier(dataclasses.replace(
                base_cfg, faults=faults if r == fault_rank else None))
            for r in range(n_ranks)]
        self.cfg = self.ranks[0].cfg   # replay params (media, sr, ...)
        self.peer_media = peer_media
        # one outbound peer-link lane per rank: rank r's lane carries the
        # KV shards r serves to the other ranks on a cross-rank restore
        self.peer: List[PageStream] = [
            PageStream(peer_media, sr=False, ds=False,
                       req_bytes=config.req_bytes,
                       dram_cache_bytes=config.dram_cache_bytes,
                       max_inflight=config.max_inflight)
            for _ in range(n_ranks)]
        # per-lane single-stream traces (replayable via replay_page_trace
        # with media=peer_media, sr=False, ds=False)
        self.peer_ops: List[List[tuple]] = [[] for _ in range(n_ranks)]
        self.peer_op_ns: List[List[float]] = [[] for _ in range(n_ranks)]
        self._peer_base: List[int] = [0] * n_ranks   # lane bump allocators
        self._peer_addr: List[Dict[object, Tuple[int, int]]] = [
            dict() for _ in range(n_ranks)]
        self._owner: Dict[object, int] = {}        # key -> primary rank
        self._holders: Dict[object, Set[int]] = {}  # key -> ranks w/ copy
        self._peer_pending: Dict[int, Tuple[int, OpHandle]] = {}
        # async multi-source companions: extra holder fetches + their
        # lane transfers riding one handle ("tier"/"link", rank, handle)
        self._companions: Dict[int, List[Tuple[str, int, object]]] = {}
        self.last_entry_failed = False
        self.topo = _ShardedTopoView(self.ranks, self.peer)
        # learned cross-rank homing state (placement="learned" only): the
        # shared policy classifies hot shared entries; per-rank restore
        # weights pick the re-home target (decayed like tier heat)
        self._policy: Optional[LearnedPlacement] = (
            LearnedPlacement(half_life_ns=config.heat_half_life_ns)
            if config.placement == "learned" else None)
        self._rank_weight: Dict[object, List[float]] = {}
        self._rank_weight_t: Dict[object, float] = {}
        self.shard_counters = {"peer_fetches": 0, "peer_fetch_ns": 0.0,
                               "peer_bytes": 0, "mirror_writes": 0,
                               "rank_remaps": 0, "peer_recoveries": 0,
                               "rehomes": 0, "multi_source_reads": 0}

    # ------------------------------------------------------------ helpers
    @staticmethod
    def entry_bytes(entry) -> int:
        """Payload bytes of a page-store entry (delegates to CxlTier)."""
        return CxlTier.entry_bytes(entry)

    def home_rank(self, key) -> int:
        """Stable home rank for ``key`` (cross-run-deterministic hash)."""
        return _stable_hash(key) % self.n_ranks

    def _resolve_owner(self, key) -> Optional[int]:
        """Rank currently serving ``key`` (remaps off dead copies).

        The recorded owner wins while its copy is live; when a
        hot-remove tears it, ownership migrates to any surviving holder
        (counted as a ``rank_remaps``) — the peer's mirror copy is what
        keeps the entry alive. Returns None when no rank holds it.
        """
        owner = self._owner.get(key)
        if owner is not None and self.ranks[owner].has_entry(key):
            return owner
        held = self._holders.get(key)
        candidates = sorted(held) if held is not None \
            else range(self.n_ranks)
        for r in candidates:
            if r != owner and self.ranks[r].has_entry(key):
                if owner is not None:
                    self.shard_counters["rank_remaps"] += 1
                self._owner[key] = r
                self._holders.setdefault(key, set()).add(r)
                return r
        return None

    def _live_rank(self, start: int) -> int:
        """First rank at/after ``start`` whose port set can still place.

        A rank whose whole topology was hot-removed has no serviceable
        media; placement falls over to the next live rank (rank-striped
        fallback). With every rank dead, returns ``start`` and lets the
        rank tier raise its own no-media error.
        """
        for step in range(self.n_ranks):
            cand = (start + step) % self.n_ranks
            t = self.ranks[cand]
            if len(t._down_ports) < t.topo.n_ports:
                return cand
        return start

    def _peer_span(self, rank: int, key, pbytes: int) -> Tuple[int, int]:
        """Lane address span for ``pbytes`` of ``key`` on ``rank``'s lane.

        Each lane has its own page-aligned bump allocator so repeated
        restores of the same hot entry re-cover the same lane range
        (warm link-side buffering), mirroring the per-port allocators of
        the rank tiers.
        """
        pbytes = max(int(pbytes), 1)
        cached = self._peer_addr[rank].get(key)
        if cached is not None and cached[1] == pbytes:
            return cached
        pg = self.cfg.page_bytes
        span = -(-pbytes // pg) * pg
        addr = self._peer_base[rank]
        self._peer_base[rank] += span
        self._peer_addr[rank][key] = (addr, pbytes)
        return addr, pbytes

    def _charge_peer(self, rank: int, kind: int, addr: int,
                     nbytes: int, ns: float) -> None:
        """Record one op on ``rank``'s peer-lane single-stream trace."""
        if len(self.peer_ops[rank]) < self.cfg.trace_cap:
            self.peer_ops[rank].append((kind, addr, nbytes))
            self.peer_op_ns[rank].append(float(ns))

    def _mirror(self, key, nbytes: int, owner: int) -> None:
        """Write the peer mirror copy (first cross-rank share only).

        The target is the nearest rank after the owner that still has a
        live port; ranks whose whole port set was hot-removed are
        skipped (no serviceable media to mirror onto).
        """
        holders = self._holders.setdefault(key, {owner})
        if len(holders) > 1:
            return
        for step in range(1, self.n_ranks):
            mirror = (owner + step) % self.n_ranks
            t = self.ranks[mirror]
            if len(t._down_ports) < t.topo.n_ports:
                t.write_entry(key, nbytes)
                holders.add(mirror)
                self.shard_counters["mirror_writes"] += 1
                return

    def _collective_pbytes(self, nbytes: int) -> int:
        """Link bytes for a collective restore: the non-owner ranks'
        shards, ``nbytes * (N - 1) / N``."""
        return max((int(nbytes) * (self.n_ranks - 1)) // self.n_ranks, 1)

    # ------------------------------------------------- learned re-homing
    def _note_rank_restore(self, key, nbytes: int,
                           req_rank: Optional[int]) -> None:
        """Feed one restore into the learned homing state.

        ``req_rank`` is the rank whose request drove the restore; the
        per-rank weights it accumulates (decayed by the tier's heat
        half-life) pick the re-home target. Restores with no requesting
        rank still train the hot/cold mixture."""
        now = self.topo.now
        self._policy.observe(key, now, int(nbytes))
        if req_rank is None:
            return
        if not 0 <= int(req_rank) < self.n_ranks:
            raise ValueError(f"req_rank {req_rank} out of range for "
                             f"{self.n_ranks} ranks")
        w = self._rank_weight.get(key)
        if w is None:
            w = self._rank_weight[key] = [0.0] * self.n_ranks
        hl = self.cfg.heat_half_life_ns
        if hl > 0.0:
            dt = max(0.0, now - self._rank_weight_t.get(key, now))
            decay = 0.5 ** (dt / hl)
            for r in range(self.n_ranks):
                w[r] *= decay
        w[int(req_rank)] += 1.0
        self._rank_weight_t[key] = now

    def _preferred_home(self, key) -> Optional[int]:
        """Restore-frequency-weighted home override for a hot entry.

        Returns the live rank whose requests restore ``key`` most, or
        None when the policy is off, the entry is not classified hot,
        or no per-rank demand has been observed — callers then keep the
        hash home / current owner."""
        if self._policy is None:
            return None
        w = self._rank_weight.get(key)
        if w is None or not any(w):
            return None
        if not self._policy.is_hot(key, self.topo.now):
            return None
        best = max(range(self.n_ranks), key=lambda r: w[r])
        return self._live_rank(best)

    def _live_holders(self, key, owner: int) -> List[int]:
        """Ranks currently holding a live copy of ``key`` (sorted)."""
        held = self._holders.get(key, {owner})
        return sorted(r for r in held if self.ranks[r].has_entry(key))

    # ---------------------------------------------------- blocking ops
    def write_entry(self, key, nbytes: int) -> float:
        """Flush an entry once, to its owning rank's port set.

        A re-flush keeps the same owner (stable segments, warm EP
        caches) and invalidates any stale mirror copies — the next
        cross-rank restore re-mirrors fresh pages. Returns the
        writer-held ns (the owning rank's slowest lane).
        """
        owner = self._resolve_owner(key)
        if owner is None:
            owner = self._live_rank(self.home_rank(key))
        pref = self._preferred_home(key)
        if pref is not None and pref != owner:
            # learned re-home: migrate the entry to the rank whose
            # requests restore it most; the flush below IS the charged
            # migration write, and the holder sweep frees stale copies
            owner = pref
            self.shard_counters["rehomes"] += 1
        for r in sorted(self._holders.get(key, ())):
            if r != owner:
                self.ranks[r].free_entry(key)
        ns = self.ranks[owner].write_entry(key, nbytes)
        self.last_entry_failed = self.ranks[owner].last_entry_failed
        self._owner[key] = owner
        self._holders[key] = {owner}
        return ns

    def read_entry(self, key, nbytes: int,
                   req_rank: Optional[int] = None) -> float:
        """Cross-rank demand restore: one media fetch + one link hop.

        The owning rank performs the only real media fetch; the other
        ``N - 1`` ranks' KV shards cross the owner's peer-link lane
        (``nbytes * (N - 1) / N`` at link speed), serialized after the
        media fetch — the returned stall is the sum. First share also
        mirrors the entry to the neighbor rank.

        ``req_rank`` tags the requesting rank for the learned homing
        policy (ignored otherwise); under ``placement="learned"`` a hot
        entry with multiple live copies is served multi-source instead
        (every holder fetches locally, missing shards split across the
        holders' lanes — see :meth:`_read_multi_source`).
        """
        owner = self._resolve_owner(key)
        if owner is None:
            # cold read of an unplaced key: CxlTier semantics (allocate
            # on the home rank and fetch) so read-before-write patterns
            # behave like the single-rank tier
            owner = self._live_rank(self.home_rank(key))
            self._owner[key] = owner
            self._holders.setdefault(key, set()).add(owner)
        if self._policy is not None:
            self._note_rank_restore(key, nbytes, req_rank)
            holders = self._live_holders(key, owner)
            if len(holders) > 1 and self._policy.is_hot(key, self.topo.now):
                return self._read_multi_source(key, nbytes, holders)
        ns = self.ranks[owner].read_entry(key, nbytes)
        failed = self.ranks[owner].last_entry_failed
        if failed:
            # transient/hot-remove on the owner: recover from a peer copy
            retry = self._resolve_owner(key)
            if retry is not None and retry != owner:
                ns = self.ranks[retry].read_entry(key, nbytes)
                failed = self.ranks[retry].last_entry_failed
                owner = retry
                if not failed:
                    self.shard_counters["peer_recoveries"] += 1
        self.last_entry_failed = failed
        if failed:
            return ns
        addr, pbytes = self._peer_span(owner, key,
                                       self._collective_pbytes(nbytes))
        link_ns = self.peer[owner].read(addr, pbytes)
        self._charge_peer(owner, PAGE_READ, addr, pbytes, link_ns)
        self.shard_counters["peer_fetches"] += 1
        self.shard_counters["peer_fetch_ns"] += link_ns
        self.shard_counters["peer_bytes"] += pbytes
        self._mirror(key, nbytes, owner)
        return ns + link_ns

    def _read_multi_source(self, key, nbytes: int,
                           holders: List[int]) -> float:
        """Collective restore of a hot entry from every live holder.

        Each holder fetches its local copy in parallel (stall is the
        max, not the sum — the fetches ride different ranks' ports) and
        the ``(N - H) / N`` of the payload held by no requester splits
        evenly across the holders' outbound lanes. With every rank
        holding a copy no peer bytes move at all. No mirror write is
        needed: multi-source only triggers with >= 2 live copies.
        """
        fetch: Dict[int, float] = {}
        ok: List[int] = []
        worst = 0.0
        for r in holders:
            ns = self.ranks[r].read_entry(key, nbytes)
            worst = max(worst, ns)
            if self.ranks[r].last_entry_failed:
                continue
            ok.append(r)
            fetch[r] = ns
        if not ok:
            self.last_entry_failed = True
            return worst
        h = len(ok)
        miss = max((int(nbytes) * (self.n_ranks - h)) // self.n_ranks, 0)
        stall = max(fetch.values())
        if miss > 0:
            share = -(-miss // h)
            left = miss
            for r in ok:
                pb = min(share, left)
                if pb <= 0:
                    break
                left -= pb
                addr, pb = self._peer_span(r, key, pb)
                link_ns = self.peer[r].read(addr, pb)
                self._charge_peer(r, PAGE_READ, addr, pb, link_ns)
                self.shard_counters["peer_fetches"] += 1
                self.shard_counters["peer_fetch_ns"] += link_ns
                self.shard_counters["peer_bytes"] += pb
                stall = max(stall, fetch[r] + link_ns)
        self.shard_counters["multi_source_reads"] += 1
        self.last_entry_failed = False
        return stall

    # ------------------------------------------------------- async ops
    def write_entry_async(self, key, nbytes: int) -> TierHandle:
        """Background flush to the owning rank (handle rank-tagged)."""
        owner = self._resolve_owner(key)
        if owner is None:
            owner = self._live_rank(self.home_rank(key))
        pref = self._preferred_home(key)
        if pref is not None and pref != owner:
            owner = pref
            self.shard_counters["rehomes"] += 1
        for r in sorted(self._holders.get(key, ())):
            if r != owner:
                self.ranks[r].free_entry(key)
        handle = self.ranks[owner].write_entry_async(key, nbytes)
        handle.rank = owner
        self._owner[key] = owner
        self._holders[key] = {owner}
        return handle

    def read_entry_async(self, key, nbytes: int,
                         req_rank: Optional[int] = None) -> TierHandle:
        """Non-blocking cross-rank restore.

        The owning rank's media fetch and the peer-link transfer are
        both issued without blocking; the handle completes only when
        the media lanes *and* the link op have landed (:meth:`poll`).
        The issuer pays only the issue-slot waits. ``req_rank`` and the
        learned multi-source path behave as in :meth:`read_entry`.
        """
        owner = self._resolve_owner(key)
        if owner is None:
            # cold read: CxlTier semantics, skipping dead ranks
            owner = self._live_rank(self.home_rank(key))
            self._owner[key] = owner
            self._holders.setdefault(key, set()).add(owner)
        if self._policy is not None:
            self._note_rank_restore(key, nbytes, req_rank)
            holders = self._live_holders(key, owner)
            if len(holders) > 1 and self._policy.is_hot(key, self.topo.now):
                return self._read_multi_source_async(key, nbytes, holders)
        handle = self.ranks[owner].read_entry_async(key, nbytes)
        handle.rank = owner
        if not handle.failed and self.ranks[owner].has_entry(key):
            addr, pbytes = self._peer_span(owner, key,
                                           self._collective_pbytes(nbytes))
            link = self.peer[owner].issue(PAGE_READ_ASYNC, addr, pbytes)
            self._charge_peer(owner, PAGE_READ_ASYNC, addr, pbytes,
                              link.wait_ns)
            handle.issue_wait_ns += link.wait_ns
            handle.done_ns = max(handle.done_ns, link.done_ns)
            self._peer_pending[id(handle)] = (owner, link)
            self.shard_counters["peer_fetches"] += 1
            self.shard_counters["peer_bytes"] += pbytes
            self._mirror(key, nbytes, owner)
        return handle

    def _read_multi_source_async(self, key, nbytes: int,
                                 holders: List[int]) -> TierHandle:
        """Async collective restore: all holder fetches + link shares
        ride one handle, completed only when every companion lands."""
        handle: Optional[TierHandle] = None
        ok: List[int] = []
        comps: List[Tuple[str, int, object]] = []
        for r in holders:
            h = self.ranks[r].read_entry_async(key, nbytes)
            h.rank = r
            if h.failed:
                if handle is None:
                    handle = h        # placeholder until a holder works
                continue
            if handle is None or handle.failed:
                handle = h
            else:
                handle.issue_wait_ns += h.issue_wait_ns
                handle.done_ns = max(handle.done_ns, h.done_ns)
                comps.append(("tier", r, h))
            ok.append(r)
        if not ok:
            return handle             # every holder refused at issue
        h_live = len(ok)
        miss = max((int(nbytes) * (self.n_ranks - h_live))
                   // self.n_ranks, 0)
        if miss > 0:
            share = -(-miss // h_live)
            left = miss
            for r in ok:
                pb = min(share, left)
                if pb <= 0:
                    break
                left -= pb
                addr, pb = self._peer_span(r, key, pb)
                link = self.peer[r].issue(PAGE_READ_ASYNC, addr, pb)
                self._charge_peer(r, PAGE_READ_ASYNC, addr, pb,
                                  link.wait_ns)
                handle.issue_wait_ns += link.wait_ns
                handle.done_ns = max(handle.done_ns, link.done_ns)
                comps.append(("link", r, link))
                self.shard_counters["peer_fetches"] += 1
                self.shard_counters["peer_bytes"] += pb
        if comps:
            self._companions[id(handle)] = comps
        self.shard_counters["multi_source_reads"] += 1
        return handle

    def poll(self, handle: TierHandle) -> bool:
        """True once the rank op, its peer-link transfer, and any
        multi-source companion fetches/transfers have all landed."""
        rank = getattr(handle, "rank", 0)
        done = self.ranks[rank].poll(handle)
        pend = self._peer_pending.get(id(handle))
        if pend is not None:
            lane_rank, link = pend
            if self.peer[lane_rank].poll(link):
                del self._peer_pending[id(handle)]
            else:
                done = False
                handle.retired = False
        comps = self._companions.get(id(handle))
        if comps is not None:
            remaining = [
                (kind, r, h) for kind, r, h in comps
                if not (self.ranks[r].poll(h) if kind == "tier"
                        else self.peer[r].poll(h))]
            if remaining:
                self._companions[id(handle)] = remaining
                done = False
                handle.retired = False
            else:
                del self._companions[id(handle)]
        return done

    def inflight_ops(self) -> int:
        """Outstanding async page ops across every rank + peer lane."""
        return (sum(t.inflight_ops() for t in self.ranks)
                + sum(p.inflight_depth() for p in self.peer))

    # ----------------------------------------------------- entry state
    def free_entry(self, key) -> int:
        """Release every rank's copy of ``key``; returns freed bytes."""
        freed = 0
        held = self._holders.pop(key, None)
        ranks = sorted(held) if held else range(self.n_ranks)
        for r in ranks:
            freed += self.ranks[r].free_entry(key)
        self._owner.pop(key, None)
        for r in range(self.n_ranks):
            self._peer_addr[r].pop(key, None)
        self._rank_weight.pop(key, None)
        self._rank_weight_t.pop(key, None)
        if self._policy is not None:
            self._policy.forget(key)
        return freed

    def has_entry(self, key) -> bool:
        """True while *any* rank still holds live segments for ``key``."""
        held = self._holders.get(key)
        ranks = held if held else range(self.n_ranks)
        return any(self.ranks[r].has_entry(key) for r in ranks)

    def speculative_read(self, key, nbytes: int) -> None:
        """MemSpecRd the entry's ranges on its owning rank."""
        owner = self._resolve_owner(key)
        if owner is not None:
            self.ranks[owner].speculative_read(key, nbytes)

    # -------------------------------------------------- time + faults
    def advance(self, dt_ns: float) -> None:
        """Tick every rank's topology and every peer lane by ``dt_ns``.

        Peer lanes record their advances as single-stream
        ``PAGE_ADVANCE`` ops so the lane traces replay with the same
        idle windows they saw live.
        """
        for t in self.ranks:
            t.advance(dt_ns)
        for r, lane in enumerate(self.peer):
            lane.advance(float(dt_ns))
            self._charge_peer(r, PAGE_ADVANCE, 0, int(dt_ns), 0.0)

    def poll_faults(self) -> List[object]:
        """Fold fired fault events on every rank (lost keys pooled)."""
        out = []
        for t in self.ranks:
            out.extend(t.poll_faults())
        return out

    def take_lost_keys(self) -> List[object]:
        """Drain rank-lost keys; keys alive on a peer rank recover.

        A key whose home copy was torn by a hot-remove but that has a
        mirror on a surviving rank is *not* reported lost — ownership
        remaps to the survivor (``rank_remaps``) and the serving layer
        never sees the fault. Only keys with no surviving copy anywhere
        propagate to the engine's recovery path.
        """
        lost = []
        for r, t in enumerate(self.ranks):
            for key in t.take_lost_keys():
                held = self._holders.get(key)
                if held is not None:
                    held.discard(r)
                if self._resolve_owner(key) is not None:
                    self.shard_counters["peer_recoveries"] += 1
                    continue
                self._owner.pop(key, None)
                self._holders.pop(key, None)
                self._rank_weight.pop(key, None)
                self._rank_weight_t.pop(key, None)
                if self._policy is not None:
                    self._policy.forget(key)
                lost.append(key)
        return lost

    # ---------------------------------------------------- aggregation
    def admit_store(self) -> bool:
        """Flush admission: conservative AND across every rank's ports."""
        verdicts = [t.admit_store() for t in self.ranks]
        return all(verdicts)

    def sr_hit_rate(self) -> float:
        """Aggregate EP internal-DRAM hit rate over every rank's reads."""
        ports = [p for t in self.ranks for p in t.topo.ports]
        reads = sum(p.ep.stats["reads"] for p in ports)
        hits = sum(p.ep.stats["hits"] for p in ports)
        return hits / reads if reads else 0.0

    def store_occupancy(self) -> float:
        """Worst staging-stack fill fraction across every rank."""
        return max(t.store_occupancy() for t in self.ranks)

    @property
    def counters(self) -> Dict[str, object]:
        """Summed per-rank tier counters + the shard-level counters.

        Built on demand (one small dict per call): every ``CxlTier``
        counter key holds the sum over ranks, and the shard-specific
        keys (``peer_fetches``, ``peer_fetch_ns``, ``peer_bytes``,
        ``mirror_writes``, ``rank_remaps``, ``peer_recoveries``,
        ``rehomes``, ``multi_source_reads``) ride alongside.
        """
        out: Dict[str, object] = {}
        for t in self.ranks:
            for k, v in t.counters.items():
                out[k] = out.get(k, 0) + v
        out.update(self.shard_counters)
        return out

    def port_stats(self) -> List[Dict[str, object]]:
        """Per-port telemetry across ranks, each row ``rank``-tagged.

        Rows keep their rank-local ``port`` index (fault schedules and
        placement are rank-local) and gain a ``rank`` key; peer lanes
        are not listed (they carry no EP/QoS state worth a row).
        """
        rows = []
        for r, t in enumerate(self.ranks):
            for row in t.port_stats():
                row["rank"] = r
                rows.append(row)
        return rows

    def snapshot(self) -> Dict[str, object]:
        """Flat dict of tier state (CxlTier-shaped, rank-aggregated).

        Every key a ``CxlTier.snapshot()`` exposes is present with the
        value summed (counters), maxed (clocks/occupancy) or aggregated
        (rates) over ranks, so the serving CLI's tier stats line and the
        bench artifact schema work unchanged; the shard-specific extras
        (``n_ranks``, the peer-link counters, per-lane trace lengths)
        ride alongside.
        """
        c = self.counters
        ports = self.port_stats()
        per = [t.snapshot() for t in self.ranks]
        snap = {
            "media": per[0]["media"],
            "topology": list(self.cfg.port_medias),
            "placement": self.cfg.placement if self.cfg.tagged else None,
            "sr_enabled": self.cfg.sr_enabled,
            "ds_enabled": self.cfg.ds_enabled,
            "now_ns": self.topo.now,
            "reads": c["reads"], "writes": c["writes"],
            "prefetches": c["prefetches"],
            "read_ns": c["read_ns"], "write_ns": c["write_ns"],
            "deferred_admits": c["deferred_admits"],
            "promotions": c["promotions"], "demotions": c["demotions"],
            "migrate_ns": c["migrate_ns"],
            "frees": c["frees"], "freed_bytes": c["freed_bytes"],
            "segment_reuses": c["reused_segments"],
            "async_reads": c["async_reads"],
            "async_writes": c["async_writes"],
            "issue_wait_ns": c["issue_wait_ns"],
            "inflight_ops": self.inflight_ops(),
            "sr_hit_rate": self.sr_hit_rate(),
            "ep_prefetches": sum(s["ep_prefetches"] for s in per),
            "gc_events": sum(s["gc_events"] for s in per),
            "staging_occupancy": self.store_occupancy(),
            "ds": [s["ds"] for s in per],
            "ports": ports,
            "trace_ops": sum(s["trace_ops"] for s in per),
            "trace_truncated": any(s["trace_truncated"] for s in per),
            "fault_ops": c["fault_ops"],
            "fault_retries": sum(s["fault_retries"] for s in per),
            "fault_failures": sum(s["fault_failures"] for s in per),
            "fault_backoff_ns": sum(s["fault_backoff_ns"] for s in per),
            "lost_entries": c["lost_entries"],
            "lost_bytes": c["lost_bytes"],
            "ports_down": self.topo.ports_down(),
            "noop_frees": c["noop_frees"],
            "dead_segment_frees": c["dead_segment_frees"],
            # shard extras
            "n_ranks": self.n_ranks,
            "peer_fetches": c["peer_fetches"],
            "peer_fetch_ns": c["peer_fetch_ns"],
            "peer_bytes": c["peer_bytes"],
            "mirror_writes": c["mirror_writes"],
            "rank_remaps": c["rank_remaps"],
            "peer_recoveries": c["peer_recoveries"],
            "rehomes": c["rehomes"],
            "multi_source_reads": c["multi_source_reads"],
            "peer_trace_ops": [len(ops) for ops in self.peer_ops],
        }
        return snap
