"""Speculative read — prefetching layer parameters from the expansion tier.

The paper's SR unit pre-shares upcoming load addresses with the endpoint
(`MemSpecRd`) so the EP's internal DRAM already holds the page when the real
read arrives. The TPU analogue (DESIGN.md §4.2): issue the all-gather of
layer *i+depth* while layer *i* computes so ICI transfers hide behind the
MXU. Two execution modes:

* ``mode="train"`` — the body is rematerialized for the backward pass, so
  gathered weights must NOT live in the scan carry (they would be saved as
  residuals and defeat the pool tier). Overlap is instead exposed via scan
  ``unroll=depth+1``: the unrolled body lets XLA's latency-hiding scheduler
  start iteration i+1's gather during iteration i's compute.

* ``mode="infer"`` — no backward, so we run the *literal* SR mechanism: the
  carry holds ``depth`` gathered layer buffers (the EP-DRAM prefetch slots);
  iteration i computes with slot 0 and issues the gather for layer i+depth.

``granularity`` mirrors MemSpecRd aggregation (256B..1KB): leaves are split
into g chunks gathered separately, trading per-collective overhead for finer
overlap opportunities.

Body contract: ``body(x, layer_params, extra_slice) -> (y, out_slice)`` where
``extra_slice``/``out_slice`` come from/stack into a leading layer axis
(e.g. per-layer KV cache in/out). Use ``None`` when unused.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel import sharding as shlib


def _tree_index(stacked: Any, i) -> Any:
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, axis=0, keepdims=False),
        stacked)


def strip_stack_axis(specs: Any) -> Any:
    """Per-layer specs from stacked specs (drop the leading layer axis)."""
    from jax.sharding import PartitionSpec as P
    return jax.tree_util.tree_map(
        lambda s: P(*tuple(s)[1:]), specs,
        is_leaf=lambda x: isinstance(x, P))


def materialize(layer: Any, layer_specs: Any, granularity: int = 1) -> Any:
    """Gather one layer's params to their expanded (FSDP-axis-free) form.

    This is the speculative-read *load*: a sharding constraint whose
    placement in the schedule (ahead of the consumer) is what hides the
    pool-tier latency.
    """
    gathered = shlib.gathered_specs(layer_specs)
    if granularity <= 1:
        return shlib.constrain(layer, gathered)

    from jax.sharding import PartitionSpec as P

    def gather_leaf(x, spec):
        if not hasattr(x, "shape") or x.ndim == 0 or \
                x.shape[0] % granularity:
            return jax.lax.with_sharding_constraint(x, spec) \
                if hasattr(x, "shape") else x
        sub = P(None, *tuple(spec))
        chunked = x.reshape((granularity, x.shape[0] // granularity)
                            + x.shape[1:])
        out = jax.lax.with_sharding_constraint(chunked, sub)
        return out.reshape(x.shape)

    flat_l, treedef = jax.tree_util.tree_flatten(layer)
    flat_s = treedef.flatten_up_to(gathered)
    return treedef.unflatten([gather_leaf(x, s)
                              for x, s in zip(flat_l, flat_s)])


def stream_layers(body: Callable, x0: Any, stacked_params: Any,
                  stacked_specs: Any, *, n_layers: int,
                  prefetch_depth: int = 1, granularity: int = 1,
                  mode: str = "train", remat: bool = True,
                  stacked_extras: Any = None,
                  unroll: int = 0, remat_policy: str = "none"
                  ) -> Tuple[Any, Any]:
    """Run layers under the SR pipeline; returns (final_carry, stacked_outs).

    unroll > 0 overrides the scan unroll factor (unroll == n_layers fully
    unrolls — used by the roofline cost extraction so HLO op counts are
    exact; XLA cost analysis visits a while body once).
    """
    layer_specs = strip_stack_axis(stacked_specs)

    if mode == "infer" and prefetch_depth > 0:
        return _stream_infer(body, x0, stacked_params, layer_specs,
                             n_layers=n_layers, depth=prefetch_depth,
                             granularity=granularity,
                             stacked_extras=stacked_extras,
                             unroll=unroll)

    # training path: materialize inside the (remat'd) body; cross-iteration
    # overlap comes from unrolling (saved residuals stay pool-sharded).
    def scan_body(x, xs):
        layer_raw, extra = xs
        layer = materialize(layer_raw, layer_specs, granularity)
        y, out = body(x, layer, extra)
        return y, out

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        scan_body = jax.checkpoint(scan_body, policy=policy)
    if unroll <= 0:
        unroll = max(1, prefetch_depth + 1) if mode == "train" else 1
    x, outs = jax.lax.scan(scan_body, x0, (stacked_params, stacked_extras),
                           unroll=min(unroll, n_layers))
    return x, outs


def _stream_infer(body, x0, stacked_params, layer_specs, *, n_layers,
                  depth, granularity, stacked_extras, unroll: int = 0):
    """Literal SR: carry holds `depth` prefetched (gathered) layer buffers."""
    depth = min(depth, n_layers)
    bufs = tuple(
        materialize(_tree_index(stacked_params, i), layer_specs, granularity)
        for i in range(depth))

    def scan_body(carry, xs):
        i, extra = xs
        x, bufs = carry
        cur = bufs[0]
        y, out = body(x, cur, extra)
        # issue the speculative read for layer i+depth (wraps at the end;
        # tail gathers are idle SR slots past the end of the trace)
        nxt_idx = jax.lax.rem(i + depth, jnp.int32(n_layers))
        nxt = materialize(_tree_index(stacked_params, nxt_idx), layer_specs,
                          granularity)
        return (y, bufs[1:] + (nxt,)), out

    (x, _), outs = jax.lax.scan(
        scan_body, (x0, bufs),
        (jnp.arange(n_layers, dtype=jnp.int32), stacked_extras),
        unroll=min(unroll, n_layers) if unroll > 0 else 1)
    return x, outs
