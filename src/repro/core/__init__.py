"""JAX-runtime analogues of the paper's mechanisms (the SYSTEM half).

HDM placement (``hdm``), speculative read (``speculative_read``),
deterministic store (``deterministic_store``), the DevLoad QoS machine
(``qos``) and the CXL-timed serving memory tier (``tier``).

``repro.core.tier`` is the bridge between the two halves: the serving
engine's page traffic timed by the ``repro.sim`` controller/endpoint
model. Re-exported lazily (PEP 562): tier imports repro.sim.engine,
whose controller imports repro.core.qos — an eager import here would
close that cycle whenever repro.sim loads first.
"""


def __getattr__(name):
    """Lazy re-export of the tier API (see module docstring)."""
    if name in ("CxlTier", "TierConfig"):
        from repro.core import tier

        return getattr(tier, name)
    if name == "ShardedTier":
        from repro.core import sharded_tier

        return sharded_tier.ShardedTier
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
