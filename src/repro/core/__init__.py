# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# repro.core.tier is the bridge between the two halves: the serving
# engine's page traffic timed by the repro.sim controller/endpoint
# model. Re-exported lazily (PEP 562): tier imports repro.sim.engine,
# whose controller imports repro.core.qos — an eager import here would
# close that cycle whenever repro.sim loads first.


def __getattr__(name):
    if name in ("CxlTier", "TierConfig"):
        from repro.core import tier

        return getattr(tier, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
