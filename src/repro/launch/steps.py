"""Step builders: train_step / prefill_step / serve_step with shardings.

This is the seam between the model zoo and the distributed runtime: every
launcher (train.py, serve.py, dryrun.py) and benchmark obtains its jitted
step, input ShapeDtypeStructs, and in/out shardings from here, so the
sharding story is defined exactly once.

The paper's mechanisms appear as:
  * params/optimizer pool placement (HDMStore tier map),
  * the SR stream inside loss_fn/decode_step (speculative read),
  * gradient out-shardings pinned to pool specs => backward emits
    reduce-scatter, never a materialized full gradient (deterministic
    store), optimizer update runs on the shards.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, RunConfig, ShapeConfig)
from repro.core import deterministic_store as ds
from repro.core.hdm import HDMStore
from repro.models import model as M
from repro.models.layers import pdtype
from repro.optim import adamw
from repro.optim import compression
from repro.parallel import sharding as shlib


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    residuals: Optional[Any]  # int8-EF residuals (grad_compression only)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — the dry-run stand-ins; also used to build
# real batches in tests with tree_map over random bits)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                rc: RunConfig) -> Dict[str, Any]:
    """Model inputs for the step kind, as ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, cfg.n_codebooks, S) if cfg.family == "audio" else (B, S)
    i32 = jnp.int32

    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct(tok_shape, i32),
               "labels": jax.ShapeDtypeStruct(tok_shape, i32)}
    elif shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct(tok_shape, i32)}
    else:  # decode: one new token against a KV cache of S
        one = (B, cfg.n_codebooks, 1) if cfg.family == "audio" else (B, 1)
        out = {"tokens": jax.ShapeDtypeStruct(one, i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_tokens, cfg.d_model), pdtype(cfg))
    return out


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                rc: RunConfig) -> Dict[str, P]:
    dp = ("pod", "data") if rc.mesh.multi_pod else "data"
    if shape.global_batch == 1:
        dp = None  # long-context single-stream: no batch parallelism

    def spec(path, leaf):
        return P(*([dp] + [None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(
        spec, input_specs(cfg, shape, rc))


# ---------------------------------------------------------------------------
# state construction (shapes first — dry-run never allocates)
# ---------------------------------------------------------------------------


def state_shapes(cfg: ModelConfig, rc: RunConfig,
                 opt_cfg: adamw.AdamWConfig) -> TrainState:
    params = jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(functools.partial(adamw.init, cfg=opt_cfg), params)
    residuals = None
    if rc.grad_compression == "int8_ef":
        residuals = jax.eval_shape(compression.init_residuals, params)
    return TrainState(params=params, opt=opt, residuals=residuals)


def state_specs(cfg: ModelConfig, rc: RunConfig,
                state: TrainState) -> TrainState:
    pspecs = shlib.param_specs(
        state.params, tier=rc.param_tier,
        multi_pod_fsdp=rc.mesh.multi_pod)
    ospecs = adamw.opt_specs(
        shlib.param_specs(state.params, tier=rc.optimizer_tier,
                          multi_pod_fsdp=rc.mesh.multi_pod),
        state.opt)
    rspecs = pspecs if state.residuals is not None else None
    return TrainState(params=pspecs, opt=ospecs, residuals=rspecs)


def shardings(mesh: Mesh, specs: Any) -> Any:
    return shlib.shardings_from_specs(mesh, specs)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, rc: RunConfig,
                     opt_cfg: adamw.AdamWConfig):
    """Returns step(state, batch) -> (state, metrics), pure and jittable."""

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state.params
        pspecs = shlib.param_specs(params, tier=rc.param_tier,
                                   multi_pod_fsdp=rc.mesh.multi_pod)

        def lf(p, b):
            return M.loss_fn(p, cfg, rc, b, pspecs, mode="train")

        if rc.microbatches > 1:
            loss, grads = _accumulated_grads(lf, params, batch,
                                             rc.microbatches)
        else:
            loss, grads = jax.value_and_grad(lf)(params, batch)

        # deterministic store: gradients complete as pool shards
        grads = ds.apply_ds(grads, pspecs, enabled=rc.ds_enabled)

        residuals = state.residuals
        if residuals is not None:
            grads, residuals = compression.compress_grads(grads, residuals)

        new_params, new_opt, om = adamw.update(grads, state.opt, params,
                                               opt_cfg)
        new_params = shlib.constrain(new_params, pspecs)  # stay in the pool
        metrics = {"loss": loss, **om}
        return TrainState(new_params, new_opt, residuals), metrics

    return step


def _accumulated_grads(lf, params, batch, n_micro: int):
    """Gradient accumulation over leading-batch microbatch splits."""
    def split(x):
        b = x.shape[0]
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    micro = jax.tree_util.tree_map(split, batch)
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(lf)(params, mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (loss_acc + loss, g_acc), None

    (loss, grads), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), micro)
    inv = 1.0 / n_micro
    grads = jax.tree_util.tree_map(
        lambda g, p: (g * inv).astype(p.dtype), grads, params)
    return loss * inv, grads


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, rc: RunConfig):
    def step(params, batch):
        pspecs = shlib.param_specs(params, tier=rc.param_tier,
                                   multi_pod_fsdp=rc.mesh.multi_pod)
        return M.prefill_step(params, cfg, rc, batch, pspecs)
    return step


def build_serve_step(cfg: ModelConfig, rc: RunConfig):
    """One decode step: (params, cache, tokens) -> (logits, cache)."""
    def step(params, cache, tokens):
        pspecs = shlib.param_specs(params, tier=rc.param_tier,
                                   multi_pod_fsdp=rc.mesh.multi_pod)
        return M.decode_step(params, cfg, rc, tokens, cache, pspecs)
    return step


# ---------------------------------------------------------------------------
# jit assembly for a (cfg, shape, mesh) cell — used by dryrun and drivers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LoweredCell:
    kind: str
    jitted: Any
    args: Tuple        # ShapeDtypeStructs (or arrays) in call order


def assemble(cfg: ModelConfig, shape: ShapeConfig, rc: RunConfig,
             mesh: Mesh, opt_cfg: Optional[adamw.AdamWConfig] = None
             ) -> LoweredCell:
    """Build the jitted step + abstract args for one dry-run cell."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        learning_rate=rc.learning_rate, weight_decay=rc.weight_decay,
        grad_clip=rc.grad_clip)
    ispecs = input_specs(cfg, shape, rc)
    bspecs = batch_specs(cfg, shape, rc)
    bshard = shlib.shardings_from_specs(mesh, bspecs)

    if shape.kind == "train":
        st_shapes = state_shapes(cfg, rc, opt_cfg)
        st_specs = state_specs(cfg, rc, st_shapes)
        st_shard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), st_specs,
            is_leaf=lambda x: isinstance(x, P))
        step = build_train_step(cfg, rc, opt_cfg)
        metric_shard = NamedSharding(mesh, P())
        jitted = jax.jit(step,
                         in_shardings=(st_shard, bshard),
                         out_shardings=(st_shard, metric_shard),
                         donate_argnums=(0,))
        return LoweredCell("train", jitted, (st_shapes, ispecs))

    pshapes = jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg))
    pspecs = shlib.param_specs(pshapes, tier=rc.param_tier,
                               multi_pod_fsdp=rc.mesh.multi_pod)
    pshard = shlib.shardings_from_specs(mesh, pspecs)

    if shape.kind == "prefill":
        step = build_prefill_step(cfg, rc)
        jitted = jax.jit(step, in_shardings=(pshard, bshard),
                         out_shardings=NamedSharding(mesh, P()))
        return LoweredCell("prefill", jitted, (pshapes, ispecs))

    # decode
    cache = M.cache_init(cfg, rc, shape.global_batch, max_seq=shape.seq_len,
                         as_shape=True)
    cspecs = M.cache_specs(cfg, rc, shape.global_batch)
    cshard = shlib.shardings_from_specs(mesh, cspecs)
    tshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), batch_specs(cfg, shape, rc))
    step = build_serve_step(cfg, rc)
    jitted = jax.jit(step,
                     in_shardings=(pshard, cshard, tshard["tokens"]),
                     out_shardings=(NamedSharding(mesh, P()), cshard),
                     donate_argnums=(1,))
    return LoweredCell("decode", jitted,
                       (pshapes, cache, ispecs["tokens"]))
