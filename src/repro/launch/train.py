"""Training driver: data -> step -> telemetry -> checkpoint, fault-aware.

The paper's controller appears here as the *between-step* adaptation loop
(DESIGN.md §4.4): step variants are pre-compiled for a ladder of
(sr_prefetch_depth, sr_granularity) settings; per-step telemetry (wall
time vs roofline expectation, staging occupancy) drives the DevLoad state
machine which picks the active variant — exactly the queue logic's
granularity ladder, at step granularity, because XLA programs are static.

Usage (smoke scale, CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import registry
from repro.configs.base import (MeshConfig, ModelConfig, RunConfig, SHAPES,
                                ShapeConfig, PEAK_FLOPS_BF16)
from repro.core.qos import RuntimeQoS, StepTelemetry
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.runtime.fault_tolerance import Heartbeat, StragglerMitigator


def build_variants(cfg: ModelConfig, rc: RunConfig, mesh,
                   opt_cfg: adamw.AdamWConfig, ladder=None) -> Dict:
    """Pre-compiled step variants keyed by (depth, granularity)."""
    ladder = ladder or [(0, 1), (1, 1), (2, 1), (1, 2)]
    variants = {}
    for depth, gran in ladder:
        rc_v = dataclasses.replace(rc, sr_prefetch_depth=depth,
                                   sr_granularity=gran)
        variants[(depth, gran)] = jax.jit(
            steps_lib.build_train_step(cfg, rc_v, opt_cfg),
            donate_argnums=(0,))
    return variants


def train(arch: str, *, smoke: bool = True, steps: int = 20,
          shape_name: str = "train_4k", ckpt_dir: Optional[str] = None,
          global_batch: int = 8, seq_len: int = 64,
          log_every: int = 5, resume: bool = False) -> Dict:
    cfg = registry.smoke(arch) if smoke else registry.get(arch)
    base_shape = SHAPES[shape_name]
    shape = (dataclasses.replace(base_shape, global_batch=global_batch,
                                 seq_len=seq_len) if smoke else base_shape)
    mesh = make_host_mesh() if smoke else make_production_mesh()
    rc = RunConfig(model=cfg, shape=shape, mesh=MeshConfig())
    opt_cfg = adamw.AdamWConfig(learning_rate=rc.learning_rate,
                                total_steps=max(steps, 10))

    with jax.set_mesh(mesh):
        params = M.init_model(jax.random.PRNGKey(rc.seed), cfg)
        opt = adamw.init(params, opt_cfg)
        state = steps_lib.TrainState(params, opt, None)

        data_cfg = DataConfig(
            vocab_size=cfg.vocab_size, global_batch=shape.global_batch,
            seq_len=shape.seq_len, seed=rc.seed,
            n_codebooks=cfg.n_codebooks if cfg.family == "audio" else 0,
            vision_tokens=cfg.n_vision_tokens if cfg.family == "vlm" else 0,
            d_model=cfg.d_model)

        ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        start_step = 0
        if ckpt and resume and ckpt.latest_step() is not None:
            start_step, state, extra = ckpt.restore()
            print(f"[train] resumed from step {start_step}")

        pipe = Pipeline(data_cfg, start_step=start_step)
        variants = build_variants(cfg, rc, mesh, opt_cfg)
        qos = RuntimeQoS(list(variants))
        active = (rc.sr_prefetch_depth, rc.sr_granularity)

        # roofline expectation for the telemetry's service ratio
        tokens = shape.global_batch * shape.seq_len
        exp_s = 6 * cfg.n_active_params() * tokens / (
            mesh.devices.size * PEAK_FLOPS_BF16)

        hb = Heartbeat(n_workers=1)
        strag = StragglerMitigator()
        history = []
        t_prev: Optional[float] = None
        for _ in range(steps):
            step_idx, batch = next(pipe)
            t0 = time.time()
            state, metrics = variants[active](state, batch)
            loss = float(metrics["loss"])    # sync point
            dt = time.time() - t0
            hb.stamp(0, step_idx, dt)
            strag.assess(hb.step_times())
            active = qos.observe(StepTelemetry(
                step=step_idx, wall_time_s=dt, expected_time_s=exp_s,
                staging_occupancy=0.0))
            if active not in variants:
                active = min(variants, key=lambda v: abs(v[0] - active[0]))
            history.append({"step": step_idx, "loss": loss, "dt": dt,
                            "variant": active})
            if step_idx % log_every == 0:
                print(f"[train] step={step_idx} loss={loss:.4f} "
                      f"dt={dt*1e3:.0f}ms variant={active}", flush=True)
            if ckpt and step_idx and step_idx % 50 == 0:
                ckpt.save(step_idx, state, extra=pipe.state())
        if ckpt:
            ckpt.save(steps - 1 + start_step, state, extra=pipe.state(),
                      blocking=True)
        pipe.close()
    return {"history": history,
            "final_loss": history[-1]["loss"] if history else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                shape_name=args.shape, ckpt_dir=args.ckpt_dir,
                resume=args.resume, global_batch=args.global_batch,
                seq_len=args.seq_len)
    print(f"[train] done: final_loss={out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
