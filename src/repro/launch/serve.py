"""Serving driver: batched requests through the tiered paged engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def serve(arch: str, *, smoke: bool = True, n_requests: int = 8,
          n_slots: int = 4, max_seq: int = 128, max_new: int = 12,
          prompt_len: int = 6, seed: int = 0):
    cfg = registry.smoke(arch) if smoke else registry.get(arch)
    mesh = make_host_mesh() if smoke else make_production_mesh()
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    with jax.set_mesh(mesh):
        params = M.init_model(jax.random.PRNGKey(seed), cfg)
        engine = ServingEngine(params, cfg, rc, n_slots=n_slots,
                               max_seq=max_seq)
        import numpy as np
        rng = np.random.default_rng(seed)
        for rid in range(n_requests):
            prompt = rng.integers(1, cfg.vocab_size,
                                  prompt_len).tolist()
            engine.submit(Request(rid=rid, prompt=prompt,
                                  max_new_tokens=max_new))
        t0 = time.time()
        finished = engine.run()
        dt = time.time() - t0
    tput = engine.stats["decode_tokens"] / dt if dt > 0 else 0.0
    print(f"[serve] {len(finished)}/{n_requests} requests, "
          f"{engine.stats['decode_tokens']} tokens in {dt:.1f}s "
          f"({tput:.1f} tok/s; {engine.stats['prefill_dispatches']} prefill"
          f" + {engine.stats['decode_dispatches']} decode dispatches, "
          f"{engine.stats['prefix_hits']} prefix hits), flushed pages for "
          f"{engine.stats['flushes']} requests, host tier holds "
          f"{len(engine.store.pages)} retired caches "
          f"({engine.store.bytes / 1024:.0f} KiB, "
          f"{engine.store.evictions} evictions)")
    return engine, finished


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, n_requests=args.requests,
          n_slots=args.slots, max_new=args.max_new)


if __name__ == "__main__":
    main()
