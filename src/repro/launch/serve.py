"""Serving driver: batched requests through the tiered paged engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --requests 8

``--cxl-media`` attaches the CXL-timed memory tier: page flushes and
prefix restores are charged against the simulated endpoint and the
restore stall / SR hit rate are reported alongside throughput.
``--cxl-topology dram,ssd-fast`` attaches a multi-root-port tier
instead (``--cxl-placement`` picks striped / hashed / hotness) and adds
a per-port stats line. ``--cxl-async`` switches the tier to
completion-based async I/O (restores overlap decode instead of stalling
the batch) and ``--preempt-policy swap|recompute`` enables preemptive
scheduling under slot pressure; both add a scheduler stats line.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.core.tier import CxlTier, TierConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def serve(arch: str, *, smoke: bool = True, n_requests: int = 8,
          n_slots: int = 4, max_seq: int = 128, max_new: int = 12,
          prompt_len: int = 6, seed: int = 0,
          cxl_media: str = "", cxl_sr: bool = True,
          cxl_topology: str = "", cxl_placement: str = "striped",
          cxl_async: bool = False, preempt_policy: str = "none"):
    """Serve ``n_requests`` random prompts through the tiered engine.

    ``cxl_media`` attaches a single-port CXL-timed tier; ``cxl_topology``
    (comma-separated media bins, e.g. ``"dram,ssd-fast"``) attaches a
    multi-root-port tier instead, with ``cxl_placement`` choosing how
    entries spread across the ports (striped / hashed / hotness).
    ``cxl_async`` switches restores and flushes to completion-based
    async tier I/O (media latency hidden behind decode);
    ``preempt_policy`` (``swap`` / ``recompute``) lets the scheduler
    evict low-priority slots under pressure. Returns
    ``(engine, finished_requests)``.
    """
    cfg = registry.smoke(arch) if smoke else registry.get(arch)
    mesh = make_host_mesh() if smoke else make_production_mesh()
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    tier = None
    if cxl_topology:
        tier = CxlTier(TierConfig(
            topology=tuple(m.strip() for m in cxl_topology.split(",")),
            placement=cxl_placement, sr_enabled=cxl_sr))
    elif cxl_media:
        tier = CxlTier(TierConfig(media=cxl_media, sr_enabled=cxl_sr))
    with jax.set_mesh(mesh):
        params = M.init_model(jax.random.PRNGKey(seed), cfg)
        engine = ServingEngine(params, cfg, rc, n_slots=n_slots,
                               max_seq=max_seq, cxl_tier=tier,
                               cxl_async=cxl_async,
                               preempt_policy=preempt_policy)
        import numpy as np
        rng = np.random.default_rng(seed)
        for rid in range(n_requests):
            prompt = rng.integers(1, cfg.vocab_size,
                                  prompt_len).tolist()
            engine.submit(Request(rid=rid, prompt=prompt,
                                  max_new_tokens=max_new))
        t0 = time.time()
        finished = engine.run()
        dt = time.time() - t0
    tput = engine.stats["decode_tokens"] / dt if dt > 0 else 0.0
    print(f"[serve] {len(finished)}/{n_requests} requests, "
          f"{engine.stats['decode_tokens']} tokens in {dt:.1f}s "
          f"({tput:.1f} tok/s; {engine.stats['prefill_dispatches']} prefill"
          f" + {engine.stats['decode_dispatches']} decode dispatches, "
          f"{engine.stats['prefix_hits']} prefix hits), flushed pages for "
          f"{engine.stats['flushes']} requests, host tier holds "
          f"{len(engine.store.pages)} retired caches "
          f"({engine.store.bytes / 1024:.0f} KiB, "
          f"{engine.store.evictions} evictions)")
    if tier is not None:
        snap = tier.snapshot()
        print(f"[serve] cxl tier ({snap['media']}, "
              f"SR {'on' if cxl_sr else 'off'}): "
              f"{snap['writes'] + snap['async_writes']} page flushes "
              f"({snap['write_ns'] / 1e3:.0f}us held), "
              f"{snap['reads'] + snap['async_reads']} cold restores "
              f"stalling "
              f"{engine.stats['restore_stall_ns'] / 1e3:.0f}us total, "
              f"SR hit rate {snap['sr_hit_rate']:.2f}, "
              f"{engine.stats['flushes_deferred']} flush windows deferred "
              f"by the EP, {snap['gc_events']} internal tasks")
        if cxl_async or preempt_policy != "none":
            st = engine.stats
            print(f"[serve] scheduler (async {'on' if cxl_async else 'off'}"
                  f", policy {preempt_policy}): "
                  f"{st['preemptions']} preemptions, "
                  f"{st['swap_out_bytes'] / 1024:.0f} KiB swapped out / "
                  f"{st['swap_in_bytes'] / 1024:.0f} KiB back in, "
                  f"restore overlap {st['restore_overlap_ratio']:.2f} "
                  f"({st['restore_inflight_ns'] / 1e3:.0f}us in flight), "
                  f"peak {st['sched_inflight_peak']} in-flight tier ops, "
                  f"{st['sim_time_ns'] / 1e6:.2f}ms simulated")
        if tier.cfg.tagged:
            print(f"[serve] topology ({snap['placement']} placement, "
                  f"{snap['promotions']} promotions / "
                  f"{snap['demotions']} demotions):")
            for p in snap["ports"]:
                print(f"[serve]   port {p['port']} ({p['media']}): "
                      f"{p['ep_reads']} EP reads, {p['ep_writes']} writes, "
                      f"SR hit rate {p['sr_hit_rate']:.2f}, "
                      f"{p['live_bytes'] / 1024:.0f} KiB live, "
                      f"devload {p['devload']}, "
                      f"staging {p['staging_occupancy']:.2f}, "
                      f"{p['inflight']} in flight")
    return engine, finished


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cxl-media", default="",
                    help="attach the CXL-timed tier: dram / ssd-fast / "
                         "ssd-slow (or any sim media spec, e.g. znand@2)")
    ap.add_argument("--cxl-sr-off", action="store_true",
                    help="disable the speculative-read engine on the tier")
    ap.add_argument("--cxl-topology", default="",
                    help="multi-root-port tier: comma-separated per-port "
                         "media bins (e.g. 'dram,ssd-fast,ssd-slow'); "
                         "overrides --cxl-media")
    ap.add_argument("--cxl-placement", default="striped",
                    choices=["striped", "hashed", "hotness"],
                    help="entry placement across the topology's ports")
    ap.add_argument("--cxl-async", action="store_true",
                    help="completion-based async tier I/O: restores no "
                         "longer stall the batch (the slot activates when "
                         "the fetch lands) and flushes run in background")
    ap.add_argument("--preempt-policy", default="none",
                    choices=["none", "swap", "recompute"],
                    help="preempt the lowest-priority slot under queue "
                         "pressure: swap its KV pages to the CXL tier "
                         "(swap) or drop and re-prefill on resume "
                         "(recompute)")
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, n_requests=args.requests,
          n_slots=args.slots, max_new=args.max_new,
          cxl_media=args.cxl_media, cxl_sr=not args.cxl_sr_off,
          cxl_topology=args.cxl_topology, cxl_placement=args.cxl_placement,
          cxl_async=args.cxl_async, preempt_policy=args.preempt_policy)


if __name__ == "__main__":
    main()
