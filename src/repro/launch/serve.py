"""Serving driver: batched requests through the tiered paged engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --requests 8

Every engine knob on the CLI is derived from
:class:`~repro.serving.config.ServeConfig` — the CLI defaults *are* the
dataclass defaults, and the engine is constructed from the assembled
config object rather than a loose keyword bag.

``--cxl-media`` attaches the CXL-timed memory tier: page flushes and
prefix restores are charged against the simulated endpoint and the
restore stall / SR hit rate are reported alongside throughput.
``--cxl-topology dram,ssd-fast`` attaches a multi-root-port tier
instead (``--cxl-placement`` picks striped / hashed / hotness /
learned — learned drives promotion by the GMM reuse classifier — and
``--cxl-heat-half-life-ns`` ages entry heat so cold entries demote) and
adds a per-port stats line. ``--cxl-async`` switches the tier to
completion-based async I/O (restores overlap decode instead of stalling
the batch) and ``--preempt-policy swap|recompute`` enables preemptive
scheduling under slot pressure; both add a scheduler stats line.

``--load`` switches from the closed submit-then-run loop to the
open-loop continuous-batching harness: a seeded arrival trace
(``--rate`` req/s, ``--arrival poisson|bursty``, zipf prompt
popularity) is played against the engine on the simulated clock and the
SLO summary (TTFT/TPOT p50/p99, goodput at the latency targets, queue
depth) is printed instead of wall-clock throughput.

``--fault-trace degrade|flaky|hot-remove|mix`` injects a named endpoint
fault preset into the attached tier (a deterministic
``FaultSchedule`` seeded by ``--fault-seed``) and prints a recovery
stats line: fault ops / retries / failures, entries and bytes lost to
hot-removed ports, and requests re-queued through RECOVERING.

``--tp N`` runs sharded: the engine builds a (1, N) mesh, shards params
and the paged KV cache over the model axis, and (with a tier attached)
splits the topology into one root-port set per rank with cross-rank
restores charged on a peer link. Faults then apply to rank 0's ports.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.serving.config import ServeConfig
from repro.serving.engine import Request, ServingEngine

# single source of truth for the CLI defaults below
_DEF = ServeConfig()

# named endpoint-fault presets (--fault-trace); times are simulated ns
# into the run, sized for the smoke/open-loop horizons. ``port`` fields
# are resolved against the attached topology at config-build time: 0 is
# always valid, -1 means the last port.
FAULT_PRESETS = {
    "degrade": (("degrade", 1.0e6, -1, 300.0, 8.0e6),),
    "flaky": (("transient", 0.5e6, 0, 0.85, 6.0e6),),
    "hot-remove": (("hot_remove", 1.5e6, -1),),
    "mix": (("transient", 0.5e6, 0, 0.85, 6.0e6),
            ("degrade", 1.0e6, -1, 300.0, 8.0e6),
            ("hot_remove", 3.0e6, -1)),
}


def resolve_fault_preset(name: str, n_ports: int):
    """Resolve a named preset's relative port indices for a topology."""
    if name not in FAULT_PRESETS:
        raise ValueError(f"unknown fault preset {name!r} "
                         f"(choices: {sorted(FAULT_PRESETS)})")
    events = []
    for kind, t_ns, port, *rest in FAULT_PRESETS[name]:
        port = port % n_ports if n_ports else port
        if kind == "hot_remove" and n_ports < 2:
            raise ValueError("the hot-remove presets need a multi-port "
                             "tier (--cxl-topology with >= 2 ports): "
                             "removing the only port leaves no tier")
        events.append((kind, t_ns, port, *rest))
    return tuple(events)


def _print_closed(engine, finished, n_requests, dt):
    """Summarize one closed-loop run (wall-clock throughput and tier)."""
    tput = engine.stats["decode_tokens"] / dt if dt > 0 else 0.0
    print(f"[serve] {len(finished)}/{n_requests} requests, "
          f"{engine.stats['decode_tokens']} tokens in {dt:.1f}s "
          f"({tput:.1f} tok/s; {engine.stats['prefill_dispatches']} prefill"
          f" + {engine.stats['decode_dispatches']} decode dispatches, "
          f"{engine.stats['prefix_hits']} prefix hits), flushed pages for "
          f"{engine.stats['flushes']} requests, host tier holds "
          f"{len(engine.store.pages)} retired caches "
          f"({engine.store.bytes / 1024:.0f} KiB, "
          f"{engine.store.evictions} evictions)")


def _print_load(metrics, depths):
    """Summarize one open-loop run (SLO percentiles and goodput)."""
    m = metrics
    print(f"[serve] open-loop: {m.completed}/{m.arrivals} arrivals "
          f"completed in {m.sim_time_ms:.2f}ms simulated "
          f"({m.throughput_req_s:.0f} req/s; "
          f"{m.completed_in_slo} within SLO "
          f"ttft<={m.slo_ttft_ms}ms & tpot<={m.slo_tpot_ms}ms "
          f"-> goodput {m.goodput_req_s:.0f} req/s)")
    print(f"[serve]   TTFT p50/p99 {m.ttft_ms_p50:.3f}/"
          f"{m.ttft_ms_p99:.3f}ms, TPOT p50/p99 {m.tpot_ms_p50:.4f}/"
          f"{m.tpot_ms_p99:.4f}ms, queue depth p50/p99 "
          f"{m.queue_depth_p50:.0f}/{m.queue_depth_p99:.0f} "
          f"({len(depths)} samples), restore stall p50/p99 "
          f"{m.restore_stall_ms_p50:.3f}/{m.restore_stall_ms_p99:.3f}ms, "
          f"{m.preemptions} preemptions, {m.prefix_hits} prefix hits")


def _print_tier(engine, config):
    """Per-tier and per-port stats lines for an attached CXL tier."""
    tier = engine.tier
    snap = tier.snapshot()
    print(f"[serve] cxl tier ({snap['media']}, "
          f"SR {'on' if config.tier_sr else 'off'}): "
          f"{snap['writes'] + snap['async_writes']} page flushes "
          f"({snap['write_ns'] / 1e3:.0f}us held), "
          f"{snap['reads'] + snap['async_reads']} cold restores "
          f"stalling "
          f"{engine.stats['restore_stall_ns'] / 1e3:.0f}us total, "
          f"SR hit rate {snap['sr_hit_rate']:.2f}, "
          f"{engine.stats['flushes_deferred']} flush windows deferred "
          f"by the EP, {snap['gc_events']} internal tasks, "
          f"{snap['frees']} segment frees "
          f"({snap['segment_reuses']} reused)")
    if config.cxl_async or config.preempt_policy != "none":
        st = engine.stats
        print(f"[serve] scheduler (async "
              f"{'on' if config.cxl_async else 'off'}"
              f", policy {config.preempt_policy}, "
              f"admit {config.admit_mode}): "
              f"{st['preemptions']} preemptions, "
              f"{st['swap_out_bytes'] / 1024:.0f} KiB swapped out / "
              f"{st['swap_in_bytes'] / 1024:.0f} KiB back in, "
              f"restore overlap {st['restore_overlap_ratio']:.2f} "
              f"({st['restore_inflight_ns'] / 1e3:.0f}us in flight), "
              f"peak {st['sched_inflight_peak']} in-flight tier ops, "
              f"{st['sim_time_ns'] / 1e6:.2f}ms simulated")
    if config.tier_faults:
        st = engine.stats
        down = [p["port"] for p in tier.port_stats() if p["down"]]
        print(f"[serve] faults (seed {config.fault_seed}): "
              f"{st['tier_fault_ops']} ops crossed the fault path "
              f"({st['tier_fault_retries']} retries, "
              f"{st['tier_fault_failures']} exhausted the budget), "
              f"{st['tier_lost_entries']} entries / "
              f"{st['tier_lost_bytes'] / 1024:.0f} KiB lost to "
              f"hot-removed ports {down or '[]'}, "
              f"{st['recoveries']} requests recovered via RECOVERING")
    if tier.cfg.tagged:
        print(f"[serve] topology ({snap['placement']} placement, "
              f"{snap['promotions']} promotions / "
              f"{snap['demotions']} demotions):")
        for p in snap["ports"]:
            rank = f"rank {p['rank']} " if "rank" in p else ""
            print(f"[serve]   {rank}port {p['port']} ({p['media']}): "
                  f"{p['ep_reads']} EP reads, {p['ep_writes']} writes, "
                  f"SR hit rate {p['sr_hit_rate']:.2f}, "
                  f"{p['live_bytes'] / 1024:.0f} KiB live, "
                  f"devload {p['devload']}, "
                  f"staging {p['staging_occupancy']:.2f}, "
                  f"{p['inflight']} in flight")


def serve(arch: str, *, smoke: bool = True, n_requests: int = 8,
          max_new: int = 12, prompt_len: int = 6,
          config: ServeConfig = _DEF, load=None, max_ticks: int = 100_000):
    """Serve requests through the tiered engine built from ``config``.

    Closed mode (``load is None``): submits ``n_requests`` random
    prompts up front, runs to completion and reports wall-clock
    throughput plus per-request handle timings. Open-loop mode: ``load``
    is a :class:`~repro.serving.loadgen.LoadConfig`; its seeded arrival
    trace is played on the simulated clock (arrivals admitted as slots
    retire) and the SLO summary is printed. Every engine knob — slots,
    tier media/topology, async I/O, preemption, admission mode — comes
    from ``config``. Returns ``(engine, finished_requests)``.
    """
    cfg = registry.smoke(arch) if smoke else registry.get(arch)
    mesh = make_host_mesh() if smoke else make_production_mesh()
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    if config.n_ranks > 1:
        # sharded decode needs the page axis divisible by the model
        # axis: cap the page size so each slot has >= n_ranks pages
        import dataclasses as _dc
        page = min(rc.kv_page_size, max(config.max_seq // config.n_ranks,
                                        1))
        rc = _dc.replace(rc, kv_page_size=page)
    with jax.set_mesh(mesh):
        params = M.init_model(jax.random.PRNGKey(config.seed), cfg)
        engine = ServingEngine(params, cfg, rc, config=config)
        if load is not None:
            from repro.serving.loadgen import (drive_open_loop, make_trace,
                                               summarize)
            trace = make_trace(load)
            handles, depths = drive_open_loop(engine, trace,
                                              max_ticks=max_ticks)
            metrics = summarize(engine, handles, depths, load)
            finished = [h.request for h in handles if h.done()]
            _print_load(metrics, depths)
        else:
            import numpy as np
            rng = np.random.default_rng(config.seed)
            handles = []
            for rid in range(n_requests):
                prompt = rng.integers(1, cfg.vocab_size,
                                      prompt_len).tolist()
                handles.append(engine.submit(
                    Request(rid=rid, prompt=prompt,
                            max_new_tokens=max_new)))
            t0 = time.time()
            finished = engine.run()
            dt = time.time() - t0
            _print_closed(engine, finished, n_requests, dt)
            ttfts = [h.ttft_ns for h in handles if h.ttft_ns is not None]
            if ttfts:
                print(f"[serve]   per-request handles: "
                      f"{sum(1 for h in handles if h.done())} done, "
                      f"mean TTFT {sum(ttfts) / len(ttfts) / 1e6:.3f}ms "
                      f"simulated")
    if engine.tier is not None:
        _print_tier(engine, config)
    return engine, finished


def main() -> None:
    """CLI entry point; every engine default comes from ``ServeConfig``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=_DEF.n_slots)
    ap.add_argument("--max-seq", type=int, default=_DEF.max_seq)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prefill-chunk", type=int, default=_DEF.prefill_chunk)
    ap.add_argument("--seed", type=int, default=_DEF.seed)
    ap.add_argument("--cxl-media", default=_DEF.tier_media,
                    help="attach the CXL-timed tier: dram / ssd-fast / "
                         "ssd-slow (or any sim media spec, e.g. znand@2)")
    ap.add_argument("--cxl-sr-off", action="store_true",
                    help="disable the speculative-read engine on the tier")
    ap.add_argument("--cxl-topology", default="",
                    help="multi-root-port tier: comma-separated per-port "
                         "media bins (e.g. 'dram,ssd-fast,ssd-slow'); "
                         "overrides --cxl-media")
    ap.add_argument("--cxl-placement", default=_DEF.tier_placement,
                    choices=["striped", "hashed", "hotness", "learned"],
                    help="entry placement across the topology's ports "
                         "(learned = GMM reuse classifier)")
    ap.add_argument("--cxl-heat-half-life-ns", type=float,
                    default=_DEF.tier_heat_half_life_ns,
                    help="entry-heat decay half-life in simulated ns "
                         "(0 = heat never decays); applies to the "
                         "hotness and learned placements")
    ap.add_argument("--kv-quant", default=_DEF.kv_quant,
                    choices=["none", "int8"],
                    help="KV page format: int8 stores per-page-scaled "
                         "int8 pages, halving every tier flush/restore/"
                         "swap/SR byte charge (decode math stays full "
                         "precision)")
    ap.add_argument("--cxl-async", action="store_true",
                    help="completion-based async tier I/O: restores no "
                         "longer stall the batch (the slot activates when "
                         "the fetch lands) and flushes run in background")
    ap.add_argument("--preempt-policy", default=_DEF.preempt_policy,
                    choices=["none", "swap", "recompute"],
                    help="preempt the lowest-priority slot under queue "
                         "pressure: swap its KV pages to the CXL tier "
                         "(swap) or drop and re-prefill on resume "
                         "(recompute)")
    ap.add_argument("--admit-mode", default=_DEF.admit_mode,
                    choices=["continuous", "closed"],
                    help="continuous = admit-on-retire slot recycling; "
                         "closed = wave batching (next wave only once "
                         "every slot drained)")
    ap.add_argument("--load", action="store_true",
                    help="open-loop mode: play a seeded arrival trace on "
                         "the simulated clock instead of submitting all "
                         "requests up front; prints the SLO summary")
    ap.add_argument("--rate", type=float, default=8000.0,
                    help="open-loop offered load, requests per simulated "
                         "second")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty"],
                    help="open-loop inter-arrival process")
    ap.add_argument("--arrivals", type=int, default=64,
                    help="open-loop trace length (number of requests)")
    ap.add_argument("--zipf-s", type=float, default=1.1,
                    help="zipf exponent for prompt popularity (prefix "
                         "reuse); larger = more skew")
    ap.add_argument("--fault-trace", default="",
                    choices=[""] + sorted(FAULT_PRESETS),
                    help="inject a named endpoint-fault preset into the "
                         "attached tier: degrade (one port at 300x media "
                         "latency), flaky (transient-error window with "
                         "bounded retries), hot-remove (a port dies "
                         "mid-run; its pages are lost and recovered), or "
                         "mix (all three)")
    ap.add_argument("--fault-seed", type=int, default=_DEF.fault_seed,
                    help="seed for the fault schedule's transient-error "
                         "draws (deterministic per (seed, port, attempt))")
    ap.add_argument("--tp", type=int, default=_DEF.tp,
                    help="tensor-parallel rank count: tp=N builds a "
                         "(1, N) mesh, shards params + the paged KV "
                         "cache over the model axis and gives the tier "
                         "one root-port set per rank (needs N devices, "
                         "e.g. XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N on CPU)")
    args = ap.parse_args()
    topology = tuple(m.strip() for m in
                     args.cxl_topology.split(",") if m.strip())
    tier_faults = ()
    if args.fault_trace:
        n_ports = len(topology) if topology else (1 if args.cxl_media
                                                  else 0)
        tier_faults = resolve_fault_preset(args.fault_trace, n_ports)
    config = ServeConfig(
        n_slots=args.slots, max_seq=args.max_seq,
        prefill_chunk=args.prefill_chunk, seed=args.seed,
        kv_quant=args.kv_quant,
        cxl_async=args.cxl_async, preempt_policy=args.preempt_policy,
        admit_mode=args.admit_mode, tier_media=args.cxl_media,
        tier_topology=topology,
        tier_placement=args.cxl_placement,
        tier_heat_half_life_ns=args.cxl_heat_half_life_ns,
        tier_sr=not args.cxl_sr_off,
        tier_faults=tier_faults, fault_seed=args.fault_seed, tp=args.tp)
    load = None
    if args.load:
        from repro.serving.loadgen import LoadConfig
        load = LoadConfig(n_arrivals=args.arrivals, rate_rps=args.rate,
                          arrival=args.arrival, zipf_s=args.zipf_s,
                          seed=args.seed)
    serve(args.arch, smoke=args.smoke, n_requests=args.requests,
          max_new=args.max_new, config=config, load=load)


if __name__ == "__main__":
    main()
