import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
we build the production mesh (16x16 single-pod, 2x16x16 multi-pod), lower
the step with full-size ShapeDtypeStruct inputs (no allocation), compile,
and record memory_analysis / cost_analysis / the collective schedule parsed
from HLO. Output lands in ``artifacts/dryrun/<cell>.json`` which
``benchmarks/roofline.py`` and EXPERIMENTS.md consume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod    # 512-chip pass
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import registry
from repro.configs.base import (MeshConfig, RunConfig, SHAPES,
                                shape_applicable)
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# HLO collective ops whose operand bytes constitute the collective roofline
# term. collective-permute moves one operand; all-gather moves the output.
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\(")


def _dtype_bytes(dt: str) -> int:
    return {"f64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
            "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
            "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1}.get(dt, 4)


_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _first_shape_bytes(text: str) -> int:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _dtype_bytes(dt)


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO, by kind.

    These are *per-participating-device* payload bytes as XLA reports
    shapes post-SPMD-partitioning (the module is the per-device program).
    """
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or "-start" in line.split("=")[0]:
            # count either the fused op or the -start of an async pair, not
            # both; async pairs appear as -start/-done — take -done lines
            # only when a -start exists; simplest robust rule: skip -start
            pass
        if not m:
            continue
        head = line.split("=", 1)[0]
        if "-done" in head:
            continue  # bytes counted at the -start line (has the shape)
        kind = m.group(1)
        nbytes = _first_shape_bytes(line.split("=", 1)[1])
        out[kind] += nbytes
        out["count"] += 1
    return out


def reduced_depth_cfg(cfg, k: int):
    """Config with n_stacked == k (k scan iterations), same family/width."""
    import dataclasses
    if cfg.family in ("dense", "moe", "audio"):
        return dataclasses.replace(cfg, n_layers=k)
    if cfg.family == "vlm":
        return dataclasses.replace(cfg, n_layers=k * cfg.cross_attn_period)
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg,
                                   n_layers=k * cfg.shared_block_period)
    return dataclasses.replace(cfg, n_layers=k * cfg.slstm_every)  # ssm


def _compile_cell(cfg, shape, rc, mesh):
    cell = steps_lib.assemble(cfg, shape, rc, mesh)
    with jax.set_mesh(mesh):
        lowered = cell.jitted.lower(*cell.args)
        compiled = lowered.compile()
    return compiled


def _extract(compiled) -> dict:
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    return {"flops": float(cost.get("flops", 0.0)) if cost else 0.0,
            "bytes_accessed": (float(cost.get("bytes accessed", 0.0))
                               if cost else 0.0),
            "collective_bytes": collective_bytes(hlo)}


def _combine(e2: dict, e3: dict, n: int) -> dict:
    """edges + n * body, where body = e3 - e2 (both fully unrolled).

    k=2/3 (not 1/2) because a depth-1 model degenerates: the SR prefetch
    wrap-around double-gathers the single layer, polluting the difference.
    """
    out = {}
    for key in ("flops", "bytes_accessed"):
        body = e3[key] - e2[key]
        out[key] = max(e2[key] - 2 * body + n * body, 0.0)
    coll = {}
    for k in e2["collective_bytes"]:
        body = e3["collective_bytes"][k] - e2["collective_bytes"][k]
        coll[k] = max(e2["collective_bytes"][k] - 2 * body + n * body, 0.0)
    out["collective_bytes"] = coll
    return out


def _polyfit_cost(pts: dict, target_seq: int) -> dict:
    """Evaluate each cost term at ``target_seq`` from short-seq samples.

    2 points -> affine (c0 + c1*S, exact for attention-free archs);
    3 points -> quadratic (adds the attention S^2 term, exact for the
    hybrid's shared-attention blocks). Vandermonde solve per term.
    """
    import numpy as np
    seqs = sorted(pts)
    deg = len(seqs) - 1
    V = np.vander(np.array(seqs, float), deg + 1, increasing=True)

    def fit(vals):
        coef = np.linalg.solve(V, np.array(vals, float))
        return float(max(sum(c * target_seq ** i
                             for i, c in enumerate(coef)), 0.0))

    out = {"flops": fit([pts[s]["flops"] for s in seqs]),
           "bytes_accessed": fit([pts[s]["bytes_accessed"] for s in seqs])}
    coll = {}
    for k in pts[seqs[0]]["collective_bytes"]:
        coll[k] = fit([pts[s]["collective_bytes"][k] for s in seqs])
    out["collective_bytes"] = coll
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rc_overrides: dict | None = None, verbose: bool = True,
             with_cost: bool = False) -> dict:
    from repro.models import model as M

    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh_cfg = MeshConfig(multi_pod=multi_pod)
    rc = RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                   **(rc_overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    compiled = _compile_cell(cfg, shape, rc, mesh)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_d[k] = getattr(mem, k, None)
    module = _extract(compiled)

    res = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "status": "ok",
        "compile_s": round(t_compile, 2),
        "module": module,           # scan body counted ONCE (raw HLO view)
        "memory_analysis": mem_d,
        "n_stacked": M.n_stacked(cfg),
        "model_flops": None,
        "rc": {k: v for k, v in (rc_overrides or {}).items()},
    }

    # analytic MODEL_FLOPS: 6*N_active*D for train (fwd+bwd), 2*N_active*D
    # for single forward kinds
    n_act = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    res["model_flops"] = (6 if shape.kind == "train" else 2) * n_act * tokens

    if with_cost:
        # exact per-layer costs from fully-unrolled reduced-depth compiles
        # (inner sequence scans also unrolled — see layers.set_unroll_inner)
        import dataclasses as _dc
        from repro.models import layers as layers_lib
        t1 = time.time()
        layers_lib.set_unroll_inner(True)
        try:
            # ssm/hybrid at long sequences: the unrolled chunk scans make
            # the compile pathological (observed ~1 h for xlstm at 32k).
            # Their per-token cost laws are known exactly — ssm terms are
            # affine in S, hybrid adds the shared-attention quadratic — so
            # fit at short sequences and evaluate at the target S.
            fit_seqs = None
            if shape.kind != "decode" and shape.seq_len > 2048:
                if cfg.family == "ssm":
                    fit_seqs = (1024, 2048)            # affine
                elif cfg.family == "hybrid":
                    fit_seqs = (1024, 2048, 4096)      # quadratic

            def extract_at(seq_len):
                shape_s = _dc.replace(
                    shape, seq_len=seq_len) if seq_len else shape
                e = {}
                for k in (2, 3):
                    cfg_k = reduced_depth_cfg(cfg, k)
                    rc_k = _dc.replace(rc, model=cfg_k, scan_unroll=k)
                    e[k] = _extract(_compile_cell(cfg_k, shape_s, rc_k,
                                                  mesh))
                return _combine(e[2], e[3], M.n_stacked(cfg))

            if fit_seqs is None:
                res["corrected"] = extract_at(None)
            else:
                pts = {s: extract_at(s) for s in fit_seqs}
                res["corrected"] = _polyfit_cost(pts, shape.seq_len)
                res["cost_fit_seqs"] = list(fit_seqs)
        finally:
            layers_lib.set_unroll_inner(False)
        res["cost_extract_s"] = round(time.time() - t1, 2)

    if verbose:
        print(f"  mem={mem_d}")
        print(f"  module: flops={module['flops']:.3e} "
              f"bytes={module['bytes_accessed']:.3e}")
        if with_cost:
            c = res["corrected"]
            print(f"  corrected: flops={c['flops']:.3e} "
                  f"bytes={c['bytes_accessed']:.3e} "
                  f"coll={ {k: round(v/1e9, 2) for k, v in c['collective_bytes'].items()} } GB")
            print(f"  model_flops={res['model_flops']:.3e} "
                  f"useful={res['model_flops']/max(c['flops']*res['n_devices'],1):.3f}")
    return res


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod for each cell")
    ap.add_argument("--tag", default=None,
                    help="artifact suffix for rc-override variants")
    ap.add_argument("--set", nargs="*", default=[],
                    help="RunConfig overrides k=v (int/bool/str)")
    ap.add_argument("--cost", action="store_true",
                    help="also extract exact per-layer costs (roofline)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                pass
        overrides[k] = v

    archs = [args.arch] if args.arch else sorted(registry.ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    ART.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
                if args.tag:
                    tag += f"__{args.tag}"
                print(f"[dryrun] {tag}", flush=True)
                try:
                    res = run_cell(arch, shape_name, multi_pod=mp,
                                   rc_overrides=overrides,
                                   with_cost=args.cost)
                except Exception as e:  # a failure here is a sharding bug
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "failed", "error": str(e)[-2000:]}
                    failures += 1
                (ART / f"{tag}.json").write_text(json.dumps(res, indent=1))
                print(f"  -> {res['status']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
