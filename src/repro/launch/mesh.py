"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while tests and benches see the single real CPU device.
"""
from __future__ import annotations

import jax

import repro._compat  # noqa: F401  (jax < 0.5: installs AxisType et al.)
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]  # single-pod uses 256 of the dry-run's 512
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before any jax import")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes),
                         devices=devices)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh() -> Mesh:
    """Single-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
