"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while tests and benches see the single real CPU device.
"""
from __future__ import annotations

import jax

import repro._compat  # noqa: F401  (jax < 0.5: installs AxisType et al.)
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False, shape=None) -> Mesh:
    """Build the serving mesh.

    Without ``shape`` this is the full dry-run topology — (16, 16) or
    (2, 16, 16) with ``multi_pod`` — and requires the 512-device
    host-platform env. With an explicit ``shape`` (a 2- or 3-tuple) it
    builds a small (data, model) / (pod, data, model) mesh from however
    many real devices the process has, so tests and benches can get a
    (1, 2) or (1, 4) mesh without XLA_FLAGS gymnastics.
    """
    explicit = shape is not None
    if explicit:
        shape = tuple(int(s) for s in shape)
        if len(shape) not in (2, 3) or any(s < 1 for s in shape):
            raise ValueError(
                f"mesh shape must be a 2- or 3-tuple of positive ints, "
                f"got {shape!r}")
        axes = ("pod", "data", "model") if len(shape) == 3 else \
            ("data", "model")
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]  # single-pod uses 256 of the dry-run's 512
    if len(devices) < n:
        if explicit:
            raise RuntimeError(
                f"mesh {shape} needs {n} devices, have {len(devices)}; "
                "run with more devices (e.g. XLA_FLAGS=--xla_force_host_"
                f"platform_device_count={n}) or pick a smaller shape")
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before any jax import")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes),
                         devices=devices)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh() -> Mesh:
    """Single-device mesh with the production axis names (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
