from repro.optim.adamw import (AdamWConfig, AdamWState, init, opt_specs,
                               schedule, update)
from repro.optim import compression

__all__ = ["AdamWConfig", "AdamWState", "init", "opt_specs", "schedule",
           "update", "compression"]
