"""AdamW on sharded pytrees, with tier-aware state placement.

The optimizer state (m, v, fp32 master copy) is the largest write-heavy
resident in large-model training — the natural occupant of the paper's
SSD-EP tier. `opt_specs` therefore places m/v/master under the *optimizer
tier* of the run config (POOL by default, HOST when enabled on TPU); the
update itself runs sharded (on the reduce-scattered gradient shards: the
deterministic-store path), so no optimizer-state collective is ever issued.

Hand-written (no optax in this environment) and deliberately minimal:
pytree in, pytree out, works under jit/shard_map and with ShapeDtypeStructs
for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray      # scalar int32
    m: Any                 # first moment  (fp32, param-shaped tree)
    v: Any                 # second moment (fp32)
    master: Any            # fp32 master params (None if params are fp32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    use_master: bool = True  # keep fp32 master when params are low-precision


def init(params: Any, cfg: AdamWConfig) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    m = jax.tree_util.tree_map(zeros32, params)
    v = jax.tree_util.tree_map(zeros32, params)
    master = None
    if cfg.use_master:
        master = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v, master=master)


def schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.learning_rate * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any,
                                                              jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), \
        norm


def update(grads: Any, state: AdamWState, params: Any,
           cfg: AdamWConfig) -> Tuple[Any, AdamWState, dict]:
    """One AdamW step. Runs entirely on gradient/param *shards* (DS path).

    Returns (new_params, new_state, metrics).
    """
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, mp):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m2 / bc1
        vhat = v2 / bc2
        base = mp if mp is not None else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        return new.astype(p.dtype), m2, v2, new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mp = (treedef.flatten_up_to(state.master)
               if state.master is not None else [None] * len(flat_p))
    out = [upd(*t) for t in zip(flat_p, flat_g, flat_m, flat_v, flat_mp)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_master = (treedef.unflatten([o[3] for o in out])
                  if state.master is not None else None)
    new_state = AdamWState(step=step, m=new_m, v=new_v, master=new_master)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_specs(param_specs: Any, state: AdamWState) -> AdamWState:
    """PartitionSpecs for the optimizer state: m/v/master mirror the param
    specs (they live in the optimizer tier with identical layout)."""
    from jax.sharding import PartitionSpec as P
    mirror = param_specs
    return AdamWState(step=P(), m=mirror, v=mirror,
                      master=mirror if state.master is not None else None)
