"""Gradient compression for cross-pod reductions: int8 + error feedback.

On the multi-pod mesh the `pod`-axis reduction crosses the slow inter-pod
links (DCI), so the framework optionally compresses the pod-axis gradient
contribution to int8 with per-block scales and an error-feedback residual
carried in the optimizer loop (the residual restores unbiasedness over
steps). The within-pod (data-axis) reduce-scatter stays full precision —
it rides the fast ICI and is the deterministic-store path.

Shape contract: works leaf-wise on any pytree; block size divides the
trailing dim or falls back to per-tensor scaling.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8-quantize with per-block absmax scales. Returns (q, scales)."""
    x32 = x.astype(jnp.float32)
    flat = x32.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape,
                dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_leaf(g: jnp.ndarray, residual: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 round trip for one gradient leaf.

    Returns (decompressed_gradient, new_residual). The caller reduces the
    *decompressed* value; in a real deployment the int8 payload is what
    crosses the wire — XLA's all-reduce operates post-dequantize here, which
    keeps the graph pure while modelling the numerics exactly.
    """
    g32 = g.astype(jnp.float32) + residual
    q, scale = _quantize(g32)
    deq = _dequantize(q, scale, g.shape, jnp.float32)
    new_residual = g32 - deq
    return deq.astype(g.dtype), new_residual


def init_residuals(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, residuals: Any) -> Tuple[Any, Any]:
    """Apply int8-EF compression leaf-wise. Returns (grads', residuals')."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [compress_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compressed_bytes(params: Any) -> int:
    """Wire bytes per step under int8+scales (for the roofline's collective
    term on the pod axis)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        n = leaf.size
        n_blocks = -(-n // BLOCK)
        total += n + 4 * n_blocks  # int8 payload + fp32 scale per block
    return total
