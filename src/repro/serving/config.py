"""Validated serving-engine configuration (the ``ServeConfig`` dataclass).

One frozen dataclass consolidates every ``ServingEngine`` constructor
knob — slot count, hot-path options, scheduler policy and the CXL-tier
attachment — so the engine, ``repro.launch.serve``'s CLI and the
``benchmarks/serve_bench.py`` scenarios all derive from the same
defaults instead of each duplicating them. Cross-field constraints
(the frozen legacy baseline vs scheduler features, closed-batch
admission vs preemption, policy spellings) are validated once, at
construction, with the same errors the engine used to raise piecemeal.

The module imports nothing heavier than the stdlib at import time; the
tier attachment (:meth:`ServeConfig.make_tier`) imports
``repro.core.tier`` lazily so building and validating a config never
touches jax.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# mirrored from repro.serving.scheduler / repro.core.tier so validating
# a config stays import-light; the owning modules re-validate on use.
_PREEMPT_POLICIES = ("none", "swap", "recompute")
_ADMIT_MODES = ("continuous", "closed")
_PLACEMENTS = ("striped", "hashed", "hotness", "learned")
_FAULT_KINDS = ("degrade", "transient", "hot_remove")
# mirrored from repro.models.kv_quant.KV_QUANT_MODES ("fp8" is reserved —
# spelled here so the error message can say so without importing jax)
_KV_QUANT_MODES = ("none", "int8", "fp8")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything a ``ServingEngine`` needs beyond params/config/mesh.

    Engine shape and hot path:

     * ``n_slots`` — concurrent decode slots (the continuous batch).
     * ``max_seq`` — per-slot page capacity in tokens.
     * ``temperature`` / ``seed`` — on-device sampling (0 = greedy).
     * ``prefill_chunk`` — tokens per jitted prefill dispatch.
     * ``store_budget_bytes`` — HostPageStore LRU budget (None = ∞).
     * ``legacy_host_path`` — the frozen pre-rewrite baseline engine.
     * ``sync_prefill`` — block after prefill (benchmark accounting).
     * ``kv_quant`` — KV page format: ``"none"`` (model dtype) or
       ``"int8"`` (per-page-scaled int8 pages; every tier flush /
       restore / swap / SR fetch is charged the quantized byte count —
       see ``repro.models.kv_quant``). ``"fp8"`` is reserved.

    Scheduler (``repro.serving.scheduler``):

     * ``cxl_async`` — completion-based async tier I/O (restores overlap
       decode; flushes become background ops).
     * ``preempt_policy`` — ``none`` / ``swap`` / ``recompute``.
     * ``admit_mode`` — ``continuous`` (admit-on-retire slot recycling,
       the default) or ``closed`` (wave batching: a new wave is admitted
       only once every slot drained — the baseline the open-loop load
       gates compare against).

    CXL tier attachment (declarative; :meth:`make_tier` builds it):

     * ``tier_media`` — single-port media bin ("" = no tier attached).
     * ``tier_topology`` — per-port media bins; non-empty overrides
       ``tier_media`` with a multi-root-port tier.
     * ``tier_placement`` / ``tier_sr`` — placement policy and the
       speculative-read engine. ``"learned"`` drives promotion /
       demotion (and, sharded, cross-rank re-homing) from a
       :class:`repro.sim.policy.LearnedPlacement` GMM instead of the
       ``hotness`` restore counter.
     * ``tier_heat_half_life_ns`` — heat aging half-life for the
       ``hotness`` / ``learned`` policies (0 = no aging; a once-hot
       entry then pins its fast port until budget pressure evicts it).
     * ``tier_step_ns`` — simulated ns per engine tick.
     * ``tier_faults`` — declarative fault events, stdlib tuples of
       ``("degrade", t_ns, port, mult[, until_ns])``,
       ``("transient", t_ns, port, p_err[, until_ns])`` or
       ``("hot_remove", t_ns, port)``; :meth:`make_tier` folds them into
       a deterministic ``repro.sim.engine.FaultSchedule`` seeded by
       ``fault_seed``. Requires a tier attachment. On a sharded tier
       the schedule applies to rank 0's port set (port indices stay
       per-rank-local).

    Sharded serving (``repro.launch.mesh`` + ``repro.parallel``):

     * ``mesh_shape`` — explicit (data, model) or (pod, data, model)
       device-mesh shape; the engine builds it via
       ``make_production_mesh(shape=...)`` and shards params + the
       paged KV cache across the model axis. ``()`` means unsharded
       (whatever mesh the caller activated, usually the host mesh).
     * ``tp`` — tensor-parallel sugar: ``tp=N`` is ``mesh_shape=(1, N)``.
       The model axis of ``mesh_shape``, when both are given, must
       equal ``tp``. ``n_ranks`` (model-axis size) also shards the CXL
       tier: :meth:`make_tier` returns a ``ShardedTier`` with one
       port set per rank when ``n_ranks > 1``.
    """

    n_slots: int = 4
    max_seq: int = 512
    temperature: float = 0.0
    seed: int = 0
    prefill_chunk: int = 32
    store_budget_bytes: Optional[int] = 256 << 20
    legacy_host_path: bool = False
    sync_prefill: bool = False
    kv_quant: str = "none"
    cxl_async: bool = False
    preempt_policy: str = "none"
    admit_mode: str = "continuous"
    tier_media: str = ""
    tier_topology: Tuple[str, ...] = ()
    tier_placement: str = "striped"
    tier_heat_half_life_ns: float = 0.0
    tier_sr: bool = True
    tier_step_ns: float = 100_000.0
    tier_faults: Tuple[tuple, ...] = ()
    fault_seed: int = 0
    mesh_shape: Tuple[int, ...] = ()
    tp: int = 1

    def __post_init__(self):
        """Validate spellings and cross-field constraints once."""
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1 (got {self.n_slots})")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1 "
                             f"(got {self.prefill_chunk})")
        if self.preempt_policy not in _PREEMPT_POLICIES:
            raise ValueError(f"unknown preempt_policy "
                             f"{self.preempt_policy!r} (expected one of "
                             f"{_PREEMPT_POLICIES})")
        if self.admit_mode not in _ADMIT_MODES:
            raise ValueError(f"unknown admit_mode {self.admit_mode!r} "
                             f"(expected one of {_ADMIT_MODES})")
        if self.kv_quant not in _KV_QUANT_MODES:
            raise ValueError(f"unknown kv_quant {self.kv_quant!r} "
                             f"(expected one of {_KV_QUANT_MODES})")
        if self.kv_quant == "fp8":
            raise ValueError("kv_quant='fp8' is reserved but not "
                             "implemented yet; use 'none' or 'int8'")
        if self.kv_quant != "none" and self.legacy_host_path:
            raise ValueError("kv_quant needs the device-resident paged "
                             "cache; the legacy host path keeps flat "
                             "full-precision K/V tuples")
        if self.tier_placement not in _PLACEMENTS:
            raise ValueError(f"unknown tier_placement "
                             f"{self.tier_placement!r} (expected one of "
                             f"{_PLACEMENTS})")
        if self.tier_heat_half_life_ns < 0:
            raise ValueError("tier_heat_half_life_ns must be >= 0 "
                             f"(got {self.tier_heat_half_life_ns})")
        if self.legacy_host_path and (self.cxl_async
                                      or self.preempt_policy != "none"):
            raise ValueError("the legacy host path is the frozen baseline: "
                             "cxl_async / preempt_policy need the "
                             "device-resident engine")
        if self.admit_mode == "closed" and self.preempt_policy != "none":
            raise ValueError("closed-batch admission cannot preempt: a "
                             "wave has no queue pressure to preempt for "
                             "(use admit_mode='continuous')")
        if self.tier_step_ns <= 0:
            raise ValueError("tier_step_ns must be positive "
                             f"(got {self.tier_step_ns})")
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1 (got {self.tp})")
        if self.mesh_shape:
            if len(self.mesh_shape) not in (2, 3) or \
                    any(int(s) < 1 for s in self.mesh_shape):
                raise ValueError(
                    "mesh_shape must be a 2- or 3-tuple of positive ints "
                    f"(got {self.mesh_shape!r})")
            if self.tp > 1 and self.mesh_shape[-1] != self.tp:
                raise ValueError(
                    f"mesh_shape model axis {self.mesh_shape[-1]} "
                    f"conflicts with tp={self.tp}; set one or make them "
                    "agree")
        if self.n_ranks > 1 and self.legacy_host_path:
            raise ValueError("sharded serving needs the device-resident "
                             "engine; the legacy host path is single-rank")
        if self.tier_faults:
            if not self.has_tier:
                raise ValueError("tier_faults without a tier attachment: "
                                 "set tier_media or tier_topology")
            for ev in self.tier_faults:
                if not ev or ev[0] not in _FAULT_KINDS:
                    raise ValueError(f"unknown fault event {ev!r} "
                                     f"(kinds: {_FAULT_KINDS})")

    @classmethod
    def field_names(cls) -> Tuple[str, ...]:
        """Declared field names in declaration order (CLI derivation)."""
        return tuple(f.name for f in dataclasses.fields(cls))

    @property
    def has_tier(self) -> bool:
        """True when this config declares a CXL tier attachment."""
        return bool(self.tier_topology or self.tier_media)

    @property
    def resolved_mesh_shape(self) -> Tuple[int, ...]:
        """The mesh shape the engine should build (``()`` = unsharded).

        ``mesh_shape`` wins when set; otherwise ``tp > 1`` expands to
        ``(1, tp)``; otherwise the config is unsharded and the engine
        runs under whatever mesh the caller activated.
        """
        if self.mesh_shape:
            return tuple(int(s) for s in self.mesh_shape)
        if self.tp > 1:
            return (1, int(self.tp))
        return ()

    @property
    def n_ranks(self) -> int:
        """Model-axis size: tensor-parallel rank count (1 = unsharded)."""
        shape = self.mesh_shape or ((1, self.tp) if self.tp > 1 else ())
        return int(shape[-1]) if shape else 1

    def _tier_config(self, faults=None):
        """The per-tier ``TierConfig`` this config declares."""
        from repro.core.tier import TierConfig
        if self.tier_topology:
            return TierConfig(topology=tuple(self.tier_topology),
                              placement=self.tier_placement,
                              heat_half_life_ns=self.tier_heat_half_life_ns,
                              sr_enabled=self.tier_sr, faults=faults)
        return TierConfig(media=self.tier_media, sr_enabled=self.tier_sr,
                          faults=faults)

    def make_tier(self):
        """Build the declared tier (or None without one).

        Single-rank configs get a ``CxlTier``; ``n_ranks > 1`` gets a
        ``ShardedTier`` with one port set per rank (fault schedule on
        rank 0). Lazy-imports ``repro.core.tier`` so config
        construction and validation stay jax-free; callers that inject
        a prebuilt tier (tests, benches) simply never call this.
        """
        if not self.has_tier:
            return None
        faults = self.make_fault_schedule()
        if self.n_ranks > 1:
            from repro.core.sharded_tier import ShardedTier
            return ShardedTier(self.n_ranks, self._tier_config(),
                               faults=faults, fault_rank=0)
        from repro.core.tier import CxlTier
        return CxlTier(self._tier_config(faults))

    def make_fault_schedule(self):
        """Fold ``tier_faults`` into a ``FaultSchedule`` (None if empty).

        Lazy-imports ``repro.sim.engine`` for the same reason
        :meth:`make_tier` is lazy; the event helpers re-validate the
        numeric fields (times, ports, multipliers, probabilities).
        """
        if not self.tier_faults:
            return None
        from repro.sim.engine import (FaultSchedule, degrade, hot_remove,
                                      transient)
        mk = {"degrade": degrade, "transient": transient,
              "hot_remove": hot_remove}
        events = tuple(mk[ev[0]](*ev[1:]) for ev in self.tier_faults)
        return FaultSchedule(events, seed=self.fault_seed)
