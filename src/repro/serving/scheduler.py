"""Request-lifecycle scheduler: preemptive admission over the serving slots.

The serving engine used to admit greedily (FIFO into the first free
slot) and charge every cold-tier restore as a synchronous stall at admit
time. This module rebuilds admission as an explicit state machine over
:class:`~repro.serving.engine.Request`:

::

            submit                    admission
    QUEUED ─────────► (scheduler) ──┬──────────────────► RUNNING
                                    │ staging hit / blocking restore /
                                    │ prefill — slot active immediately
                                    │
                                    │ async cold-tier fetch issued
                                    └─► RESTORING ──completion──► RUNNING
    RUNNING ──preempt, swap policy──────► SWAPPED ───► QUEUED (requeued)
    RUNNING ──preempt, recompute policy─► PREEMPTED ─► QUEUED (requeued)
    RUNNING ──max tokens / position bound───────────────────────► RETIRED
    RESTORING ──fetch failed (transient budget / port hot-removed)─┐
                                          RECOVERING ─► QUEUED ◄───┘

Fault recovery (RECOVERING): a restore or swap-in whose tier fetch
failed — the transient-retry budget ran out, or the entry's port was
hot-removed mid-flight — re-queues the request instead of activating
garbage. If the tier copy survived (transient exhaustion) the next
admission simply retries the fetch; if the pages were lost the engine's
lost-key sweep has already dropped the host-store copy or downgraded
the swap payload to a recompute marker, so the retry falls through to a
fresh prefill / the ``preempt_policy="recompute"`` resume path. After
``RECOVERY_PREFILL_AFTER`` failed attempts the scheduler force-drops
the surviving copy too (no livelock on a permanently flaky port).

Two mechanisms hide the expansion tier's media latency behind decode:

 * **asynchronous restore** (``async_restore=True``): a cold-tier prefix
   fetch is issued through ``CxlTier.read_entry_async`` and the slot sits
   in RESTORING while *the rest of the batch keeps decoding*; the slot
   activates on the tick the completion lands. Only in-flight-cap issue
   stalls (plus any tick where every occupied slot was RESTORING) are
   exposed — the rest of the fetch overlaps decode, which is exactly the
   paper's speculative-read/deterministic-store claim lifted to request
   granularity.
 * **preemption** (``preempt_policy``): under slot pressure — queued work
   with strictly higher priority than the lowest-priority running slot
   and no free capacity — the victim's pages swap *out* to the CXL tier
   (``"swap"``: KV pages charged as an async flush, token progress kept)
   or are dropped (``"recompute"``: only the token stream is kept and the
   prompt + generated prefix is re-prefilled on resume). The freed slot
   admits the queued request instead of idling behind a long decode.

All scheduling state lives here; the engine keeps owning the cache, the
slots and the jitted hot path. ``preempt_policy="none"`` with
``async_restore=False`` reproduces the pre-scheduler engine exactly
(same admission order, same charges, same tokens).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

from repro.core.tier import CxlTier

# Request.state values (plain strings so Request stays a simple dataclass)
QUEUED = "QUEUED"
RESTORING = "RESTORING"
RUNNING = "RUNNING"
PREEMPTED = "PREEMPTED"
SWAPPED = "SWAPPED"
RETIRED = "RETIRED"
RECOVERING = "RECOVERING"

PREEMPT_POLICIES = ("none", "swap", "recompute")
ADMIT_MODES = ("continuous", "closed")

# After this many failed fetch attempts for one request, drop its
# surviving tier/store copy and force a fresh prefill — bounds the
# retry loop on a permanently flaky port (no livelock).
RECOVERY_PREFILL_AFTER = 3


@dataclasses.dataclass
class _InflightRestore:
    """One slot's outstanding async fetch (prefix restore or swap-in)."""

    req: object
    slot: int
    entry: dict
    handle: object                # repro.core.tier.TierHandle
    mode: str                     # "restore" | "swap"
    key: object = None            # tier/store key (recovery bookkeeping)


class RequestScheduler:
    """Preemptive request-lifecycle scheduler over one ``ServingEngine``.

    Owns the QUEUED/RESTORING/SWAPPED bookkeeping and the per-tick
    scheduling pass (:meth:`begin_tick`); delegates cache surgery and
    tier charging to the engine's helpers. ``stats`` accumulates the
    scheduler telemetry the engine surfaces (preemptions, swap bytes,
    in-flight restore time, exposed stall).
    """

    def __init__(self, engine, *, async_restore: bool = False,
                 preempt_policy: str = "none",
                 admit_mode: str = "continuous"):
        if preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"unknown preempt_policy {preempt_policy!r} "
                             f"(expected one of {PREEMPT_POLICIES})")
        if admit_mode not in ADMIT_MODES:
            raise ValueError(f"unknown admit_mode {admit_mode!r} "
                             f"(expected one of {ADMIT_MODES})")
        self.engine = engine
        self.async_restore = bool(async_restore)
        self.preempt_policy = preempt_policy
        self.admit_mode = admit_mode
        self.inflight: Dict[int, _InflightRestore] = {}   # slot -> fetch
        self.swapped: Dict[int, dict] = {}                # rid -> payload
        self.stats = {"preemptions": 0, "swap_out_bytes": 0,
                      "swap_in_bytes": 0, "restore_inflight_ns": 0.0,
                      "restore_exposed_ns": 0.0, "inflight_peak": 0,
                      "activations": 0, "blocked_ticks": 0,
                      "recoveries": 0}

    # ------------------------------------------------------------- tick
    def busy(self) -> bool:
        """True while any slot's async fetch is still outstanding."""
        return bool(self.inflight)

    def drain(self) -> None:
        """Poll outstanding async fetches and activate any that landed —
        the engine's horizon drain calls this between simulated-time
        advances so RESTORING slots settle without a full decode tick."""
        self._activate_completed()

    def begin_tick(self) -> None:
        """One scheduling pass: activate landed fetches, preempt under
        pressure, then admit queued work into free slots."""
        self._activate_completed()
        self._maybe_preempt()
        self._admit()

    def note_blocked_tick(self, dt_ns: float) -> None:
        """Account one tick where the whole batch idled on in-flight
        restores (no RUNNING slot): that tick's simulated time is exposed
        stall, not hidden latency."""
        self.stats["blocked_ticks"] += 1
        self.stats["restore_exposed_ns"] += dt_ns
        self.engine.stats["restore_stall_ns"] += dt_ns

    # ------------------------------------------------------- transitions
    def _activate_completed(self) -> None:
        eng = self.engine
        for slot in sorted(self.inflight):
            rec = self.inflight[slot]
            if not eng.tier.poll(rec.handle):
                continue
            del self.inflight[slot]
            if getattr(rec.handle, "failed", False):
                self._recover_inflight(rec)
                continue
            if rec.mode == "swap":
                eng.slots[slot] = rec.req
                eng._apply_swap_in(rec.req, slot, rec.entry)
                if eng.tier is not None:     # swap pages are back in GPU
                    eng.tier.free_entry(("swap", rec.req.rid))
            else:
                eng.slots[slot] = rec.req
                eng._apply_restore(rec.req, slot, rec.entry)
            rec.req.state = RUNNING
            self.stats["activations"] += 1

    # ---------------------------------------------------- fault recovery
    def _recover_inflight(self, rec: _InflightRestore) -> None:
        """An async fetch failed (retry budget exhausted or its port
        hot-removed): re-queue the request in RECOVERING state instead
        of activating a slot from pages that never arrived."""
        eng = self.engine
        req = rec.req
        if rec.mode == "swap":
            # put the payload back for the retry — unless the tier copy
            # died with its port (or keeps failing), in which case only
            # the token stream survives and resume goes through the
            # recompute path.
            if (eng.tier.has_entry(("swap", req.rid))
                    and req.recoveries + 1 < RECOVERY_PREFILL_AFTER):
                self.swapped[req.rid] = rec.entry
            else:
                eng.tier.free_entry(("swap", req.rid))
                self.swapped[req.rid] = {"recompute": True}
        elif rec.key is not None and (
                not eng.tier.has_entry(rec.key)
                or req.recoveries + 1 >= RECOVERY_PREFILL_AFTER):
            # pages lost, or this key keeps failing: drop the host-store
            # copy so the next admission prefills from scratch.
            eng.store.drop(rec.key)
        self._requeue_recovering(req)

    def _requeue_recovering(self, req) -> None:
        """Common tail of every recovery path: count it, mark the
        request RECOVERING and push it back on the admission queue."""
        req.slot = None
        req.recoveries += 1
        req.state = RECOVERING
        self.engine.queue.append(req)
        self.stats["recoveries"] += 1

    def _pop_next(self):
        """Highest-priority queued request, FIFO-stable on ties (so the
        default all-zero-priority queue is exactly the old FIFO)."""
        q = self.engine.queue
        best = 0
        for j in range(1, len(q)):
            if q[j].priority > q[best].priority:
                best = j
        return q.pop(best)

    def _admit(self) -> None:
        eng = self.engine
        if self.admit_mode == "closed" and (
                any(s is not None for s in eng.slots) or self.inflight):
            # wave batching: the next wave is admitted only once every
            # slot has drained — the closed-loop baseline the open-loop
            # load harness compares continuous admit-on-retire against
            return
        for slot in range(eng.n_slots):
            if eng.slots[slot] is not None or slot in self.inflight \
                    or not eng.queue:
                continue
            req = self._pop_next()
            req.slot = slot
            t0 = time.perf_counter()
            self._place(req, slot)
            eng.stats["prefill_time_s"] += time.perf_counter() - t0

    def _place(self, req, slot: int) -> None:
        """Route one admitted request: swap-in, prefix restore or prefill."""
        eng = self.engine
        if req.rid in self.swapped:
            self._swap_in(req, slot, self.swapped.pop(req.rid))
            return
        eng.slots[slot] = req
        if not eng.legacy and self._try_restore(req, slot):
            pass          # prefix_hits counted inside (failed fetches
        elif eng.legacy:  # recover into the queue, not into the stat)
            eng._prefill_slot_legacy(req, slot)
            req.state = RUNNING
        else:
            eng._prefill_slot(req, slot)
            req.state = RUNNING

    def _note_inflight_peak(self) -> None:
        if self.engine.tier is not None:
            depth = self.engine.tier.inflight_ops()
            if depth > self.stats["inflight_peak"]:
                self.stats["inflight_peak"] = depth

    def _try_restore(self, req, slot: int) -> bool:
        """Prefix restore — blocking charge, or async issue + RESTORING.

        Staging-index hits stay free and instant in both modes (the
        deterministic store keeps those pages in reserved GPU memory);
        only a cold-tier hit goes through the simulated fetch.
        """
        eng = self.engine
        res = eng._restore_lookup(req)
        if res is None:
            return False
        entry, key, source = res
        if eng.tier is not None and source == "store":
            nbytes = CxlTier.entry_bytes(entry)
            if self.async_restore:
                handle = eng.tier.read_entry_async(key, nbytes)
                req.restore_stall_ns = handle.issue_wait_ns
                eng.stats["restore_stall_ns"] += handle.issue_wait_ns
                self.stats["restore_exposed_ns"] += handle.issue_wait_ns
                self.stats["restore_inflight_ns"] += handle.in_flight_ns
                eng.slots[slot] = None          # reserved, not active
                self.inflight[slot] = _InflightRestore(
                    req, slot, entry, handle, "restore", key)
                req.state = RESTORING
                self._note_inflight_peak()
                eng.stats["prefix_hits"] += 1
                return True
            stall = eng.tier.read_entry(key, nbytes)
            req.restore_stall_ns = stall
            eng.stats["restore_stall_ns"] += stall
            if eng.tier.last_entry_failed:
                eng.slots[slot] = None
                if (not eng.tier.has_entry(key)
                        or req.recoveries + 1 >= RECOVERY_PREFILL_AFTER):
                    eng.store.drop(key)
                self._requeue_recovering(req)
                return True
        eng._apply_restore(req, slot, entry)
        req.state = RUNNING
        eng.stats["prefix_hits"] += 1
        return True

    # -------------------------------------------------------- preemption
    def _maybe_preempt(self) -> None:
        """Swap out the lowest-priority running slot when queued work of
        strictly higher priority has no free capacity to land on."""
        eng = self.engine
        if self.preempt_policy == "none" or not eng.queue:
            return
        if self.preempt_policy == "swap" and not eng._restorable:
            return            # no paged KV to swap for this family
        if any(eng.slots[s] is None and s not in self.inflight
               for s in range(eng.n_slots)):
            return            # free capacity: no pressure
        running = [(eng.slots[s].priority, s) for s in range(eng.n_slots)
                   if eng.slots[s] is not None]
        if not running:
            return
        best_queued = max(r.priority for r in eng.queue)
        vprio, vslot = min(running)
        if best_queued <= vprio:
            return
        self._swap_out(vslot)

    def _swap_out(self, slot: int) -> None:
        eng = self.engine
        req = eng.slots[slot]
        eng._materialize_tokens(req, slot)
        if self.preempt_policy == "swap":
            entry = eng._capture_swap_entry(req, slot)
            nbytes = CxlTier.entry_bytes(entry)
            if eng.tier is not None:
                if self.async_restore:
                    h = eng.tier.write_entry_async(("swap", req.rid), nbytes)
                    eng._async_writes.append(h)
                    eng.stats["tier_write_ns"] += h.issue_wait_ns
                    self._note_inflight_peak()
                else:
                    eng.stats["tier_write_ns"] += eng.tier.write_entry(
                        ("swap", req.rid), nbytes)
            self.stats["swap_out_bytes"] += nbytes
            self.swapped[req.rid] = entry
            req.state = SWAPPED
        else:                 # recompute: keep only the token stream
            self.swapped[req.rid] = {"recompute": True}
            req.state = PREEMPTED
        eng.slots[slot] = None
        req.slot = None
        eng.queue.append(req)
        self.stats["preemptions"] += 1

    def _swap_in(self, req, slot: int, entry: dict) -> None:
        eng = self.engine
        if entry.get("recompute"):
            eng.slots[slot] = req
            eng._recompute_resume(req, slot)
            req.state = RUNNING
            return
        nbytes = CxlTier.entry_bytes(entry)
        self.stats["swap_in_bytes"] += nbytes
        if eng.tier is not None:
            if self.async_restore:
                handle = eng.tier.read_entry_async(("swap", req.rid), nbytes)
                req.restore_stall_ns += handle.issue_wait_ns
                eng.stats["restore_stall_ns"] += handle.issue_wait_ns
                self.stats["restore_exposed_ns"] += handle.issue_wait_ns
                self.stats["restore_inflight_ns"] += handle.in_flight_ns
                self.inflight[slot] = _InflightRestore(
                    req, slot, entry, handle, "swap", ("swap", req.rid))
                req.state = RESTORING
                self._note_inflight_peak()
                return
            stall = eng.tier.read_entry(("swap", req.rid), nbytes)
            req.restore_stall_ns += stall
            eng.stats["restore_stall_ns"] += stall
            if eng.tier.last_entry_failed:
                if eng.tier.has_entry(("swap", req.rid)) and \
                        req.recoveries + 1 < RECOVERY_PREFILL_AFTER:
                    self.swapped[req.rid] = entry   # retry the swap-in
                else:
                    eng.tier.free_entry(("swap", req.rid))
                    self.swapped[req.rid] = {"recompute": True}
                self._requeue_recovering(req)
                return
            eng.tier.free_entry(("swap", req.rid))  # pages back in GPU
        eng.slots[slot] = req
        eng._apply_swap_in(req, slot, entry)
        req.state = RUNNING
