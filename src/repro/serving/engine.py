"""Continuous-batching serving engine over the paged, tiered KV cache.

The paper's storage-expansion loop, at request granularity:

 * slots — the engine runs a fixed decode batch; requests stream through
   slots (continuous batching). Each slot owns a page range of the
   distributed cache and its own position (per-slot `pos` vector).
 * tiered pages — a finished slot's pages are not dropped: they retire
   through the ``StagingRing`` (deterministic store: the release is
   immediate; the flush to the cold tier happens in the background, gated
   by the QoS controller exactly like Fig. 8) into the host-side page
   store, keyed by request id — prefix reuse fetches them back (the
   speculative-read path) instead of re-prefilling.
 * QoS — per-step telemetry drives the same DevLoad machine the training
   driver and the simulator use; under congestion flushes pause and the
   prefetch window narrows.

The decode step itself is models.model.decode_step — the page-sharded
distributed attention with owner-rank writes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core import deterministic_store as ds
from repro.core.qos import DevLoad, QoSController
from repro.models import model as M
from repro.parallel import sharding as shlib


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None


class HostPageStore:
    """Cold tier for retired KV pages (the SSD-EP analogue)."""

    def __init__(self):
        self.pages: Dict[int, Dict] = {}
        self.bytes = 0

    def put(self, rid: int, kv_slot) -> None:
        host = jax.tree_util.tree_map(np.asarray, kv_slot)
        self.pages[rid] = host
        self.bytes += sum(a.nbytes for a in jax.tree_util.tree_leaves(host))

    def get(self, rid: int):
        return self.pages.get(rid)


class ServingEngine:
    """Fixed-batch continuous batching with tiered page lifecycle."""

    def __init__(self, params, cfg: ModelConfig, rc: RunConfig, *,
                 n_slots: int = 4, max_seq: int = 512,
                 temperature: float = 0.0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.rc = rc
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.pspecs = shlib.param_specs(
            jax.eval_shape(lambda: params), tier=rc.param_tier,
            multi_pod_fsdp=rc.mesh.multi_pod)
        self.cache = M.cache_init(cfg, rc, n_slots, max_seq=max_seq)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.qos = QoSController()
        self.store = HostPageStore()
        self.flusher = ds.StagingFlusher(
            sink=lambda rid, kv: self.store.put(rid, kv), qos=self.qos)
        self.step_fn = jax.jit(self._step)
        self.stats = {"steps": 0, "prefill_tokens": 0, "decode_tokens": 0,
                      "flushes": 0}

    # ----------------------------------------------------------- step fn
    def _step(self, params, cache, tokens):
        return M.decode_step(params, self.cfg, self.rc, tokens, cache,
                             self.pspecs)

    # ------------------------------------------------------------ admit
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _batch_axes(self):
        """Locate each cache leaf's batch axis (differencing two shapes)."""
        if not hasattr(self, "_baxes"):
            a = M.cache_init(self.cfg, self.rc, 2, max_seq=self.max_seq,
                             as_shape=True)
            b = M.cache_init(self.cfg, self.rc, 3, max_seq=self.max_seq,
                             as_shape=True)
            self._baxes = jax.tree_util.tree_map(
                lambda x, y: next(i for i, (p, q) in
                                  enumerate(zip(x.shape, y.shape))
                                  if p != q), a, b)
        return self._baxes

    def _prefill_slot(self, req: Request, slot: int) -> None:
        """Isolated single-slot prefill, then splice into the batch cache.

        Other slots never observe the prefill (continuous-batching
        isolation); the final prefill logits seed the first sampled token.
        """
        mini = M.cache_init(self.cfg, self.rc, 1, max_seq=self.max_seq)
        logits = None
        for t in req.prompt:
            tok = (jnp.full((1, self.cfg.n_codebooks, 1), t, jnp.int32)
                   if self.cfg.family == "audio"
                   else jnp.full((1, 1), t, jnp.int32))
            logits, mini = self.step_fn(self.params, mini, tok)
            self.stats["prefill_tokens"] += 1

        def splice(dst, src, axis):
            idx = [slice(None)] * dst.ndim
            idx[axis] = slot
            src_idx = [slice(None)] * src.ndim
            src_idx[axis] = 0
            return dst.at[tuple(idx)].set(src[tuple(src_idx)].astype(
                dst.dtype))

        self.cache = jax.tree_util.tree_map(splice, self.cache, mini,
                                            self._batch_axes())
        if logits is not None:
            row = np.asarray(logits.astype(jnp.float32)).reshape(
                -1, logits.shape[-1])[-1]
            req.generated.append(int(row.argmax()))
            self.stats["decode_tokens"] += 1

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            req.slot = slot
            self.slots[slot] = req
            self._prefill_slot(req, slot)

    # ----------------------------------------------------------- advance
    def _advance(self) -> Dict[int, int]:
        """One decode step for every active slot; returns sampled tokens."""
        toks = np.zeros((self.n_slots, 1), np.int32)
        if self.cfg.family == "audio":
            toks = np.zeros((self.n_slots, self.cfg.n_codebooks, 1),
                            np.int32)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            last = req.generated[-1] if req.generated else 0
            if self.cfg.family == "audio":
                toks[slot, :, 0] = last
            else:
                toks[slot, 0] = last
        t0 = time.time()
        logits, self.cache = self.step_fn(self.params, self.cache,
                                          jnp.asarray(toks))
        logits.block_until_ready()
        self.stats["steps"] += 1
        out: Dict[int, int] = {}
        lg = np.asarray(logits.astype(jnp.float32))
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            row = lg[slot, -1] if lg.ndim == 3 else lg[slot, 0, -1]
            if self.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                row = row / self.temperature
                p = np.exp(row - row.max())
                p /= p.sum()
                tok = int(np.random.default_rng(
                    int(jax.random.randint(sub, (), 0, 2**31 - 1))
                ).choice(len(p), p=p))
            else:
                tok = int(row.argmax())
            out[slot] = tok
        return out

    # -------------------------------------------------------------- run
    def _retire(self, slot: int) -> None:
        """Deterministic store: release the slot immediately; its pages
        flush to the host tier in the background."""
        req = self.slots[slot]
        req.done = True
        kv_slot = jax.tree_util.tree_map(
            lambda a: a[:, slot] if a.ndim > 1 else a[slot],
            self.cache["kv"]) if "kv" in self.cache else None
        if kv_slot is not None:
            self.flusher.stage(req.rid, kv_slot)
        self.finished.append(req)
        self.slots[slot] = None

    def _check_done(self, slot: int) -> None:
        req = self.slots[slot]
        pos = int(np.asarray(self.cache["pos"])[slot])
        if (len(req.generated) >= req.max_new_tokens
                or pos >= self.max_seq - 1):
            self._retire(slot)

    def step(self) -> None:
        """One engine tick: admit, decode, retire, background-flush."""
        self._admit()
        for slot in range(self.n_slots):
            if self.slots[slot] is not None:
                self._check_done(slot)     # prefill may already satisfy
        if not any(s is not None for s in self.slots):
            return
        sampled = self._advance()
        for slot, tok in sampled.items():
            req = self.slots[slot]
            req.generated.append(tok)
            self.stats["decode_tokens"] += 1
            self._check_done(slot)
        # QoS: occupancy = queue pressure; flushes gated by DevLoad
        occ = len(self.flusher.pending) / max(self.n_slots * 2, 1)
        dl = self.qos.classify(occupancy=min(occ, 1.0), service_ratio=1.0)
        self.qos.update(dl)
        self.stats["flushes"] += self.flusher.maybe_flush()

    def run(self, max_ticks: int = 1000) -> List[Request]:
        ticks = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        self.flusher.maybe_flush()
        return self.finished
