"""Continuous-batching serving engine over the paged, tiered KV cache.

The paper's storage-expansion loop, at request granularity:

 * slots — the engine runs a fixed decode batch; requests stream through
   slots (continuous batching). Each slot owns a page range of the
   distributed cache and its own position (per-slot `pos` vector).
 * tiered pages — a finished slot's pages are not dropped: they retire
   through the ``StagingRing`` (deterministic store: the release is
   immediate; the flush to the cold tier happens in the background, gated
   by the QoS controller exactly like Fig. 8) into the host-side page
   store, keyed by request id — prefix reuse fetches them back (the
   speculative-read path) instead of re-prefilling.
 * QoS — per-step telemetry drives the same DevLoad machine the training
   driver and the simulator use; under congestion flushes pause and the
   prefetch window narrows.
 * CXL timing — with a ``repro.core.tier.CxlTier`` attached, every page
   movement is charged against the simulated endpoint: restores stall for
   the demand fetch (hidden by the MemSpecRd issued at enqueue time),
   flushes ride the deterministic-store path, and the EP's announced
   state (DevLoad / internal tasks) gates the flusher's admission window.
   Per-request stalls land on ``Request.restore_stall_ns``; aggregates in
   ``engine.stats`` (restore_stall_ns, tier_sr_hit_rate,
   tier_store_occupancy, flushes_deferred).

The hot path is device-resident:

 * prefill — chunked multi-token ingestion (``models.model.
   prefill_step_cached``): each chunk is one jitted dispatch that slices
   the request's slot out of the batch cache, writes the chunk's K/V
   in-graph (``dynamic_update_slice``) and splices the slot back — no
   per-token dispatch, no host-side cache surgery.
 * decode — one jitted dispatch per tick that runs the page-sharded
   ``decode_step`` for every slot AND samples the next token on device
   (argmax, or inverse-CDF categorical sampling via the jax PRNG — see
   ``models.model.sample_tokens``). Last tokens, positions and the PRNG
   key stay device arrays across ticks; the host never calls
   ``block_until_ready`` or reads logits except when a slot retires.
 * prefix reuse — on admit, a request whose rid (or prompt) matches a
   retired entry in the staging index or the host page store restores its
   pages into the slot (the speculative-read fetch) with zero prefill
   dispatches.

Admission is owned by the request-lifecycle scheduler
(``repro.serving.scheduler``): requests move through an explicit state
machine (QUEUED -> RESTORING -> RUNNING -> PREEMPTED/SWAPPED ->
RETIRED). With ``cxl_async=True`` cold-tier restores are issued as
completion-based async ops — the slot sits RESTORING while the rest of
the batch decodes, hiding the media latency — and flushes become
background ops; ``preempt_policy`` ("swap"/"recompute") lets the
scheduler evict a low-priority slot to the CXL tier under pressure and
admit queued work instead of idling. The defaults (``cxl_async=False``,
``preempt_policy="none"``) reproduce the blocking greedy-FIFO engine
bit-for-bit.

``legacy_host_path=True`` preserves the pre-rewrite hot path (per-token
prefill dispatches, host softmax/numpy sampling, per-tick logits
transfer + sync) as the measured baseline for ``benchmarks/serve_bench``.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core import deterministic_store as ds
from repro.core.qos import DevLoad, QoSController
from repro.core.tier import CxlTier
from repro.models import model as M
from repro.parallel import sharding as shlib
from repro.serving import scheduler as sched
from repro.serving.config import ServeConfig
from repro.serving.stats import EngineStats


@dataclasses.dataclass
class Request:
    """One serving request: prompt tokens in, generated tokens out.

    ``restore_stall_ns`` is the simulated CXL demand-fetch stall (ns)
    charged when the request was served via a cold-tier prefix restore
    (0.0 otherwise or without an attached tier). ``priority`` orders
    admission (higher first, FIFO among equals) and marks preemption
    victims; ``state`` walks the scheduler's lifecycle (QUEUED ->
    RESTORING -> RUNNING -> PREEMPTED/SWAPPED -> RETIRED, see
    ``repro.serving.scheduler``).
    """

    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    priority: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None
    state: str = sched.QUEUED       # scheduler lifecycle state
    restored: bool = False          # served via prefix restore (no prefill)
    restore_stall_ns: float = 0.0   # simulated CXL fetch stall (cold-tier
                                    # restore through the CxlTier, else 0)
    recoveries: int = 0             # failed-fetch / page-loss re-queues
                                    # (RECOVERING transitions survived)
    # SLO timestamps on the engine's simulated clock (``engine.clock_ns``,
    # tier_step_ns per working tick plus open-loop idle jumps): stamped at
    # submit / first sampled token / retirement, read back through the
    # RequestHandle's ttft_ns / tpot_ns properties.
    arrival_ns: Optional[float] = None
    first_token_ns: Optional[float] = None
    finish_ns: Optional[float] = None
    # device-resident bookkeeping: the sampled-token handle plus this
    # request's tick range in the engine trace; the host only materializes
    # tokens at retirement (one [n_slots] transfer per tick, memoized
    # across co-retiring slots)
    _first_tok: Optional[jax.Array] = dataclasses.field(
        default=None, repr=False)
    _start_tick: int = 0
    _n_gen: int = 0                 # total generated tokens (stop check)
    _n_dec: int = 0                 # decode ticks participated (trace span)


class RequestHandle:
    """What ``ServingEngine.submit`` returns: one request's progress view.

    Callers poll :meth:`done` / read :meth:`result` instead of fishing
    retired ``Request`` objects out of ``run()``'s return list (which
    still returns them, as the deprecation shim for the old shape). The
    timing properties expose the per-request SLO measurements on the
    engine's simulated clock — the raw material ``loadgen.summarize``
    folds into TTFT/TPOT percentiles and goodput.
    """

    def __init__(self, request: Request, engine: "ServingEngine"):
        self._req = request
        self._engine = engine

    @property
    def rid(self) -> int:
        """The submitted request's id."""
        return self._req.rid

    @property
    def request(self) -> Request:
        """The underlying ``Request`` (escape hatch for tests/tools)."""
        return self._req

    def done(self) -> bool:
        """True once the request retired (its token stream is final)."""
        return self._req.done

    def result(self) -> List[int]:
        """The generated token stream; raises while still pending."""
        if not self._req.done:
            raise RuntimeError(f"request {self._req.rid} is still "
                               f"{self._req.state}; call done() first")
        return list(self._req.generated)

    def tokens(self) -> List[int]:
        """Tokens materialized so far (empty until retirement on the
        device-resident path — the stream lives on device mid-flight)."""
        return list(self._req.generated)

    @property
    def ttft_ns(self) -> Optional[float]:
        """Time to first token (simulated ns), None until it exists."""
        if self._req.first_token_ns is None or self._req.arrival_ns is None:
            return None
        return self._req.first_token_ns - self._req.arrival_ns

    @property
    def tpot_ns(self) -> Optional[float]:
        """Mean time per output token after the first (simulated ns)."""
        if self._req.finish_ns is None or self._req.first_token_ns is None:
            return None
        span = self._req.finish_ns - self._req.first_token_ns
        return span / max(len(self._req.generated) - 1, 1)

    @property
    def restore_stall_ns(self) -> float:
        """Simulated ns this request stalled on cold-tier fetches."""
        return self._req.restore_stall_ns

    @property
    def recoveries(self) -> int:
        """RECOVERING re-queues this request survived (failed tier
        fetches and pages lost to a hot-removed port; 0 without faults)."""
        return self._req.recoveries


# Families whose full per-request decode state lives in the paged "kv"
# leaves — the only ones prefix restore can reconstruct a slot from.
_RESTORABLE_FAMILIES = ("dense", "moe", "audio")


def _fsdp_axis_size() -> int:
    """Product of the pool-tier (FSDP) mesh axes under the active mesh."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    sizes = dict(mesh.shape)
    return sizes.get("data", 1) * sizes.get("pod", 1)


class HostPageStore:
    """Cold tier for retired KV pages (the SSD-EP analogue).

    LRU-bounded by ``budget_bytes``: inserts evict the least-recently-used
    entries until the store fits; ``get`` refreshes recency. ``bytes`` and
    ``evictions`` are surfaced through the engine stats. ``on_evict`` is
    called as ``on_evict(rid, entry, reason)`` for every dropped
    (``reason="evict"``) or replaced (``reason="replace"``) entry so side
    indexes (the engine's prompt->rid alias map) stay bounded too — and so
    the engine can release a truly evicted entry's CXL-tier segments
    without freeing the pages a replacement just rewrote. ``put`` reports
    whether the entry survived admission: budget pressure can evict an
    entry during its own insert (a re-staged rid growing past the budget,
    or any oversized entry), and indexing such an entry would leak — the
    eviction callback for it has already fired by the time ``put``
    returns.
    """

    def __init__(self, budget_bytes: Optional[int] = None, on_evict=None):
        self.pages: "collections.OrderedDict[int, Dict]" = \
            collections.OrderedDict()
        self.budget_bytes = budget_bytes
        self.on_evict = on_evict
        self.bytes = 0
        self.evictions = 0

    # one canonical pytree-size helper for the whole page path: the tier
    # charges the same byte counts this budget is accounted in
    _entry_bytes = staticmethod(CxlTier.entry_bytes)

    def put(self, rid: int, entry) -> bool:
        """Insert/replace; returns True iff ``rid`` survived admission."""
        if not isinstance(entry, dict) or "kv" not in entry:
            entry = {"kv": entry}      # bare-pytree compat (pre-entry API)
        entry = dict(entry)
        entry["kv"] = jax.tree_util.tree_map(np.asarray, entry["kv"])
        if rid in self.pages:
            old = self.pages.pop(rid)
            self.bytes -= self._entry_bytes(old)
            if self.on_evict is not None:
                self.on_evict(rid, old, "replace")
        self.pages[rid] = entry
        self.bytes += self._entry_bytes(entry)
        self._evict()
        return rid in self.pages

    def get(self, rid: int):
        """Fetch ``rid``'s entry (refreshing LRU recency), else None."""
        entry = self.pages.get(rid)
        if entry is not None:
            self.pages.move_to_end(rid)
        return entry

    def drop(self, rid: int) -> bool:
        """Remove ``rid`` outright, regardless of budget or recency.

        The fault-recovery path uses this when the entry's tier copy was
        lost (port hot-removed) or keeps failing its fetch: the next
        lookup misses and the request prefills fresh. Fires ``on_evict``
        with ``reason="evict"`` like an LRU eviction (so side indexes and
        tier segments are released the same way); returns True iff the
        rid was present.
        """
        old = self.pages.pop(rid, None)
        if old is None:
            return False
        self.bytes -= self._entry_bytes(old)
        self.evictions += 1
        if self.on_evict is not None:
            self.on_evict(rid, old, "evict")
        return True

    def _evict(self) -> None:
        if self.budget_bytes is None:
            return
        while self.bytes > self.budget_bytes and self.pages:
            rid, old = self.pages.popitem(last=False)
            self.bytes -= self._entry_bytes(old)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(rid, old, "evict")


class ServingEngine:
    """Fixed-batch continuous batching with tiered page lifecycle."""

    def __init__(self, params, cfg: ModelConfig, rc: RunConfig, *,
                 config: Optional[ServeConfig] = None,
                 cxl_tier: Optional[CxlTier] = None, **knobs):
        """Build the engine from a :class:`ServeConfig`.

        ``config`` carries every knob (slot count, hot-path options,
        scheduler policy, declarative tier attachment); passing the old
        keyword knobs directly (``n_slots=...``, ``cxl_async=...``) still
        works — they construct the ServeConfig, with the same validation.
        ``cxl_tier`` injects a prebuilt tier (tests/benches that need to
        inspect the instance); otherwise ``config.make_tier()`` builds
        whatever the config declares.
        """
        if config is not None and knobs:
            raise TypeError("pass either config=ServeConfig(...) or the "
                            f"legacy keyword knobs, not both: "
                            f"{sorted(knobs)}")
        if config is None:
            config = ServeConfig(**knobs)
        self.serve_config = config
        # quantized KV pages: thread the knob into the RunConfig so
        # cache_init emits int8 pages + scales and every downstream tier
        # charge (flush/restore/swap/SR) sees the quantized byte counts
        if config.kv_quant != "none" and rc.kv_quant != config.kv_quant:
            rc = dataclasses.replace(rc, kv_quant=config.kv_quant)
        # sharded serving: build the (data, model) mesh the config asks
        # for and activate it around every jitted dispatch — params and
        # the paged KV cache shard over the model axis, and
        # paged_decode_attention's shard_map body engages (the page axis
        # carries the tensor parallelism; see models/attention.py)
        self.mesh = None
        mesh_shape = config.resolved_mesh_shape
        if mesh_shape:
            from repro.launch.mesh import make_production_mesh
            self.mesh = make_production_mesh(shape=mesh_shape)
            page = min(rc.kv_page_size, config.max_seq)
            n_pages = max(config.max_seq // page, 1)
            n_ranks = config.n_ranks
            if n_pages % n_ranks:
                raise ValueError(
                    f"sharded decode needs the page axis divisible by the "
                    f"model axis: {n_pages} pages (max_seq={config.max_seq},"
                    f" kv_page_size={rc.kv_page_size}) % {n_ranks} ranks "
                    "!= 0 — lower kv_page_size or adjust max_seq")
        self.params = params
        self.cfg = cfg
        self.rc = rc
        self.n_slots = config.n_slots
        self.max_seq = config.max_seq
        self.temperature = config.temperature
        self.prefill_chunk = max(1, min(config.prefill_chunk,
                                        config.max_seq))
        self.legacy = config.legacy_host_path
        self.sync_prefill = config.sync_prefill
        self.key = jax.random.PRNGKey(config.seed)
        n_slots, max_seq = config.n_slots, config.max_seq
        legacy_host_path = config.legacy_host_path
        self.pspecs = shlib.param_specs(
            jax.eval_shape(lambda: params), tier=rc.param_tier,
            multi_pod_fsdp=rc.mesh.multi_pod)
        # Device-resident hot path: when the pool tier is degenerate (the
        # FSDP axes have size 1, so the SR "gather" fetches nothing) the
        # infer-mode prefetch-buffer rotation is pure per-tick overhead —
        # drop it and unroll the short layer scan. The legacy path keeps
        # the caller's rc untouched (it is the measured pre-rewrite
        # baseline).
        self._hot_rc = rc
        with self._mesh_scope():
            fsdp_size = _fsdp_axis_size()
        if not legacy_host_path and rc.sr_prefetch_depth \
                and fsdp_size == 1:
            self._hot_rc = dataclasses.replace(
                rc, sr_prefetch_depth=0,
                scan_unroll=rc.scan_unroll or min(M.n_stacked(cfg), 8))
        self.cache = M.cache_init(cfg, rc, n_slots, max_seq=max_seq)
        if self.mesh is not None:
            # place params and the paged cache onto the mesh: params via
            # the production sharding rules, cache leaves (pages + int8
            # scales) via cache_specs — the page axis lands on "model"
            self.params = jax.device_put(
                params, shlib.shardings_from_specs(self.mesh, self.pspecs))
            cspecs = M.cache_specs(cfg, self._hot_rc, n_slots)
            self.cache = jax.device_put(
                self.cache, shlib.shardings_from_specs(self.mesh, cspecs))
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.qos = QoSController()
        # CXL-timed tier: every page movement below is charged against the
        # simulated endpoint (restore stall, flush cost, SR prefetch), and
        # the EP's announced state gates the flusher's admission window.
        self.tier = cxl_tier if cxl_tier is not None else config.make_tier()
        self.tier_step_ns = config.tier_step_ns
        self.cxl_async = bool(config.cxl_async)
        self._restorable = cfg.family in _RESTORABLE_FAMILIES
        # the engine's simulated clock: tier_step_ns per working tick plus
        # explicit open-loop idle jumps (advance_time). All per-request
        # SLO timestamps (arrival/first-token/finish) land on it.
        self.clock_ns = 0.0
        # outstanding async background writes (flush / swap-out): their
        # TierHandles are polled each tick and drained at run()'s horizon
        # so end-of-run in-flight depth is consistent.
        self._async_writes: List = []
        # request-lifecycle scheduler: admission, async restore
        # activation and preemption decisions live there; with async off
        # and preempt_policy="none" it reproduces the old greedy-FIFO
        # blocking admission exactly.
        self.scheduler = sched.RequestScheduler(
            self, async_restore=self.cxl_async,
            preempt_policy=config.preempt_policy,
            admit_mode=config.admit_mode)
        self.store = HostPageStore(budget_bytes=config.store_budget_bytes,
                                   on_evict=self._drop_prompt_alias)
        self._prompt_index: Dict[Tuple[int, ...], int] = {}
        self.flusher = ds.StagingFlusher(
            sink=self._store_sink, qos=self.qos,
            admit=self.tier.admit_store if self.tier is not None else None)
        # device-resident tick state (new path)
        self.last_tokens = jnp.zeros((n_slots,), jnp.int32)
        self._pos_host = [0] * n_slots      # mirror of cache["pos"]
        self._tick = 0                      # decode ticks executed
        self._trace: Dict[int, jax.Array] = {}      # tick -> [n_slots] toks
        self._trace_np: Dict[int, np.ndarray] = {}  # memoized transfers
        # jitted hot-path entry points (traced lazily on first use). The
        # batch cache is donated: nothing on the host ever re-reads an old
        # cache, and aliasing in/out buffers saves a full cache copy per
        # tick (last_tokens/key are NOT donated — the token trace keeps
        # handles to old tick outputs until retirement).
        self.step_fn = jax.jit(self._step)                  # legacy decode
        self._decode_fn = jax.jit(self._decode_sample, donate_argnums=(1,))
        self._prefill_fn = jax.jit(self._prefill_chunk_body,
                                   donate_argnums=(1,), static_argnums=(8,))
        # typed stats: field list = schema (see repro.serving.stats). The
        # mapping protocol keeps every stats["..."] call site unchanged,
        # and a typo'd key raises KeyError instead of silently growing
        # the bench schema.
        self.stats = EngineStats()
        self.stats["mesh_ranks"] = (config.n_ranks if self.mesh is not None
                                    else 1)

    # ----------------------------------------------------------- step fns
    def _mesh_scope(self):
        """Context activating the engine's mesh (no-op when unsharded).

        jax's ``set_mesh`` is a lexical context manager, so the engine
        scopes it around every jitted dispatch: tracing then sees the
        (data, model) mesh and the page-sharded decode takes the
        shard_map path with a real model axis.
        """
        if self.mesh is None:
            return contextlib.nullcontext()
        return jax.set_mesh(self.mesh)

    def _step(self, params, cache, tokens):
        return M.decode_step(params, self.cfg, self.rc, tokens, cache,
                             self.pspecs)

    def _decode_sample(self, params, cache, last_tokens, key):
        """One fused decode tick: step every slot + sample on device."""
        if self.cfg.family == "audio":
            toks = jnp.broadcast_to(
                last_tokens[:, None, None],
                (self.n_slots, self.cfg.n_codebooks, 1))
        else:
            toks = last_tokens[:, None]
        logits, cache = M.decode_step(params, self.cfg, self._hot_rc, toks,
                                      cache, self.pspecs)
        row = M.last_token_logits(logits)
        if self.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = M.sample_tokens(row, sub, self.temperature)
        else:
            nxt = M.sample_tokens(row, None, 0.0)
        return cache, nxt, key

    def _prefill_chunk_body(self, params, cache, tokens, slot, pos0,
                            new_pos, last_tokens, key, sample):
        """One prefill chunk for one slot, entirely in-graph.

        Slices the slot out of the batch cache, pins the slot position to
        the chunk start (a reused slot's device pos is stale — decode
        advances every row each tick), runs the chunked cache-writing
        prefill, and splices the slot back (dynamic_update_slice along
        each leaf's batch axis). Only the final chunk (``sample=True``,
        static) samples the last-position token on device — one PRNG
        split per request, so sampled streams do not depend on the chunk
        size. Other slots never observe the prefill (continuous-batching
        isolation).
        """
        baxes = self._batch_axes()
        cache1 = jax.tree_util.tree_map(
            lambda a, ax: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax),
            cache, baxes)
        cache1["pos"] = jnp.full((1,), pos0, jnp.int32)
        logits, cache1 = M.prefill_step_cached(params, self.cfg,
                                               self._hot_rc, tokens, cache1,
                                               self.pspecs)
        cache1["pos"] = jnp.full((1,), new_pos, jnp.int32)
        cache = jax.tree_util.tree_map(
            lambda a, a1, ax: jax.lax.dynamic_update_slice_in_dim(
                a, a1.astype(a.dtype), slot, axis=ax),
            cache, cache1, baxes)
        if not sample:
            return cache
        row = M.last_token_logits(logits)            # [1, V]
        if self.temperature > 0:
            key, sub = jax.random.split(key)
            tok = M.sample_tokens(row, sub, self.temperature)[0]
        else:
            tok = M.sample_tokens(row, None, 0.0)[0]
        last_tokens = last_tokens.at[slot].set(tok)
        return cache, last_tokens, tok, key

    # ------------------------------------------------------------ admit
    def submit(self, req: Request, *,
               arrival_ns: Optional[float] = None) -> RequestHandle:
        """Enqueue a request (admission happens on a later tick).

        Returns a :class:`RequestHandle` the caller polls for completion
        and per-request SLO timings. ``arrival_ns`` backdates the arrival
        timestamp onto the simulated clock (the open-loop driver submits
        a trace whose arrival times were generated ahead of the run);
        default is the engine clock at submit time.
        """
        req.arrival_ns = (self.clock_ns if arrival_ns is None
                          else float(arrival_ns))
        # Speculative read at enqueue time: if this request's pages sit in
        # the cold tier, pre-share the addresses with the EP (MemSpecRd)
        # now — admission happens ticks later, so the fill runs ahead of
        # the demand fetch the restore will stall on.
        if self.tier is not None and not self.legacy \
                and self.cfg.family in _RESTORABLE_FAMILIES:
            key = self._store_key(req.rid, tuple(req.prompt))
            if key is not None:
                self.tier.speculative_read(
                    key, CxlTier.entry_bytes(self.store.pages[key]))
        self.queue.append(req)
        return RequestHandle(req, self)

    def _batch_axes(self):
        """Locate each cache leaf's batch axis (differencing two shapes)."""
        if not hasattr(self, "_baxes"):
            a = M.cache_init(self.cfg, self.rc, 2, max_seq=self.max_seq,
                             as_shape=True)
            b = M.cache_init(self.cfg, self.rc, 3, max_seq=self.max_seq,
                             as_shape=True)
            self._baxes = jax.tree_util.tree_map(
                lambda x, y: next(i for i, (p, q) in
                                  enumerate(zip(x.shape, y.shape))
                                  if p != q), a, b)
        return self._baxes

    def _prefill_slot(self, req: Request, slot: int,
                      tokens: Optional[List[int]] = None) -> None:
        """Chunked device-resident prefill: one dispatch per chunk.

        ``tokens`` overrides the ingested sequence (default: the
        request's prompt) — the recompute-resume path feeds the prompt
        plus the already-generated prefix through the same chunked path.
        """
        prompt = list(req.prompt) if tokens is None else list(tokens)
        if len(prompt) + 1 > self.max_seq:
            raise ValueError(f"prompt ({len(prompt)} tokens) does not fit "
                             f"a {self.max_seq}-token slot")
        c = self.prefill_chunk
        chunks = [prompt[i:i + c] for i in range(0, len(prompt), c)]
        pos0, tok = 0, None
        for i, chunk in enumerate(chunks):
            arr = np.asarray(chunk, np.int32)[None]          # [1, c]
            if self.cfg.family == "audio":
                arr = np.broadcast_to(
                    arr[:, None],
                    (1, self.cfg.n_codebooks, len(chunk))).copy()
            final = i == len(chunks) - 1
            with self._mesh_scope():
                out = self._prefill_fn(self.params, self.cache,
                                       jnp.asarray(arr), slot, pos0,
                                       pos0 + len(chunk), self.last_tokens,
                                       self.key, final)
            if final:
                self.cache, self.last_tokens, tok, self.key = out
            else:
                self.cache = out
            pos0 += len(chunk)
            self.stats["prefill_dispatches"] += 1
        self.stats["prefill_tokens"] += len(prompt)
        self._pos_host[slot] = len(prompt)
        req._first_tok = tok
        req._start_tick = self._tick
        req._n_gen = 1
        req._n_dec = 0
        if req.first_token_ns is None:
            req.first_token_ns = self.clock_ns
        self.stats["decode_tokens"] += 1
        if self.sync_prefill:
            tok.block_until_ready()

    def _prefill_slot_legacy(self, req: Request, slot: int) -> None:
        """Pre-rewrite path: one decode_step dispatch per prompt token on a
        mini cache, host-side splice, host argmax. Kept as the serve_bench
        baseline."""
        mini = M.cache_init(self.cfg, self.rc, 1, max_seq=self.max_seq)
        logits = None
        for t in req.prompt:
            tok = (jnp.full((1, self.cfg.n_codebooks, 1), t, jnp.int32)
                   if self.cfg.family == "audio"
                   else jnp.full((1, 1), t, jnp.int32))
            with self._mesh_scope():
                logits, mini = self.step_fn(self.params, mini, tok)
            self.stats["prefill_tokens"] += 1
            self.stats["prefill_dispatches"] += 1

        def splice(dst, src, axis):
            idx = [slice(None)] * dst.ndim
            idx[axis] = slot
            src_idx = [slice(None)] * src.ndim
            src_idx[axis] = 0
            return dst.at[tuple(idx)].set(src[tuple(src_idx)].astype(
                dst.dtype))

        self.cache = jax.tree_util.tree_map(splice, self.cache, mini,
                                            self._batch_axes())
        if logits is not None:
            row = np.asarray(logits.astype(jnp.float32)).reshape(
                -1, logits.shape[-1])[-1]
            req.generated.append(int(row.argmax()))
            if req.first_token_ns is None:
                req.first_token_ns = self.clock_ns
            self.stats["decode_tokens"] += 1

    # ----------------------------------------------------- prefix restore
    def _store_key(self, rid: int, prompt: Tuple[int, ...]) -> Optional[int]:
        """Cold-tier key holding pages for (rid, prompt), else None.

        A *confirmed* hit refreshes the entry's LRU recency (via
        ``store.get``): the queued request will demand-fetch exactly
        those pages at admission, ticks from now — without the touch a
        hot, about-to-be-restored prefix could age out behind entries no
        one is waiting for, turning the queued SR into a wasted prefetch
        and the restore into a full re-prefill. Mismatched probes still
        read ``store.pages`` directly and leave recency alone."""
        entry = self.store.pages.get(rid)
        if entry is not None and entry.get("prompt") == prompt:
            self.store.get(rid)
            return rid
        alias = self._prompt_index.get(prompt)
        if alias is not None:
            entry = self.store.pages.get(alias)
            if entry is not None and entry.get("prompt") == prompt:
                self.store.get(alias)
                return alias
        return None

    def _lookup_pages(self, rid: int, prompt: Tuple[int, ...]):
        """Staging index first (latest-write-wins, the deterministic-store
        read path), then the cold tier; rid match first, then prompt.

        Returns ``(entry, store_key, source)``: source "staging" is the
        read-through path (reserved GPU memory — no CXL fetch to charge),
        source "store" is a cold-tier hit whose demand fetch the restore
        stalls on (charged against the CxlTier when one is attached)."""
        for _, entry in reversed(self.flusher.pending):
            if isinstance(entry, dict) and entry.get("prompt") == prompt:
                return entry, None, "staging"
        entry = self.store.get(rid)
        if entry is not None and entry.get("prompt") == prompt:
            return entry, rid, "store"
        alias = self._prompt_index.get(prompt)
        if alias is not None and alias != rid:
            entry = self.store.get(alias)
            if entry is not None and entry.get("prompt") == prompt:
                return entry, alias, "store"
        return None, None, None

    def _restore_lookup(self, req: Request):
        """Restorable (entry, store_key, source) for ``req``, else None.

        Pure lookup — no timing is charged; the scheduler decides whether
        the fetch is blocking or issued asynchronously."""
        if not self._restorable:
            return None
        entry, key, source = self._lookup_pages(req.rid, tuple(req.prompt))
        if entry is None or "pos" not in entry or "first_token" not in entry:
            return None
        if int(entry["pos"]) >= self.max_seq - 1:
            return None                       # no room left to decode into
        return entry, key, source

    def _apply_restore(self, req: Request, slot: int, entry) -> None:
        """Rebuild the slot from a retired entry (the data half of the
        speculative-read fetch; any simulated stall was already charged).

        The stored entry captures the *post-prefill* state — pages plus
        the prompt's first sampled token at pos=len(prompt) — so a
        restored request reproduces the prompt-conditioned continuation
        (greedy-identical to a fresh prefill) rather than extending the
        previous generation.
        """
        first = int(entry["first_token"])
        kv = jax.tree_util.tree_map(jnp.asarray, entry["kv"])
        self.cache["kv"] = jax.tree_util.tree_map(
            lambda a, h: a.at[:, slot].set(h.astype(a.dtype)),
            self.cache["kv"], kv)
        self.cache["pos"] = self.cache["pos"].at[slot].set(
            int(entry["pos"]))
        self.last_tokens = self.last_tokens.at[slot].set(first)
        self._pos_host[slot] = int(entry["pos"])
        req.restored = True
        req._first_tok = None
        req._start_tick = self._tick
        req.generated = req.generated + [first]
        req._n_gen = 1
        req._n_dec = 0
        if req.first_token_ns is None:
            req.first_token_ns = self.clock_ns

    # -------------------------------------------------- preemption state
    def _capture_slot_kv(self, slot: int):
        """This slot's KV pages as a host-free pytree view (or None)."""
        if "kv" not in self.cache:
            return None
        return jax.tree_util.tree_map(
            lambda a: a[:, slot] if a.ndim > 1 else a[slot],
            self.cache["kv"])

    def _capture_swap_entry(self, req: Request, slot: int) -> Dict:
        """Snapshot a running slot's mid-decode state for swap-out:
        pages, current position and the last sampled token — everything a
        swap-in needs to continue the stream bit-for-bit (greedy)."""
        kv = self._capture_slot_kv(slot)
        if kv is not None:
            kv = jax.tree_util.tree_map(np.asarray, kv)
        return {"kv": kv, "pos": self._pos_host[slot],
                "last_token": req.generated[-1] if req.generated else 0,
                "prompt": tuple(req.prompt)}

    def _apply_swap_in(self, req: Request, slot: int, entry) -> None:
        """Resume a swapped-out request: pages, position and last token
        back into the slot; decode continues where it was preempted."""
        kv = jax.tree_util.tree_map(jnp.asarray, entry["kv"])
        self.cache["kv"] = jax.tree_util.tree_map(
            lambda a, h: a.at[:, slot].set(h.astype(a.dtype)),
            self.cache["kv"], kv)
        pos = int(entry["pos"])
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos)
        self.last_tokens = self.last_tokens.at[slot].set(
            int(entry["last_token"]))
        self._pos_host[slot] = pos
        req._first_tok = None
        req._start_tick = self._tick
        req._n_gen = len(req.generated)
        req._n_dec = 0

    def _recompute_resume(self, req: Request, slot: int) -> None:
        """Resume a recompute-preempted request by re-prefilling the
        prompt plus the already-generated prefix (pages were dropped at
        preemption — the compute-for-capacity trade of the policy flag).

        The chunked prefill re-derives the KV for every consumed token;
        its re-sampled final token is discarded — the stream already
        holds it (``generated[-1]``), which becomes the next decode
        input, so the greedy continuation is unchanged.
        """
        if not req.generated:             # preempted pre-prefill: fresh
            self._prefill_slot(req, slot)
            return
        fed = list(req.prompt) + req.generated[:-1]
        self._prefill_slot(req, slot, tokens=fed)
        req._first_tok = None             # drop the re-sampled duplicate
        self.stats["decode_tokens"] -= 1
        req._n_gen = len(req.generated)
        self.last_tokens = self.last_tokens.at[slot].set(
            int(req.generated[-1]))

    # ----------------------------------------------------------- advance
    def _advance(self) -> None:
        """One fused decode+sample dispatch; tokens stay on device."""
        with self._mesh_scope():
            self.cache, self.last_tokens, self.key = self._decode_fn(
                self.params, self.cache, self.last_tokens, self.key)
        self.stats["steps"] += 1
        self.stats["decode_dispatches"] += 1
        self._trace[self._tick] = self.last_tokens
        self._tick += 1
        for slot, req in enumerate(self.slots):
            self._pos_host[slot] += 1     # decode_step advances every row
            if req is None:
                continue
            req._n_gen += 1
            req._n_dec += 1
            self.stats["decode_tokens"] += 1

    def _advance_legacy(self) -> Dict[int, int]:
        """Pre-rewrite tick: full logits to host, numpy-RNG sampling."""
        toks = np.zeros((self.n_slots, 1), np.int32)
        if self.cfg.family == "audio":
            toks = np.zeros((self.n_slots, self.cfg.n_codebooks, 1),
                            np.int32)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            last = req.generated[-1] if req.generated else 0
            if self.cfg.family == "audio":
                toks[slot, :, 0] = last
            else:
                toks[slot, 0] = last
        with self._mesh_scope():
            logits, self.cache = self.step_fn(self.params, self.cache,
                                              jnp.asarray(toks))
        logits.block_until_ready()
        self.stats["steps"] += 1
        self.stats["decode_dispatches"] += 1
        out: Dict[int, int] = {}
        lg = np.asarray(logits.astype(jnp.float32))
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            row = lg[slot, -1] if lg.ndim == 3 else lg[slot, 0, -1]
            if self.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                row = row / self.temperature
                p = np.exp(row - row.max())
                p /= p.sum()
                tok = int(np.random.default_rng(
                    int(jax.random.randint(sub, (), 0, 2**31 - 1))
                ).choice(len(p), p=p))
            else:
                tok = int(row.argmax())
            out[slot] = tok
        return out

    # -------------------------------------------------------------- run
    def _materialize_tokens(self, req: Request, slot: int) -> None:
        """Pull the request's sampled tokens off the device trace into
        ``req.generated`` (retirement and swap-out both need the stream
        on the host); resets the trace span so a resumed request appends
        cleanly."""
        toks: List[int] = []
        if req._first_tok is not None:
            toks.append(int(np.asarray(req._first_tok)))
        for t in range(req._start_tick, req._start_tick + req._n_dec):
            toks.append(int(self._tok_tick(t)[slot]))
        req.generated = req.generated + toks
        req._first_tok = None
        req._start_tick = self._tick
        req._n_dec = 0

    def _retire(self, slot: int) -> None:
        """Deterministic store: release the slot immediately; its pages
        flush to the host tier in the background. The only host transfers
        on the hot path happen here: the request's sampled tokens and its
        retiring pages."""
        req = self.slots[slot]
        req.done = True
        req.state = sched.RETIRED
        req.finish_ns = self.clock_ns
        if not self.legacy:
            self._materialize_tokens(req, slot)
        kv_slot = self._capture_slot_kv(slot)
        if kv_slot is not None and req.generated:
            # snapshot the post-prefill state: pages + the prompt's first
            # sampled token at pos=len(prompt). Pages beyond the prompt
            # are masked by pos and overwritten as a restored slot decodes.
            self.flusher.stage(req.rid, {
                "kv": kv_slot, "pos": len(req.prompt),
                "first_token": req.generated[0],
                "prompt": tuple(req.prompt)})
        self.finished.append(req)
        self.slots[slot] = None

    def _tok_tick(self, t: int) -> np.ndarray:
        """Materialize one tick's [n_slots] sampled tokens, memoized so
        co-retiring slots share a single transfer."""
        arr = self._trace_np.get(t)
        if arr is None:
            arr = np.asarray(self._trace[t])
            self._trace_np[t] = arr
        return arr

    def _prune_trace(self) -> None:
        """Drop trace entries no live request can still need."""
        starts = [r._start_tick for r in self.slots if r is not None]
        if not starts:
            self._trace.clear()
            self._trace_np.clear()
            return
        low = min(starts)
        for t in [t for t in self._trace if t < low]:
            self._trace.pop(t, None)
            self._trace_np.pop(t, None)

    def _drop_prompt_alias(self, rid: int, entry, reason: str) -> None:
        """Keep side state in lockstep with store evictions.

        Drops the prompt->rid alias for the departing entry and — only
        for true LRU evictions (``reason="evict"``) — releases the
        entry's CXL-tier segments for reuse. A ``"replace"`` fires while
        the same rid's fresh pages are being re-inserted (the flush
        already rewrote the tier segments in place), so freeing there
        would tear down ranges that are still live.
        """
        if isinstance(entry, dict):
            prompt = entry.get("prompt")
            if prompt is not None and self._prompt_index.get(prompt) == rid:
                del self._prompt_index[prompt]
        if reason == "evict" and self.tier is not None:
            self.tier.free_entry(rid)

    def _store_sink(self, rid: int, entry) -> None:
        if self.tier is not None:
            # the background drain: page writes ride the deterministic-
            # store path (GPU-speed completion, divert under congestion).
            # In async mode the flush is a background op — the writer is
            # held only for the issue-slot wait and the media work
            # completes on the port cursors as simulated time passes.
            nbytes = CxlTier.entry_bytes(entry)
            if self.cxl_async:
                handle = self.tier.write_entry_async(rid, nbytes)
                self._async_writes.append(handle)
                self.stats["tier_write_ns"] += handle.issue_wait_ns
                self.scheduler._note_inflight_peak()
            else:
                self.stats["tier_write_ns"] += self.tier.write_entry(
                    rid, nbytes)
        kept = self.store.put(rid, entry)
        # alias only entries that survived admission: budget pressure can
        # evict an entry during its own put (oversized, or a re-staged rid
        # growing past the budget), and its on_evict has already fired —
        # indexing it afterwards would leak a dangling prompt alias
        if kept and isinstance(entry, dict) and "prompt" in entry:
            self._prompt_index[entry["prompt"]] = rid

    def _n_generated(self, req: Request) -> int:
        return len(req.generated) if self.legacy else req._n_gen

    def _check_done(self, slot: int) -> None:
        req = self.slots[slot]
        pos = (int(np.asarray(self.cache["pos"])[slot]) if self.legacy
               else self._pos_host[slot])
        if (self._n_generated(req) >= req.max_new_tokens
                or pos >= self.max_seq - 1):
            self._retire(slot)

    def step(self) -> None:
        """One engine tick: schedule (activate/preempt/admit), decode,
        retire, background-flush.

        A slot whose restore is still in flight does not stall the
        batch: the other slots keep decoding and the slot activates on
        the tick its completion lands. Only when *every* occupied slot
        is awaiting a fetch does the tick idle — that simulated time is
        exposed stall, accounted against the overlap ratio."""
        self.scheduler.begin_tick()
        for slot in range(self.n_slots):
            if self.slots[slot] is not None:
                self._check_done(slot)   # prefill/restore may already satisfy
        active = any(s is not None for s in self.slots)
        if not active and not self.scheduler.busy():
            return
        if not active:
            # all occupied slots are RESTORING: the batch idles this tick
            # while simulated time (below) brings the completions closer
            self.scheduler.note_blocked_tick(self.tier_step_ns)
        elif self.legacy:
            sampled = self._advance_legacy()
            for slot, tok in sampled.items():
                req = self.slots[slot]
                req.generated.append(tok)
                self.stats["decode_tokens"] += 1
                self._check_done(slot)
        else:
            self._advance()
            for slot in range(self.n_slots):
                if self.slots[slot] is not None:
                    self._check_done(slot)
        if not self.legacy:
            self._prune_trace()
        # QoS: occupancy = queue pressure; flushes gated by DevLoad
        occ = len(self.flusher.pending) / max(self.n_slots * 2, 1)
        dl = self.qos.classify(occupancy=min(occ, 1.0), service_ratio=1.0)
        self.qos.update(dl)
        self.stats["flushes"] += self.flusher.maybe_flush()
        self._tier_tick()
        self.stats["store_bytes"] = self.store.bytes
        self.stats["store_evictions"] = self.store.evictions

    def _tier_tick(self) -> None:
        """Advance simulated time one engine tick and surface tier +
        scheduler state.

        With a multi-port tier attached this is also the blocking-op
        drain barrier: per-port clocks (which skew freely within a tick)
        realign, while async op handles keep riding the service cursors
        until simulated time reaches their completions. All surfaced
        telemetry is live and cheap — ``tier.port_stats()`` updates its
        per-port dicts in place, so reading it every tick costs no
        allocation churn and no drain."""
        self.clock_ns += self.tier_step_ns
        self.stats["clock_ns"] = self.clock_ns
        self.stats["flush_backlog"] = len(self.flusher.pending)
        ss = self.scheduler.stats
        self.stats["preemptions"] = ss["preemptions"]
        self.stats["swap_out_bytes"] = ss["swap_out_bytes"]
        self.stats["swap_in_bytes"] = ss["swap_in_bytes"]
        self.stats["restore_inflight_ns"] = ss["restore_inflight_ns"]
        infl = ss["restore_inflight_ns"]
        self.stats["restore_overlap_ratio"] = max(
            0.0, 1.0 - ss["restore_exposed_ns"] / infl) if infl > 0 else 0.0
        self.stats["sched_inflight_peak"] = ss["inflight_peak"]
        self.stats["recoveries"] = ss["recoveries"]
        if self.tier is None:
            return
        self.tier.advance(self.tier_step_ns)
        self._fault_sweep()
        if self._async_writes:      # retire completed background flushes
            self._async_writes = [h for h in self._async_writes
                                  if not self.tier.poll(h)]
        self.stats["sim_time_ns"] = self.tier.topo.now
        self.stats["sched_inflight_ops"] = self.tier.inflight_ops()
        self.stats["tier_sr_hit_rate"] = self.tier.sr_hit_rate()
        self.stats["tier_store_occupancy"] = self.tier.store_occupancy()
        self.stats["tier_ports"] = self.tier.port_stats()
        self.stats["flushes_deferred"] = self.flusher.deferred
        tc = self.tier.counters
        self.stats["tier_promotions"] = tc["promotions"]
        self.stats["tier_demotions"] = tc["demotions"]
        self.stats["tier_migrate_ns"] = tc["migrate_ns"]
        self.stats["tier_fault_ops"] = tc["fault_ops"]
        self.stats["tier_lost_entries"] = tc["lost_entries"]
        self.stats["tier_lost_bytes"] = tc["lost_bytes"]
        self.stats["tier_fault_retries"] = sum(
            p.fault_retries for p in self.tier.topo.ports)
        self.stats["tier_fault_failures"] = sum(
            p.fault_failures for p in self.tier.topo.ports)
        self.stats["tier_ports_down"] = len(self.tier.topo.ports_down())
        if "peer_fetches" in tc:        # ShardedTier: cross-rank telemetry
            self.stats["tier_peer_fetches"] = tc["peer_fetches"]
            self.stats["tier_peer_bytes"] = tc["peer_bytes"]
            self.stats["tier_peer_fetch_ns"] = tc["peer_fetch_ns"]
            self.stats["tier_rank_remaps"] = tc["rank_remaps"]
            self.stats["tier_peer_recoveries"] = tc["peer_recoveries"]
            self.stats["tier_rehomes"] = tc["rehomes"]
            self.stats["tier_multi_source_reads"] = tc["multi_source_reads"]

    def _fault_sweep(self) -> None:
        """Fold newly-fired tier faults into serving state.

        ``tier.advance`` already invalidated every entry on a
        hot-removed port; this drains the lost keys and repairs the
        serving side: a lost store entry's host copy is dropped (the
        next lookup misses and prefills fresh — the tier copy it would
        restore from is gone), and a lost swap payload is downgraded to
        a recompute marker (only the token stream survives; resume rides
        the ``preempt_policy="recompute"`` re-prefill path). Runs after
        every simulated-time advance and always before the next tick's
        admissions, so a recovering request can never re-admit against a
        dead copy.
        """
        if self.tier is None:
            return
        for key in self.tier.take_lost_keys():
            if isinstance(key, tuple) and len(key) == 2 \
                    and key[0] == "swap":
                rid = key[1]
                if rid in self.scheduler.swapped:
                    self.scheduler.swapped[rid] = {"recompute": True}
            else:
                self.store.drop(key)

    def advance_time(self, dt_ns: float) -> None:
        """Jump the simulated clock across an idle window (no decode work).

        The open-loop driver calls this when the engine is drained but
        the next arrival is still in the future: the engine clock and the
        tier both see the gap (background flushes complete, QoS ladders
        and GC windows stay live), without charging any decode ticks.
        """
        if dt_ns <= 0:
            return
        self.clock_ns += float(dt_ns)
        self.stats["clock_ns"] = self.clock_ns
        if self.tier is not None:
            self.tier.advance(float(dt_ns))
            self._fault_sweep()
            if self._async_writes:
                self._async_writes = [h for h in self._async_writes
                                      if not self.tier.poll(h)]
            self.stats["sim_time_ns"] = self.tier.topo.now
            self.stats["sched_inflight_ops"] = self.tier.inflight_ops()
        self.stats["flushes"] += self.flusher.maybe_flush()

    def _drain_async(self, guard_ticks: int = 10_000) -> None:
        """Tick simulated time until every outstanding async tier op
        lands: in-flight restores activate (and their slots settle) and
        background flush/swap writes retire their ``TierHandle``s — so
        end-of-run stats (``restore_inflight_ns``, per-port ``inflight``
        depth) are consistent wherever the horizon fell."""
        if self.tier is None:
            return
        ticks = 0
        while (self.scheduler.busy() or self.tier.inflight_ops() > 0) \
                and ticks < guard_ticks:
            self.tier.advance(self.tier_step_ns)
            self.clock_ns += self.tier_step_ns
            self._fault_sweep()
            self.scheduler.drain()
            if self._async_writes:
                self._async_writes = [h for h in self._async_writes
                                      if not self.tier.poll(h)]
            ticks += 1

    def run(self, max_ticks: int = 1000) -> List[Request]:
        """Tick until the queue, slots and in-flight restores drain (or
        ``max_ticks``); returns the finished requests in retirement
        order (the pre-``RequestHandle`` return shape, kept as a shim —
        new callers read their handles instead).

        Whatever the horizon, outstanding async tier ops are drained
        before returning: pending flushes/swap writes complete on the
        simulated clock and in-flight restores land (their requests
        settle into slots; they still need decode ticks to finish)."""
        ticks = 0
        while (self.queue or any(s is not None for s in self.slots)
               or self.scheduler.busy()) and ticks < max_ticks:
            self.step()
            ticks += 1
        self.flusher.maybe_flush()
        self._drain_async()
        self._tier_tick()
        self.stats["store_bytes"] = self.store.bytes
        self.stats["store_evictions"] = self.store.evictions
        return self.finished
