"""Open-loop load generation for the serving engine.

Production serving traffic is *open loop*: arrivals keep coming at the
offered rate whether or not the engine keeps up, so queueing delay —
not per-request service time — dominates the latency a user sees. This
module generates that traffic and drives the engine with it on the
simulated clock:

 * **arrival process** — seeded Poisson (exponential inter-arrival) or
   bursty (interrupted Poisson: geometric-length bursts at
   ``burst_factor`` × the base rate, separated by OFF gaps sized so the
   long-run mean rate still equals ``rate_rps``);
 * **prompt popularity** — a fixed catalog of ``n_prompts`` prompts
   drawn once, then sampled per arrival from a zipf(``zipf_s``)
   rank-frequency distribution, so prefix reuse mirrors millions of
   users sharing a handful of system prompts (the regime the paper's
   speculative-read path is built for);
 * **mixed lengths** — prompt and output lengths drawn per arrival from
   small discrete level sets (bounded jit-trace count on the chunked
   prefill path while still exercising mixed shapes);
 * **priorities** — a ``hi_prio_frac`` fraction of arrivals is tagged
   priority 1 (interactive class), which the FIFO-vs-preempt sweep in
   ``benchmarks/serve_bench.py`` leans on.

:func:`drive_open_loop` injects the trace against ``engine.clock_ns``:
arrivals whose timestamp has passed are submitted, the engine ticks
while it has work, and genuinely idle gaps fast-forward the clock to
the next arrival (charging the idle time to the tier so DevLoad/QoS
state stays live). :func:`summarize` turns the per-request timing the
engine stamped into a :class:`~repro.serving.stats.LoadMetrics` SLO
summary (TTFT/TPOT p50/p99, goodput at the latency target, queue-depth
and restore-stall percentiles).

Everything is deterministic in ``LoadConfig.seed`` — the same seed
reproduces the identical arrival trace, which is what lets the bench
sweep continuous-vs-closed batching and FIFO-vs-preempt on *identical*
traffic. Module-level imports stay numpy-only (``serve_bench`` loads
this file standalone to derive its schema in the jax-free docs CI job);
engine types are imported lazily inside the driver.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

ARRIVAL_MODES = ("poisson", "bursty")


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """One open-loop load scenario, fully determined by its fields.

    Rates are requests per *simulated* second (the engine tick clock:
    ``tier_step_ns`` per working tick); SLO targets are simulated ms.
    ``prompt_len_choices`` / ``max_new_choices`` are the discrete length
    levels arrivals mix over — discrete so the chunked prefill path
    compiles a bounded set of shapes.
    """

    n_arrivals: int = 64             # requests in the trace
    rate_rps: float = 8000.0         # mean offered rate (sim req/s)
    arrival: str = "poisson"         # "poisson" | "bursty"
    burst_factor: float = 8.0        # in-burst rate multiplier (bursty)
    burst_len: int = 8               # mean arrivals per burst (bursty)
    zipf_s: float = 1.1              # prompt-popularity exponent
    n_prompts: int = 32              # distinct prompt catalog size
    prompt_len_choices: Tuple[int, ...] = (8, 16, 32)
    max_new_choices: Tuple[int, ...] = (4, 8, 16)
    vocab: int = 1024                # prompt token id range [1, vocab)
    hi_prio_frac: float = 0.0        # fraction tagged priority 1
    seed: int = 0
    slo_ttft_ms: float = 1.5         # goodput latency targets
    slo_tpot_ms: float = 0.5

    def __post_init__(self):
        """Validate the arrival mode and distribution parameters."""
        if self.arrival not in ARRIVAL_MODES:
            raise ValueError(f"unknown arrival mode {self.arrival!r} "
                             f"(expected one of {ARRIVAL_MODES})")
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0 (got {self.rate_rps})")
        if self.zipf_s <= 0:
            raise ValueError(f"zipf_s must be > 0 (got {self.zipf_s})")
        if self.n_prompts < 1 or self.n_arrivals < 1:
            raise ValueError("n_prompts and n_arrivals must be >= 1")
        if self.arrival == "bursty" and (self.burst_factor <= 1
                                         or self.burst_len < 1):
            raise ValueError("bursty mode needs burst_factor > 1 and "
                             "burst_len >= 1")
        if not self.prompt_len_choices or not self.max_new_choices:
            raise ValueError("length choice sets must be non-empty")

    @classmethod
    def field_names(cls) -> Tuple[str, ...]:
        """Declared field names (the bench's load-config schema)."""
        return tuple(f.name for f in dataclasses.fields(cls))


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One generated arrival: when it lands and what it asks for."""

    rid: int                         # unique request id
    t_ns: float                      # arrival timestamp (sim ns)
    prompt: Tuple[int, ...]          # catalog prompt (shared = reusable)
    prompt_id: int                   # catalog rank (0 = most popular)
    max_new: int                     # output-length budget
    priority: int                    # 0 = batch, 1 = interactive


def zipf_probs(cfg: LoadConfig) -> np.ndarray:
    """Analytic zipf(``zipf_s``) rank probabilities over the catalog.

    ``p[k] ∝ (k + 1) ** -s`` normalized over ``n_prompts`` ranks — the
    distribution :func:`make_trace` samples prompt ids from, exposed so
    tests can check the empirical frequencies against it.
    """
    w = np.arange(1, cfg.n_prompts + 1, dtype=np.float64) ** -cfg.zipf_s
    return w / w.sum()


def _inter_arrivals(cfg: LoadConfig, rng: np.random.Generator) -> np.ndarray:
    """Inter-arrival gaps (sim ns) for ``n_arrivals`` requests.

    Poisson mode draws exponential gaps at ``rate_rps``. Bursty mode is
    an interrupted Poisson process: bursts of geometric(1/``burst_len``)
    arrivals at ``burst_factor`` × the base rate, separated by OFF gaps
    whose mean is sized so the long-run rate still equals ``rate_rps``
    (mean cycle time ``burst_len / rate``).
    """
    base_ns = 1e9 / cfg.rate_rps
    if cfg.arrival == "poisson":
        return rng.exponential(base_ns, size=cfg.n_arrivals)
    hot_ns = base_ns / cfg.burst_factor
    off_mean_ns = cfg.burst_len * base_ns * (1.0 - 1.0 / cfg.burst_factor)
    gaps = np.empty(cfg.n_arrivals)
    left = 0                          # arrivals left in the current burst
    for i in range(cfg.n_arrivals):
        if left == 0:
            left = int(rng.geometric(1.0 / cfg.burst_len))
            gaps[i] = rng.exponential(off_mean_ns) if i else 0.0
        else:
            gaps[i] = rng.exponential(hot_ns)
        left -= 1
    return gaps


def make_trace(cfg: LoadConfig) -> List[Arrival]:
    """Generate the full seeded arrival trace for one scenario.

    One ``default_rng(seed)`` drives every draw in a fixed order, so the
    trace is bit-reproducible: identical configs produce identical
    traces (the property the continuous-vs-closed and FIFO-vs-preempt
    sweeps rely on, and which ``tests/test_loadgen.py`` gates).
    """
    rng = np.random.default_rng(cfg.seed)
    lens = rng.choice(cfg.prompt_len_choices, size=cfg.n_prompts)
    catalog = [tuple(int(t) for t in rng.integers(1, cfg.vocab, size=int(n)))
               for n in lens]
    ranks = rng.choice(cfg.n_prompts, size=cfg.n_arrivals,
                       p=zipf_probs(cfg))
    news = rng.choice(cfg.max_new_choices, size=cfg.n_arrivals)
    prios = (rng.random(cfg.n_arrivals) < cfg.hi_prio_frac).astype(int)
    gaps = _inter_arrivals(cfg, rng)
    t = 0.0
    trace = []
    for i in range(cfg.n_arrivals):
        t += float(gaps[i])
        trace.append(Arrival(rid=i, t_ns=t, prompt=catalog[int(ranks[i])],
                             prompt_id=int(ranks[i]),
                             max_new=int(news[i]), priority=int(prios[i])))
    return trace


def drive_open_loop(engine, trace: List[Arrival], *,
                    max_ticks: int = 100_000):
    """Play an arrival trace against the engine on the simulated clock.

    Each iteration submits every arrival whose timestamp the engine
    clock has passed, then either ticks the engine (when it has queued,
    running or in-flight work) or fast-forwards the clock to the next
    arrival (``engine.advance_time`` — the tier sees the idle window, so
    QoS ladders and background flushes stay live). The loop ends when
    the trace is exhausted and the engine drains, or at ``max_ticks``;
    a final ``engine.run(max_ticks=0)`` drains outstanding async tier
    ops so end-of-run stats are horizon-independent.

    Returns ``(handles, queue_depths)``: one ``RequestHandle`` per
    arrival in trace order, plus the per-tick queue-depth samples the
    SLO summary turns into percentiles.
    """
    from repro.serving.engine import Request

    handles = []
    depths: List[int] = []
    i, ticks = 0, 0
    while True:
        now = engine.clock_ns
        while i < len(trace) and trace[i].t_ns <= now:
            a = trace[i]
            handles.append(engine.submit(
                Request(rid=a.rid, prompt=list(a.prompt),
                        max_new_tokens=a.max_new, priority=a.priority),
                arrival_ns=a.t_ns))
            i += 1
        busy = (engine.queue or any(s is not None for s in engine.slots)
                or engine.scheduler.busy())
        if busy:
            if ticks >= max_ticks:
                break
            engine.step()
            ticks += 1
            depths.append(len(engine.queue))
        elif i < len(trace):
            engine.advance_time(max(trace[i].t_ns - now, 1.0))
        else:
            break
    engine.run(max_ticks=0)           # drain async tier ops at the horizon
    # Fault recovery during that drain can re-queue RECOVERING requests
    # (a failed in-flight fetch has nowhere else to land at the horizon);
    # keep ticking until they finish so page loss never strands work —
    # bounded because each request force-prefills after a few failures.
    extra = 0
    while (engine.queue or any(s is not None for s in engine.slots)
           or engine.scheduler.busy()) and extra < max_ticks:
        engine.step()
        extra += 1
    return handles, depths


def _pct(values, q: float) -> float:
    """Percentile helper returning 0.0 on an empty sample."""
    arr = np.asarray(list(values), dtype=np.float64)
    return float(np.percentile(arr, q)) if arr.size else 0.0


def summarize(engine, handles, queue_depths, cfg: LoadConfig):
    """Fold one driven scenario into a ``LoadMetrics`` SLO summary.

    TTFT/TPOT come from the per-request timestamps the engine stamped on
    its tick clock (``arrival_ns`` / ``first_token_ns`` / ``finish_ns``);
    goodput counts completions within *both* SLO targets per simulated
    second of engine-clock span.
    """
    from repro.serving.stats import LoadMetrics

    done = [h for h in handles if h.done()]
    ttft = [h.ttft_ns / 1e6 for h in done if h.ttft_ns is not None]
    tpot = [h.tpot_ns / 1e6 for h in done if h.tpot_ns is not None]
    stall = [h.restore_stall_ns / 1e6 for h in done]
    in_slo = sum(1 for h in done
                 if h.ttft_ns is not None and h.tpot_ns is not None
                 and h.ttft_ns / 1e6 <= cfg.slo_ttft_ms
                 and h.tpot_ns / 1e6 <= cfg.slo_tpot_ms)
    sim_s = max(engine.clock_ns / 1e9, 1e-12)
    return LoadMetrics(
        arrivals=len(handles),
        completed=len(done),
        completed_in_slo=in_slo,
        goodput_req_s=round(in_slo / sim_s, 2),
        throughput_req_s=round(len(done) / sim_s, 2),
        ttft_ms_p50=round(_pct(ttft, 50), 4),
        ttft_ms_p99=round(_pct(ttft, 99), 4),
        tpot_ms_p50=round(_pct(tpot, 50), 4),
        tpot_ms_p99=round(_pct(tpot, 99), 4),
        queue_depth_p50=round(_pct(queue_depths, 50), 2),
        queue_depth_p99=round(_pct(queue_depths, 99), 2),
        restore_stall_ms_p50=round(_pct(stall, 50), 4),
        restore_stall_ms_p99=round(_pct(stall, 99), 4),
        slo_ttft_ms=cfg.slo_ttft_ms,
        slo_tpot_ms=cfg.slo_tpot_ms,
        sim_time_ms=round(engine.clock_ns / 1e6, 4),
        preemptions=engine.stats["preemptions"],
        prefix_hits=engine.stats["prefix_hits"],
        recoveries=engine.stats["recoveries"],
        lost_requests=len(handles) - len(done),
    )
