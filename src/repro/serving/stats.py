"""Typed serving telemetry: the single source of truth for stat keys.

Two dataclasses whose *field lists* are schema:

 * :class:`EngineStats` — the serving engine's per-run counters
   (``engine.stats``). It replaces the old ad-hoc dict but keeps the
   mapping protocol (``stats["decode_tokens"] += 1``) so every existing
   call site and test reads unchanged; unknown keys raise ``KeyError``
   instead of silently growing the schema.
 * :class:`LoadMetrics` — the SLO summary one open-loop load scenario
   produces (``repro.serving.loadgen.summarize``): TTFT/TPOT
   percentiles, goodput at the latency target, queue-depth and
   restore-stall percentiles.

``benchmarks/serve_bench.py`` derives its ``SCHEMA_KEYS`` sections from
:meth:`EngineStats.field_names` / :meth:`LoadMetrics.field_names`, and
``tools/check_docs.py`` pins the docs/ARCHITECTURE.md schema tables
against the same constant — so the engine's fields, the bench artifact
and the documentation cannot drift independently.

This module is deliberately **pure stdlib** (no jax, no numpy): the CI
docs job imports it (by file path, through serve_bench) in an
environment where only numpy is installed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


class _StatsMapping:
    """Dataclass mixin adding the dict-style protocol over the fields.

    Keys are exactly the dataclass fields: ``__getitem__`` /
    ``__setitem__`` on any other name raise ``KeyError`` (a typo'd stat
    can no longer silently create a key the schema never sees).
    """

    @classmethod
    def field_names(cls) -> Tuple[str, ...]:
        """The declared field names, in declaration order (the schema)."""
        return tuple(f.name for f in dataclasses.fields(cls))

    def __getitem__(self, key: str):
        """Read one stat by name (``stats["decode_tokens"]``)."""
        if key not in self.__dataclass_fields__:
            raise KeyError(key)
        return getattr(self, key)

    def __setitem__(self, key: str, value) -> None:
        """Assign one stat by name; unknown names raise ``KeyError``."""
        if key not in self.__dataclass_fields__:
            raise KeyError(key)
        setattr(self, key, value)

    def __contains__(self, key: str) -> bool:
        """True when ``key`` is a declared stat field."""
        return key in self.__dataclass_fields__

    def keys(self) -> Tuple[str, ...]:
        """Field names, dict-style."""
        return self.field_names()

    def items(self):
        """(name, value) pairs in declaration order, dict-style."""
        return [(k, getattr(self, k)) for k in self.field_names()]

    def values(self) -> List:
        """Field values in declaration order, dict-style."""
        return [getattr(self, k) for k in self.field_names()]

    def as_dict(self) -> dict:
        """A JSON-serializable copy (nested stat dicts are copied)."""
        out = {}
        for k in self.field_names():
            v = getattr(self, k)
            if isinstance(v, list):
                v = [dict(e) if isinstance(e, dict) else e for e in v]
            out[k] = v
        return out


@dataclasses.dataclass
class EngineStats(_StatsMapping):
    """Serving-engine telemetry for one ``ServingEngine`` instance.

    The field list *is* the schema: ``serve_bench.SCHEMA_KEYS`` and the
    documented table in docs/ARCHITECTURE.md both derive from
    :meth:`field_names`. All times are simulated nanoseconds unless the
    suffix says otherwise (``prefill_time_s`` is wall seconds).
    """

    # hot-path counters
    steps: int = 0                       # engine ticks that did work
    prefill_tokens: int = 0              # prompt tokens ingested
    decode_tokens: int = 0               # tokens generated
    flushes: int = 0                     # retired entries flushed to host
    prefill_dispatches: int = 0          # jitted prefill-chunk dispatches
    decode_dispatches: int = 0           # fused decode+sample dispatches
    prefix_hits: int = 0                 # admissions served via restore
    prefill_time_s: float = 0.0          # wall time in prefill (admission)
    store_bytes: int = 0                 # HostPageStore LRU occupancy
    store_evictions: int = 0             # HostPageStore LRU evictions
    # CXL-tier accounting (all zero without a tier): simulated ns the
    # restore path stalled on cold-tier fetches / the flusher held on EP
    # writes, the EP's SR hit rate, DS staging-stack fill, and flush
    # windows the EP deferred (QoS admission).
    restore_stall_ns: float = 0.0
    tier_write_ns: float = 0.0
    tier_sr_hit_rate: float = 0.0
    tier_store_occupancy: float = 0.0
    flush_backlog: int = 0
    flushes_deferred: int = 0
    # per-root-port telemetry (multi-port topologies): occupancy, queue
    # depth, DevLoad, SR hit rate and async in-flight depth per port —
    # refreshed live every tick (tier.port_stats() is an in-place
    # updated view, so this is allocation-free).
    tier_ports: list = dataclasses.field(default_factory=list)
    # placement telemetry (multi-port tiers): entries migrated onto /
    # off the fast ports by the placement policy (``hotness`` counter or
    # the ``learned`` GMM — see repro.sim.policy) and the simulated ns
    # those migrations charged.
    tier_promotions: int = 0
    tier_demotions: int = 0
    tier_migrate_ns: float = 0.0
    # request-lifecycle scheduler telemetry: preempted slots, page bytes
    # swapped out/in through the tier, total async restore in-flight ns
    # and the fraction hidden behind decode (1.0 = fully overlapped),
    # plus current/peak outstanding async tier ops.
    preemptions: int = 0
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0
    restore_inflight_ns: float = 0.0
    restore_overlap_ratio: float = 0.0
    sched_inflight_ops: int = 0
    sched_inflight_peak: int = 0
    # fault injection + recovery (all zero without a FaultSchedule on
    # the tier): page ops that needed transient retries / exhausted the
    # retry budget, entries+bytes lost to hot-removed ports, ports
    # currently down, and requests re-queued through the RECOVERING
    # state after a failed fetch or page loss.
    tier_fault_ops: int = 0
    tier_fault_retries: int = 0
    tier_fault_failures: int = 0
    tier_lost_entries: int = 0
    tier_lost_bytes: int = 0
    tier_ports_down: int = 0
    recoveries: int = 0
    # sharded serving (all zero/1 on a single-rank engine): model-axis
    # rank count, cross-rank peer-link fetches + bytes + link ns served
    # by entry owners, and keys whose ownership migrated to a surviving
    # rank's mirror copy after a fault (the peer-recovery path).
    mesh_ranks: int = 1
    tier_peer_fetches: int = 0
    tier_peer_bytes: int = 0
    tier_peer_fetch_ns: float = 0.0
    tier_rank_remaps: int = 0
    tier_peer_recoveries: int = 0
    # learned cross-rank homing (zero unless placement="learned" on a
    # sharded tier): entries re-homed to their dominant requester rank,
    # and hot restores served multi-source from every live holder.
    tier_rehomes: int = 0
    tier_multi_source_reads: int = 0
    # clocks: the tier topology's simulated time at the last tick, and
    # the engine's own tick clock (tier_step_ns per working tick plus
    # open-loop idle jumps — requests per simulated second and every SLO
    # latency are measured on it).
    sim_time_ns: float = 0.0
    clock_ns: float = 0.0


@dataclasses.dataclass
class LoadMetrics(_StatsMapping):
    """SLO summary of one open-loop load scenario (all latencies ms).

    Produced by ``repro.serving.loadgen.summarize`` from the per-request
    timing the engine stamps on its simulated tick clock:

     * **TTFT** (time to first token) = ``first_token_ns - arrival_ns``
       — queueing + admission + restore wait, everything before the
       first generated token exists.
     * **TPOT** (time per output token) = decode span / (tokens - 1).
     * **goodput** = requests that completed *within both SLO targets*
       (``slo_ttft_ms`` and ``slo_tpot_ms``) per simulated second;
       ``throughput_req_s`` counts every completion regardless of SLO.

    Percentiles over completed requests (TTFT/TPOT/restore stall) and
    over per-tick samples (queue depth).
    """

    arrivals: int = 0                    # requests the trace injected
    completed: int = 0                   # requests retired by the horizon
    completed_in_slo: int = 0            # completed within both SLOs
    goodput_req_s: float = 0.0           # SLO-compliant completions / sim s
    throughput_req_s: float = 0.0        # all completions / sim s
    ttft_ms_p50: float = 0.0
    ttft_ms_p99: float = 0.0
    tpot_ms_p50: float = 0.0
    tpot_ms_p99: float = 0.0
    queue_depth_p50: float = 0.0
    queue_depth_p99: float = 0.0
    restore_stall_ms_p50: float = 0.0
    restore_stall_ms_p99: float = 0.0
    slo_ttft_ms: float = 0.0             # the targets the goodput gate used
    slo_tpot_ms: float = 0.0
    sim_time_ms: float = 0.0             # engine clock span of the run
    preemptions: int = 0
    prefix_hits: int = 0
    # fault axis: RECOVERING re-queues the run absorbed, and requests
    # that never completed (the zero-lost-requests gate's numerator —
    # arrivals minus completions after the horizon drain).
    recoveries: int = 0
    lost_requests: int = 0
