"""Mixture-of-Experts layer: top-k routing with expert parallelism.

Two dispatch paths:

* ``moe_apply_ep`` (train/prefill) — shard_map expert parallelism. Tokens
  are (data x model)-sharded; experts are model-sharded. Each rank routes
  its local tokens, packs fixed-capacity per-destination send buffers, and
  one ``all_to_all`` over the model axis moves tokens to the rank owning
  their expert (the return trip mirrors it). All scatters are local-shaped,
  so GSPMD never sees a partitioned scatter — the naive global scatter
  (``moe_apply``) makes XLA replicate [T, ...] buffers (observed: 191 GB
  temp/device on granite train_4k vs ~1 GB with this path).

* ``moe_apply_ep_decode`` (single-token decode) — tokens are small, so
  they stay replicated across the model axis; each rank computes only its
  local experts' contribution and a psum over the model axis combines.

``moe_apply`` (pure, single-device semantics) remains the oracle for
tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (dense_init, pdtype, rmsnorm, rmsnorm_init)


def moe_init(key, cfg: ModelConfig) -> Dict:
    d, ff, e, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, pdtype(cfg)
    ks = jax.random.split(key, 4)
    return {"router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
            "e_gate": (jax.random.normal(ks[1], (e, d, ff)) * 0.02
                       ).astype(dt),
            "e_up": (jax.random.normal(ks[2], (e, d, ff)) * 0.02).astype(dt),
            "e_down": (jax.random.normal(ks[3], (e, ff, d)) * 0.02
                       ).astype(dt)}


def moe_apply(params: Dict, cfg: ModelConfig,
              x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y, aux_loss). Capacity C = cf * T * k / E."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    # --- routing (float32 for a stable softmax) -------------------------
    logits = xt.astype(jnp.float32) @ params["router"]          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, k)                    # [T, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                     # [E]
    ce = jnp.zeros((e,), jnp.float32).at[gate_i.reshape(-1)].add(
        1.0 / (t * k))
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    capacity = int(max(1, round(cfg.capacity_factor * t * k / e)))
    capacity = min(capacity, t)

    # --- positions in expert (slot-major priority: k=0 first) -----------
    flat_e = gate_i.T.reshape(-1)                               # [k*T]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # [k*T, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot                   # pre-count
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < capacity
    slot_w = gate_w.T.reshape(-1) * keep                        # [k*T]

    # --- scatter dispatch: [E, C, d] -------------------------------------
    safe_pos = jnp.where(keep, pos, capacity - 1)
    src = jnp.tile(xt, (k, 1))
    buf = jnp.zeros((e, capacity, d), xt.dtype)
    buf = buf.at[flat_e, safe_pos].add(jnp.where(keep[:, None], src, 0))

    # --- expert compute (E over the "model" axis) ------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["e_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["e_up"])
    y_e = jnp.einsum("ecf,efd->ecd", h, params["e_down"])       # [E, C, d]

    # --- gather combine (gate applied at combine: y = sum_i g_i e_i(x)) ---
    vals = y_e[flat_e, safe_pos]                                # [k*T, d]
    vals = vals * slot_w[:, None].astype(vals.dtype)
    y = vals.reshape(k, t, d).sum(axis=0)
    return y.reshape(b, s, d), aux


def _mesh_axis_size(name: str) -> int:
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    return dict(mesh.shape).get(name, 1)


def _capacity(cf: float, tokens: int, k: int, buckets: int) -> int:
    return int(max(1, round(cf * tokens * k / buckets)))


def moe_apply_ep(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                 dp_axes="data") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map + all_to_all (train/prefill).

    x: [B, S, d], tokens sharded (dp_axes x model); experts sharded over
    "model". Per rank: route local tokens -> pack per-destination send
    buffers (capacity-bounded) -> all_to_all over model -> local expert
    compute -> all_to_all back -> gated combine. Equivalent to
    ``moe_apply`` on a 1x1 mesh (same capacity discipline & priority).
    """
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    nm = _mesh_axis_size("model")
    b, s, _ = x.shape
    if nm == 1 or e % nm or s % nm:
        return moe_apply(params, cfg, x)
    e_loc = e // nm
    cf = cfg.capacity_factor

    x = jax.lax.with_sharding_constraint(x, P(dp_axes, "model", None))
    x_spec = P(dp_axes, "model", None)
    ep_spec = P("model", None, None)

    def local(xl, wr, wg, wu, wd):
        bl, sl, _ = xl.shape
        t = bl * sl
        xt = xl.reshape(t, d)

        # ---- routing --------------------------------------------------
        logits = xt.astype(jnp.float32) @ wr                    # [t, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, k)                # [t, k]
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        # load-balance aux (global via pmean over every token shard)
        all_axes = ((dp_axes,) if isinstance(dp_axes, str)
                    else tuple(dp_axes)) + ("model",)
        me = jax.lax.pmean(probs.mean(axis=0), all_axes)        # [E]
        ce = jnp.zeros((e,), jnp.float32).at[gate_i.reshape(-1)].add(
            1.0 / (t * k))
        ce = jax.lax.pmean(ce, all_axes)
        aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

        # ---- stage 1: pack per-destination send buffers ----------------
        flat_e = gate_i.T.reshape(-1)                           # [k*t]
        flat_g = gate_w.T.reshape(-1)
        tok_of_slot = jnp.tile(jnp.arange(t, dtype=jnp.int32), (k,))
        dest = flat_e // e_loc                                  # [k*t]
        cd = _capacity(cf, t, k, nm)
        onehot_d = jax.nn.one_hot(dest, nm, dtype=jnp.int32)
        posd = jnp.cumsum(onehot_d, axis=0) - onehot_d
        posd = jnp.take_along_axis(posd, dest[:, None], axis=1)[:, 0]
        keep1 = posd < cd
        safe1 = jnp.where(keep1, posd, cd - 1)

        send_x = jnp.zeros((nm, cd, d), xt.dtype).at[dest, safe1].add(
            jnp.where(keep1[:, None], jnp.take(xt, tok_of_slot, axis=0), 0))
        e_local_id = (flat_e % e_loc).astype(jnp.int32)
        send_meta = jnp.zeros((nm, cd), jnp.int32).at[dest, safe1].max(
            jnp.where(keep1, e_local_id + 1, 0))
        # local bookkeeping for the return trip (never leaves the rank)
        ret_tok = jnp.full((nm, cd), t, jnp.int32).at[dest, safe1].min(
            jnp.where(keep1, tok_of_slot, t))
        ret_gate = jnp.zeros((nm, cd), jnp.float32).at[dest, safe1].add(
            jnp.where(keep1, flat_g, 0.0))

        # ---- all_to_all dispatch over the model axis -------------------
        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=True)
        recv_meta = jax.lax.all_to_all(send_meta, "model", 0, 0, tiled=True)
        recv_e = recv_meta.reshape(-1) - 1                      # [nm*cd]
        recv_ok = recv_e >= 0
        recv_e = jnp.where(recv_ok, recv_e, 0)
        rx = recv_x.reshape(nm * cd, d)

        # ---- stage 2: local per-expert dispatch ------------------------
        ce_cap = _capacity(cf, t * nm, k, e)
        onehot_e = jax.nn.one_hot(recv_e, e_loc, dtype=jnp.int32)
        onehot_e = onehot_e * recv_ok[:, None].astype(jnp.int32)
        pose = jnp.cumsum(onehot_e, axis=0) - onehot_e
        pose = jnp.take_along_axis(pose, recv_e[:, None], axis=1)[:, 0]
        keep2 = recv_ok & (pose < ce_cap)
        safe2 = jnp.where(keep2, pose, ce_cap - 1)
        buf = jnp.zeros((e_loc, ce_cap, d), xt.dtype).at[recv_e, safe2].add(
            jnp.where(keep2[:, None], rx, 0))

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
            * jnp.einsum("ecd,edf->ecf", buf, wu)
        y_e = jnp.einsum("ecf,efd->ecd", h, wd)                 # [e_loc,C,d]

        # ---- return trip ------------------------------------------------
        back = y_e[recv_e, safe2]
        back = jnp.where(keep2[:, None], back, 0).reshape(nm, cd, d)
        ret = jax.lax.all_to_all(back, "model", 0, 0, tiled=True)

        # ---- gated combine ----------------------------------------------
        ret = ret.reshape(nm * cd, d) \
            * ret_gate.reshape(-1)[:, None].astype(y_e.dtype)
        y_t = jnp.zeros((t + 1, d), y_e.dtype).at[
            ret_tok.reshape(-1)].add(ret)[:t]
        return y_t.reshape(bl, sl, d).astype(xl.dtype), aux

    return jax.shard_map(
        local,
        in_specs=(x_spec, P(None, None), ep_spec, ep_spec, ep_spec),
        out_specs=(x_spec, P()))(
            x, params["router"], params["e_gate"], params["e_up"],
            params["e_down"])


def moe_apply_ep_decode(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                        dp_axes="data") -> jnp.ndarray:
    """Expert-parallel MoE for single-token decode.

    Tokens are few: keep them replicated over the model axis, let each
    rank compute only its local experts' gated contributions, and psum
    over the model axis. No all_to_all, no drops.
    """
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    nm = _mesh_axis_size("model")
    if nm == 1 or e % nm:
        return moe_apply(params, cfg, x)[0]
    e_loc = e // nm
    x_spec = P(dp_axes, None, None)
    ep_spec = P("model", None, None)

    def local(xl, wr, wg, wu, wd):
        bl, sl, _ = xl.shape
        t = bl * sl
        xt = xl.reshape(t, d)
        rank = jax.lax.axis_index("model")
        logits = xt.astype(jnp.float32) @ wr
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        flat_e = gate_i.T.reshape(-1)                           # [k*t]
        flat_g = gate_w.T.reshape(-1)
        tok_of_slot = jnp.tile(jnp.arange(t, dtype=jnp.int32), (k,))
        local_e = flat_e - rank * e_loc
        mine = (local_e >= 0) & (local_e < e_loc)
        local_e = jnp.where(mine, local_e, 0)

        cap = t * k                      # no drops at decode
        slot = jnp.arange(k * t, dtype=jnp.int32)
        buf = jnp.zeros((e_loc, cap, d), xt.dtype).at[local_e, slot].add(
            jnp.where(mine[:, None], jnp.take(xt, tok_of_slot, axis=0), 0))
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
            * jnp.einsum("ecd,edf->ecf", buf, wu)
        y_e = jnp.einsum("ecf,efd->ecd", h, wd)
        vals = y_e[local_e, slot]                               # [k*t, d]
        vals = jnp.where(mine[:, None], vals, 0) \
            * flat_g[:, None].astype(y_e.dtype)
        y_t = vals.reshape(k, t, d).sum(axis=0)
        y_t = jax.lax.psum(y_t, "model")
        return y_t.reshape(bl, sl, d).astype(xl.dtype)

    return jax.shard_map(
        local,
        in_specs=(x_spec, P(None, None), ep_spec, ep_spec, ep_spec),
        out_specs=x_spec)(
            x, params["router"], params["e_gate"], params["e_up"],
            params["e_down"])


def moe_block_init(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 2)
    return {"ln_attn": rmsnorm_init(cfg.d_model, pdtype(cfg)),
            "attn": attn.attn_init(ks[0], cfg),
            "ln_mlp": rmsnorm_init(cfg.d_model, pdtype(cfg)),
            "moe": moe_init(ks[1], cfg)}


def moe_block_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, *, causal: bool = True,
                    fuse_qkv: bool = True, q_block: int = 512,
                    kv_block: int = 512, dp_axes="data"):
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    q, kk, v = attn.qkv_project(params["attn"], cfg, h, positions,
                                fuse_qkv=fuse_qkv)
    o = attn.chunked_attention(q, kk, v, causal=causal, q_block=q_block,
                               kv_block=kv_block)
    b, s, _, _ = o.shape
    x = x + o.reshape(b, s, cfg.q_dim) @ params["attn"]["wo"]
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    y, aux = moe_apply_ep(params["moe"], cfg, h, dp_axes=dp_axes)
    return x + y, aux


def moe_block_decode_paged(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                           pos: jnp.ndarray, kv: Dict, *, batch_axes,
                           page_axes, fuse_qkv: bool = True,
                           kv_block: int = 2048):
    """Single-token decode against a page-sharded cache (see
    transformer.block_decode_paged)."""
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    positions = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1, 1), (x.shape[0], 1))
    q, kk, v = attn.qkv_project(params["attn"], cfg, h, positions,
                                fuse_qkv=fuse_qkv)
    if "k_scale" in kv:
        o, k_pages, v_pages, k_scale, v_scale = attn.paged_decode_attention(
            q, kv["k"], kv["v"], kk, v, pos, batch_axes=batch_axes,
            page_axes=page_axes, kv_block=kv_block,
            k_scale=kv["k_scale"], v_scale=kv["v_scale"])
        kv_out = {"k": k_pages, "v": v_pages, "k_scale": k_scale,
                  "v_scale": v_scale}
    else:
        o, k_pages, v_pages = attn.paged_decode_attention(
            q, kv["k"], kv["v"], kk, v, pos, batch_axes=batch_axes,
            page_axes=page_axes, kv_block=kv_block)
        kv_out = {"k": k_pages, "v": v_pages}
    x = x + o.reshape(x.shape[0], 1, cfg.q_dim) @ params["attn"]["wo"]
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    y = moe_apply_ep_decode(params["moe"], cfg, h,
                            dp_axes=batch_axes or "data")
    return x + y, kv_out


def moe_block_decode(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                     pos: jnp.ndarray, kv_cache, *, fuse_qkv: bool = True,
                     kv_block: int = 2048):
    k_cache, v_cache = kv_cache
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    positions = jnp.broadcast_to(pos, (x.shape[0], 1)).astype(jnp.int32)
    q, kk, v = attn.qkv_project(params["attn"], cfg, h, positions,
                                fuse_qkv=fuse_qkv)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, kk.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    o = attn.decode_attention(q, k_cache, v_cache, kv_len=pos + 1,
                              kv_block=kv_block)
    x = x + o.reshape(x.shape[0], 1, cfg.q_dim) @ params["attn"]["wo"]
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    y, _ = moe_apply(params["moe"], cfg, h)
    return x + y, (k_cache, v_cache)
