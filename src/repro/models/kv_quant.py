"""Int8 KV page quantization with per-(page, head) fp32 scales.

Page layout (see docs/ARCHITECTURE.md "KV page format"): a quantized KV
leaf keeps the same ``[..., n_pages, page, Hkv, D]`` geometry as the bf16
cache but stores int8 codes, plus a sibling fp32 scale leaf shaped
``[..., n_pages, Hkv]`` (one symmetric amax scale per page per KV head).
Dequantization is ``x ~= q.astype(f32) * scale`` broadcast over the
(page, D) axes; decode math stays bf16/fp32.

Scales grow monotonically (``new = max(old, amax/127)``): a page that is
dequantized and rewritten unchanged requantizes to the *bit-identical*
int8 payload, because ``round(i * s / s') == i`` whenever ``s' >= s`` up
to ~1 ulp (the rounding tolerance is 0.5/127, many orders of magnitude
above float32 rounding error). This keeps tier flush -> restore -> decode
round trips byte-exact for untouched pages.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

# Supported ServeConfig/RunConfig kv_quant spellings. "fp8" is reserved
# (validated, but not implemented yet).
KV_QUANT_MODES: Tuple[str, ...] = ("none", "int8", "fp8")

# Symmetric int8 code range: [-127, 127] (we never emit -128 so the grid
# is symmetric and dequantization of -q equals -dequantization of q).
QMAX = 127.0

# amax floor: an all-zero (or subnormal) page still gets a strictly
# positive, *normal* fp32 scale so dequantization never divides by zero
# and never produces subnormal scales. 1e-20/127 ~= 7.9e-23 is normal.
SCALE_FLOOR = 1e-20

# Scale value used for freshly initialised (all-zero) cache pages.
INIT_SCALE = SCALE_FLOOR / QMAX


def validate_mode(mode: str) -> str:
    """Validate a kv_quant mode string; returns it unchanged.

    Raises ValueError for unknown spellings and for the reserved "fp8"
    stub (page format + scales land here later; the knob is pinned now so
    configs stay forward-compatible).
    """
    if mode not in KV_QUANT_MODES:
        raise ValueError(
            f"kv_quant={mode!r} unknown (expected one of {KV_QUANT_MODES})")
    if mode == "fp8":
        raise ValueError(
            "kv_quant='fp8' is reserved but not implemented yet; "
            "use 'none' or 'int8'")
    return mode


def page_scales(x: jnp.ndarray,
                prev_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Per-(page, head) symmetric scales for ``x``: [..., P, page, Hkv, D].

    Returns fp32 ``[..., P, Hkv]``. With ``prev_scale`` the result is the
    elementwise maximum of old and new (monotone growth -- see module
    docstring for why this keeps untouched pages bit-stable).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-3, -1))
    scale = jnp.maximum(amax, SCALE_FLOOR) / QMAX
    if prev_scale is not None:
        scale = jnp.maximum(scale, prev_scale.astype(jnp.float32))
    return scale.astype(jnp.float32)


def quantize_pages(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Quantize ``x`` [..., P, page, Hkv, D] to int8 with given scales.

    ``scale`` is ``[..., P, Hkv]`` fp32 (from :func:`page_scales`). Codes
    are round-to-nearest, clipped to the symmetric range [-127, 127].
    """
    inv = (1.0 / scale)[..., :, None, :, None]
    q = jnp.round(x.astype(jnp.float32) * inv)
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


def dequantize_pages(q: jnp.ndarray, scale: jnp.ndarray,
                     dtype=jnp.float32) -> jnp.ndarray:
    """Dequantize int8 pages ``q`` [..., P, page, Hkv, D] back to ``dtype``."""
    x = q.astype(jnp.float32) * scale.astype(jnp.float32)[..., :, None, :,
                                                          None]
    return x.astype(dtype)


def requantize_pages(x: jnp.ndarray, prev_scale: jnp.ndarray):
    """Quantize updated pages with monotone scale growth.

    Returns ``(q, scale)`` where ``scale = max(prev_scale, amax/127)``
    per (page, head). Pages whose contents are unchanged since the last
    quantization round-trip bit-exactly.
    """
    scale = page_scales(x, prev_scale)
    return quantize_pages(x, scale), scale
