"""Common layer primitives: norms, RoPE, embeddings, MLP variants.

Pure-functional: every layer is (init_fn, apply_fn) over plain dicts of
jnp arrays. Params live in cfg.dtype (bf16 by default); normalization and
softmax statistics accumulate in float32.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6):
    """QK-norm over the head_dim axis (qwen3-style), x: [..., head_dim]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype)


# ---------------------------------------------------------------------------
# Rotary / sinusoidal position encodings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: [B, S, H, D]; positions: [B, S] (int32)."""
    freqs = rope_freqs(x.shape[-1], theta)                     # [D/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Additive sinusoidal embedding (musicgen). positions: [B, S]."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs     # [B, S, half]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> Dict:
    d, dt = cfg.d_model, pdtype(cfg)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], d, d_ff, dt),
                "w_up": dense_init(ks[1], d, d_ff, dt),
                "w_down": dense_init(ks[2], d_ff, d, dt)}
    return {"w_up": dense_init(ks[0], d, d_ff, dt),
            "w_down": dense_init(ks[1], d_ff, d, dt)}


def mlp_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig) -> Dict:
    dt = pdtype(cfg)
    ks = jax.random.split(key, 2)
    n_tables = cfg.n_codebooks if cfg.family == "audio" else 1
    p = {"embedding": (jax.random.normal(ks[0], (n_tables * cfg.vocab_size,
                                                 cfg.d_model)) * 0.02
                       ).astype(dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(
            ks[1], cfg.d_model,
            n_tables * cfg.vocab_size, dt)
    return p


def embed_apply(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray):
    """tokens: [B, S] (or [B, K, S] audio)."""
    if cfg.family == "audio":
        # each codebook has its own vocab slice; sum the K embeddings
        offsets = (jnp.arange(cfg.n_codebooks) * cfg.vocab_size)[None, :, None]
        flat = tokens + offsets                       # [B, K, S]
        emb = jnp.take(params["embedding"], flat, axis=0)  # [B, K, S, d]
        return emb.sum(axis=1)
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray):
    if cfg.tie_embeddings:
        table = params["embedding"]
        logits = x @ table.T
    else:
        logits = x @ params["unembed"]
    if cfg.family == "audio":
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
        logits = jnp.moveaxis(logits, 2, 1)           # [B, K, S, V]
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy; logits [..., V], labels [...] int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Cost-extraction mode: XLA's HLO cost analysis visits a while-loop body
# ONCE regardless of trip count, so the dry-run's exact-cost pass fully
# unrolls inner sequence scans (attention KV blocks, SSD/mLSTM chunks).
# Numerics are identical; only the lowering changes. The sLSTM per-token
# recurrence stays scanned (its FLOPs are <0.1% of any cell — documented in
# EXPERIMENTS.md §Roofline).
# ---------------------------------------------------------------------------

_UNROLL_INNER = False


def set_unroll_inner(value: bool) -> None:
    global _UNROLL_INNER
    _UNROLL_INNER = bool(value)


def inner_unroll():
    """Pass as lax.scan's unroll= for inner sequence scans."""
    return True if _UNROLL_INNER else 1
