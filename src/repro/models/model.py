"""Model assembly: init / train forward / prefill / decode for all families.

Layer stacks carry a leading layer (or group) axis so the SR pipeline
(repro.core.speculative_read.stream_layers) can stream them from the pool
tier. KV caches are *paged*: [B, n_pages, page, Hkv, D], which (a) keeps
decode attention a block-parallel flash-decode with a cheap cross-page
combine, and (b) is the same layout the serving engine's tiered pager uses.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core import speculative_read as sr
from repro.models import attention as attn_lib
from repro.models import kv_quant as kv_quant_lib
from repro.models import mamba2, moe, transformer, xlstm
from repro.models.layers import (embed_apply, embed_init, mlp_apply, pdtype,
                                 rmsnorm, rmsnorm_init, sinusoidal_positions,
                                 softmax_xent, unembed_apply)


def _stack_init(fn, key, n, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(k, *args))(keys)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig) -> Dict:
    k_embed, k_blocks, k_extra = jax.random.split(key, 3)
    params: Dict[str, Any] = {"embed": embed_init(k_embed, cfg),
                              "ln_f": rmsnorm_init(cfg.d_model, pdtype(cfg))}
    fam = cfg.family
    if fam in ("dense", "audio"):
        params["blocks"] = _stack_init(transformer.block_init, k_blocks,
                                       cfg.n_layers, cfg)
    elif fam == "moe":
        params["blocks"] = _stack_init(moe.moe_block_init, k_blocks,
                                       cfg.n_layers, cfg)
    elif fam == "vlm":
        period = cfg.cross_attn_period
        n_groups = cfg.n_layers // period
        ks = jax.random.split(k_blocks, 2)
        params["groups"] = {
            "self_blocks": jax.vmap(lambda k: _stack_init(
                transformer.block_init, k, period - 1, cfg))(
                    jax.random.split(ks[0], n_groups)),
            "cross": _stack_init(transformer.cross_block_init, ks[1],
                                 n_groups, cfg)}
    elif fam == "hybrid":
        period = cfg.shared_block_period
        n_groups = cfg.n_layers // period
        params["groups"] = jax.vmap(lambda k: _stack_init(
            mamba2.mamba_init, k, period, cfg))(
                jax.random.split(k_blocks, n_groups))
        ks = jax.random.split(k_extra, 3)
        params["shared"] = {
            "in_map": (jax.random.normal(ks[0],
                                         (2 * cfg.d_model, cfg.d_model))
                       * 0.02).astype(pdtype(cfg)),
            "block": transformer.block_init(ks[1], cfg),
            "out_map": (jax.random.normal(ks[2], (cfg.d_model, cfg.d_model))
                        * 0.02).astype(pdtype(cfg))}
    elif fam == "ssm":
        period = cfg.slstm_every
        n_groups = cfg.n_layers // period
        ks = jax.random.split(k_blocks, 2)
        params["groups"] = {
            "mlstm": jax.vmap(lambda k: _stack_init(
                xlstm.mlstm_init, k, period - 1, cfg))(
                    jax.random.split(ks[0], n_groups)),
            "slstm": _stack_init(xlstm.slstm_init, ks[1], n_groups, cfg)}
    else:
        raise ValueError(fam)
    return params


def stacked_key(cfg: ModelConfig) -> str:
    return "blocks" if cfg.family in ("dense", "moe", "audio") else "groups"


def n_stacked(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "moe", "audio"):
        return cfg.n_layers
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_period
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.shared_block_period
    return cfg.n_layers // cfg.slstm_every


# ---------------------------------------------------------------------------
# forward bodies (one stacked step each)
# ---------------------------------------------------------------------------


def _act_spec(rc: RunConfig, seq_sharded: bool) -> P:
    dp = ("pod", "data") if rc.mesh.multi_pod else "data"
    return P(dp, "model" if seq_sharded else None, None)


def _constrain_act(x, rc: RunConfig, seq_sharded: bool):
    return jax.lax.with_sharding_constraint(x, _act_spec(rc, seq_sharded))


def _body_train(cfg: ModelConfig, rc: RunConfig, positions, seq_sharded,
                shared=None, vision=None):
    """Returns body(x_carry, layer_params, extra) -> (x_carry, out)."""
    fam = cfg.family

    def body(carry, layer, extra):
        del extra
        x, aux = carry if isinstance(carry, tuple) else (carry, 0.0)
        x = _constrain_act(x, rc, seq_sharded)
        if fam in ("dense", "audio"):
            x = transformer.block_apply(layer, cfg, x, positions,
                                        fuse_qkv=rc.fuse_qkv,
                                        use_pallas=rc.use_pallas)
            return (x, aux), None
        if fam == "moe":
            x, a = moe.moe_block_apply(layer, cfg, x, positions,
                                       fuse_qkv=rc.fuse_qkv)
            return (x, aux + a), None
        if fam == "vlm":
            for i in range(cfg.cross_attn_period - 1):
                blk = jax.tree_util.tree_map(lambda a: a[i],
                                             layer["self_blocks"])
                x = transformer.block_apply(blk, cfg, x, positions,
                                            fuse_qkv=rc.fuse_qkv)
            kv = transformer.vision_kv(layer["cross"], cfg, vision)
            x = transformer.cross_block_apply(layer["cross"], cfg, x, kv)
            return (x, aux), None
        if fam == "hybrid":
            emb = shared["emb"]
            for i in range(cfg.shared_block_period):
                blk = jax.tree_util.tree_map(lambda a: a[i], layer)
                x = x + mamba2.mamba_apply(blk, cfg, x)
            x = _shared_block_apply(shared["params"], cfg, x, emb, positions,
                                    rc)
            return (x, aux), None
        if fam == "ssm":
            for i in range(cfg.slstm_every - 1):
                blk = jax.tree_util.tree_map(lambda a: a[i], layer["mlstm"])
                x = xlstm.mlstm_apply(blk, cfg, x)
            x = xlstm.slstm_apply(layer["slstm"], cfg, x)
            return (x, aux), None
        raise ValueError(fam)

    return body


def _shared_block_apply(sp, cfg, x, emb, positions, rc):
    """zamba2 shared attention block: concat(h, emb) -> attn+mlp -> project."""
    z = jnp.concatenate([x, emb], axis=-1) @ sp["in_map"]
    z = transformer.block_apply(sp["block"], cfg, z, positions,
                                fuse_qkv=rc.fuse_qkv)
    return x + z @ sp["out_map"]


# ---------------------------------------------------------------------------
# train forward + loss
# ---------------------------------------------------------------------------


def loss_fn(params: Dict, cfg: ModelConfig, rc: RunConfig, batch: Dict,
            param_specs: Dict, *, mode: str = "train") -> jnp.ndarray:
    tokens = batch["tokens"]
    seq_sharded = mode == "train" or rc.seq_shard_attn
    x = embed_apply(params["embed"], cfg, tokens)
    bsz, seq = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None],
                                 (bsz, seq))
    if cfg.family == "audio" or not cfg.use_rope:
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    x = _constrain_act(x, rc, seq_sharded)

    shared = None
    vision = batch.get("vision_embeds")
    if cfg.family == "hybrid":
        shared = {"params": params["shared"], "emb": x}
    body = _body_train(cfg, rc, positions, seq_sharded, shared=shared,
                       vision=vision)

    key = stacked_key(cfg)
    (x, aux), _ = sr.stream_layers(
        body, (x, jnp.zeros((), jnp.float32)), params[key],
        param_specs[key], n_layers=n_stacked(cfg),
        prefetch_depth=rc.sr_prefetch_depth, granularity=rc.sr_granularity,
        mode="train", remat=rc.remat, unroll=rc.scan_unroll,
        remat_policy=rc.remat_policy)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    loss = _chunked_xent(params, cfg, x, batch["labels"])
    return loss + aux


def _chunked_xent(params, cfg, x, labels, n_chunks: int = 8):
    """Cross-entropy without materializing full [T, V] logits."""
    b, s, d = x.shape
    if s % n_chunks or s // n_chunks == 0:
        n_chunks = 1
    cs = s // n_chunks
    xs = jnp.moveaxis(x.reshape(b, n_chunks, cs, d), 1, 0)
    if cfg.family == "audio":
        lab = jnp.moveaxis(labels.reshape(b, labels.shape[1], n_chunks, cs),
                           2, 0)
    else:
        lab = jnp.moveaxis(labels.reshape(b, n_chunks, cs), 1, 0)

    def chunk(carry, inp):
        xc, lc = inp
        logits = unembed_apply(params["embed"], cfg, xc)
        return carry + softmax_xent(logits, lc), None

    total, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (xs, lab),
                            unroll=n_chunks)
    return total / n_chunks


# ---------------------------------------------------------------------------
# KV caches (paged layout)
# ---------------------------------------------------------------------------


def cache_init(cfg: ModelConfig, rc: RunConfig, batch: int, max_seq: int,
               as_shape: bool = False) -> Dict:
    """Paged cache pytree. as_shape=True -> ShapeDtypeStructs (dry-run).

    With ``rc.kv_quant == "int8"`` the paged self-attention K/V leaves are
    int8 and each gains a sibling fp32 per-(page, head) scale leaf
    ("k_scale"/"v_scale", [n, B, n_pages, Hkv] — see models/kv_quant.py).
    The vlm cross-attention K/V (written once at prefill, never behind the
    tier hot path) stays at the model dtype.
    """
    page = min(rc.kv_page_size, max_seq)
    n_pages = max(max_seq // page, 1)
    dt = pdtype(cfg)
    quant = rc.kv_quant == "int8"
    if rc.kv_quant != "none":
        kv_quant_lib.validate_mode(rc.kv_quant)

    def arr(shape, dtype, fill=None):
        if as_shape:
            return jax.ShapeDtypeStruct(shape, dtype)
        if fill is not None:
            return jnp.full(shape, fill, dtype)
        return jnp.zeros(shape, dtype)

    def kv(n):
        kv_dt = jnp.int8 if quant else dt
        pages = {"k": arr((n, batch, n_pages, page, cfg.n_kv_heads,
                           cfg.head_dim), kv_dt),
                 "v": arr((n, batch, n_pages, page, cfg.n_kv_heads,
                           cfg.head_dim), kv_dt)}
        if quant:
            sshape = (n, batch, n_pages, cfg.n_kv_heads)
            pages["k_scale"] = arr(sshape, jnp.float32,
                                   fill=kv_quant_lib.INIT_SCALE)
            pages["v_scale"] = arr(sshape, jnp.float32,
                                   fill=kv_quant_lib.INIT_SCALE)
        return pages

    fam = cfg.family
    if fam in ("dense", "moe", "audio"):
        return {"kv": kv(cfg.n_layers), "pos": arr((batch,), jnp.int32)}
    if fam == "vlm":
        g = n_stacked(cfg)
        nv = cfg.n_vision_tokens
        return {"kv": kv(g * (cfg.cross_attn_period - 1)),
                "cross_k": arr((g, batch, nv, cfg.n_kv_heads, cfg.head_dim),
                               dt),
                "cross_v": arr((g, batch, nv, cfg.n_kv_heads, cfg.head_dim),
                               dt),
                "pos": arr((batch,), jnp.int32)}
    if fam == "hybrid":
        g = n_stacked(cfg)
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        return {
            "kv": kv(g),  # one shared-block invocation cache per group
            "h": arr((g, cfg.shared_block_period, batch, nh,
                      cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "conv": arr((g, cfg.shared_block_period, batch, cfg.ssm_conv - 1,
                         d_in + 2 * cfg.ssm_state), jnp.float32),
            "pos": arr((batch,), jnp.int32)}
    if fam == "ssm":
        g = n_stacked(cfg)
        m = cfg.slstm_every - 1
        d_in = cfg.mlstm_expand * cfg.d_model
        nh = cfg.n_heads
        dh_m = d_in // nh
        dh_s = cfg.d_model // nh
        return {
            "mC": arr((g, m, batch, nh, dh_m, dh_m), jnp.float32),
            "mn": arr((g, m, batch, nh, dh_m), jnp.float32),
            "mm": arr((g, m, batch, nh), jnp.float32),
            "mconv": arr((g, m, batch, 3, d_in), jnp.float32),
            "sh": arr((g, batch, nh, dh_s), jnp.float32),
            "sc": arr((g, batch, nh, dh_s), jnp.float32),
            "sn": arr((g, batch, nh, dh_s), jnp.float32),
            "sm": arr((g, batch, nh, dh_s), jnp.float32),
            "sconv": arr((g, batch, 3, cfg.d_model), jnp.float32),
            "pos": arr((batch,), jnp.int32)}
    raise ValueError(fam)


def decode_axes(rc: RunConfig, batch: int):
    """(batch_axes, page_axes) for the page-sharded decode cache.

    batch > 1: batch over the DP axes, pages over "model" — each model
    rank plays one root port/EP owning a contiguous token range.
    batch == 1: no batch parallelism; pages spread over the whole mesh.
    """
    dp = ("pod", "data") if rc.mesh.multi_pod else "data"
    if batch == 1:
        page_axes = (("pod", "data", "model") if rc.mesh.multi_pod
                     else ("data", "model"))
        return None, page_axes
    return dp, "model"


def cache_specs(cfg: ModelConfig, rc: RunConfig, batch: int) -> Dict:
    """PartitionSpecs for the cache pytree (leading stack axis included)."""
    dp = ("pod", "data") if rc.mesh.multi_pod else "data"
    batch_axes, page_axes = decode_axes(rc, batch)
    kv_spec = P(None, batch_axes, page_axes, None, None, None)

    cache = cache_init(cfg, rc, batch, max_seq=rc.kv_page_size,
                       as_shape=True)

    def spec_for(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("k", "v"):
            return kv_spec
        if name in ("k_scale", "v_scale"):
            # per-(page, head) int8 scales shard exactly like the pages
            return P(None, batch_axes, page_axes, None)
        if name in ("cross_k", "cross_v"):
            return P(None, batch_axes, None, None, None)
        if name == "pos":
            return P(batch_axes)
        # SSM / conv states: batch-sharded when batch parallelism exists
        shape = leaf.shape
        out = [None] * len(shape)
        if batch_axes is not None:
            # find the batch axis (first axis whose size == batch)
            for i, s in enumerate(shape):
                if s == batch:
                    out[i] = batch_axes
                    break
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec_for, cache)


# ---------------------------------------------------------------------------
# decode (serve_step body)
# ---------------------------------------------------------------------------


def _paged_block_decode(block_fn, layer, cfg, x, pos, kv, rc):
    """One paged-attention decode block; the cache stays page-sharded (the
    distributed write + combine happen inside paged_decode_attention)."""
    batch_axes, page_axes = decode_axes(rc, x.shape[0])
    return block_fn(layer, cfg, x, pos, kv, batch_axes=batch_axes,
                    page_axes=page_axes, fuse_qkv=rc.fuse_qkv)


def decode_step(params: Dict, cfg: ModelConfig, rc: RunConfig, tokens,
                cache: Dict, param_specs: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. tokens: [B, 1] (audio: [B, K, 1])."""
    pos = cache["pos"]                     # [B] per-slot positions
    x = embed_apply(params["embed"], cfg, tokens)
    b = x.shape[0]
    if cfg.family == "audio" or not cfg.use_rope:
        ppos = pos.reshape(b, 1).astype(jnp.int32)
        x = x + sinusoidal_positions(ppos, cfg.d_model).astype(x.dtype)

    fam = cfg.family
    key = stacked_key(cfg)
    new_cache = dict(cache)

    if fam in ("dense", "moe", "audio"):
        block_fn = (moe.moe_block_decode_paged if fam == "moe"
                    else transformer.block_decode_paged)

        def body(x, layer, kv):
            x, kv2 = _paged_block_decode(block_fn, layer, cfg, x, pos, kv,
                                         rc)
            return x, kv2

        x, kv_out = sr.stream_layers(
            body, x, params[key], param_specs[key], n_layers=cfg.n_layers,
            prefetch_depth=rc.sr_prefetch_depth,
            granularity=rc.sr_granularity, mode="infer", remat=False,
            stacked_extras=cache["kv"], unroll=rc.scan_unroll)
        new_cache["kv"] = kv_out
    elif fam == "vlm":
        x, new_cache = _decode_vlm(params, cfg, rc, x, pos, cache,
                                   param_specs)
    elif fam == "hybrid":
        x, new_cache = _decode_hybrid(params, cfg, rc, x, pos, cache,
                                      param_specs)
    elif fam == "ssm":
        x, new_cache = _decode_ssm(params, cfg, rc, x, pos, cache,
                                   param_specs)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_apply(params["embed"], cfg, x)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _decode_vlm(params, cfg, rc, x, pos, cache, param_specs):
    g = n_stacked(cfg)
    per = cfg.cross_attn_period - 1
    kv_g = jax.tree_util.tree_map(
        lambda a: a.reshape((g, per) + a.shape[1:]), cache["kv"])

    def body(x, group, extra):
        kv, ck, cv = extra
        kv_new = []
        for i in range(per):
            blk = jax.tree_util.tree_map(lambda a: a[i],
                                         group["self_blocks"])
            kv_i = jax.tree_util.tree_map(lambda a: a[i], kv)
            x2, kv2 = _paged_block_decode(transformer.block_decode_paged, blk, cfg,
                                          x, pos, kv_i, rc)
            x = x2
            kv_new.append(kv2)
        # cross layer: reuse cached vision K/V, single-query attention
        h = rmsnorm(group["cross"]["ln_attn"], x, cfg.norm_eps)
        ppos = jnp.zeros((x.shape[0], 1), jnp.int32)
        q, _, _ = attn_lib.qkv_project(group["cross"]["attn"], cfg, h, ppos,
                                       rope=False)
        o = attn_lib.decode_attention(q, ck, cv, kv_len=ck.shape[1])
        gate = jnp.tanh(group["cross"]["attn_gate"].astype(jnp.float32)
                        ).astype(x.dtype)
        x = x + gate * (o.reshape(x.shape[0], 1, cfg.q_dim)
                        @ group["cross"]["attn"]["wo"])
        h = rmsnorm(group["cross"]["ln_mlp"], x, cfg.norm_eps)
        from repro.models.layers import mlp_apply
        gate = jnp.tanh(group["cross"]["mlp_gate"].astype(jnp.float32)
                        ).astype(x.dtype)
        x = x + gate * mlp_apply(group["cross"]["mlp"], cfg, h)
        kv_stack = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *kv_new)
        return x, kv_stack

    x, kv_out = sr.stream_layers(
        body, x, params["groups"], param_specs["groups"], n_layers=g,
        prefetch_depth=rc.sr_prefetch_depth, granularity=rc.sr_granularity,
        mode="infer", remat=False,
        stacked_extras=(kv_g, cache["cross_k"], cache["cross_v"]),
        unroll=rc.scan_unroll)
    new_cache = dict(cache)
    new_cache["kv"] = jax.tree_util.tree_map(
        lambda a: a.reshape((g * per,) + a.shape[2:]), kv_out)
    return x, new_cache


def _decode_hybrid(params, cfg, rc, x, pos, cache, param_specs):
    g = n_stacked(cfg)
    emb = x

    def body(x, group, extra):
        kv, hs, convs = extra
        h_new, conv_new = [], []
        for i in range(cfg.shared_block_period):
            blk = jax.tree_util.tree_map(lambda a: a[i], group)
            st = {"h": hs[i], "conv": convs[i]}
            y, st2 = mamba2.mamba_step(blk, cfg, x, st)
            x = x + y
            h_new.append(st2["h"])
            conv_new.append(st2["conv"])
        # shared attention block (single-token)
        sp = params["shared"]
        z = jnp.concatenate([x, emb], axis=-1) @ sp["in_map"]
        z, kv2 = _paged_block_decode(transformer.block_decode_paged, sp["block"],
                                     cfg, z, pos, kv, rc)
        x = x + z @ sp["out_map"]
        return x, (kv2, jnp.stack(h_new), jnp.stack(conv_new))

    x, (kv_out, h_out, conv_out) = sr.stream_layers(
        body, x, params["groups"], param_specs["groups"], n_layers=g,
        prefetch_depth=rc.sr_prefetch_depth, granularity=rc.sr_granularity,
        mode="infer", remat=False,
        stacked_extras=(cache["kv"], cache["h"], cache["conv"]),
        unroll=rc.scan_unroll)
    new_cache = dict(cache)
    new_cache.update({"kv": kv_out, "h": h_out, "conv": conv_out})
    return x, new_cache


def _decode_ssm(params, cfg, rc, x, pos, cache, param_specs):
    g = n_stacked(cfg)
    m = cfg.slstm_every - 1

    def body(x, group, extra):
        mC, mn, mm, mconv, sh, sc, sn, sm, sconv = extra
        outC, outn, outm, outconv = [], [], [], []
        for i in range(m):
            blk = jax.tree_util.tree_map(lambda a: a[i], group["mlstm"])
            st = {"C": mC[i], "n": mn[i], "m": mm[i], "conv": mconv[i]}
            x, st2 = xlstm.mlstm_step(blk, cfg, x, st)
            outC.append(st2["C"])
            outn.append(st2["n"])
            outm.append(st2["m"])
            outconv.append(st2["conv"])
        st = {"h": sh, "c": sc, "n": sn, "m": sm, "conv": sconv}
        x, st2 = xlstm.slstm_step(group["slstm"], cfg, x, st)
        return x, (jnp.stack(outC), jnp.stack(outn), jnp.stack(outm),
                   jnp.stack(outconv), st2["h"], st2["c"], st2["n"],
                   st2["m"], st2["conv"])

    x, outs = sr.stream_layers(
        body, x, params["groups"], param_specs["groups"], n_layers=g,
        prefetch_depth=rc.sr_prefetch_depth, granularity=rc.sr_granularity,
        mode="infer", remat=False,
        stacked_extras=(cache["mC"], cache["mn"], cache["mm"],
                        cache["mconv"], cache["sh"], cache["sc"],
                        cache["sn"], cache["sm"], cache["sconv"]),
        unroll=rc.scan_unroll)
    new_cache = dict(cache)
    for name, val in zip(("mC", "mn", "mm", "mconv", "sh", "sc", "sn", "sm",
                          "sconv"), outs):
        new_cache[name] = val
    return x, new_cache


# ---------------------------------------------------------------------------
# prefill (inference context ingestion; returns logits of last position)
# ---------------------------------------------------------------------------


def _block_prefill_cached(layer: Dict, cfg: ModelConfig, rc: RunConfig,
                          x: jnp.ndarray, positions: jnp.ndarray,
                          pos: jnp.ndarray, kv: Dict, *, moe_mlp: bool):
    """One block over a C-token chunk, writing K/V into the paged cache.

    x: [B, C, d]; pos: [B] per-row start positions; kv: {"k","v"} each
    [B, n_pages, page, Hkv, D]. The chunk K/V are written in-graph at
    [pos, pos+C) (dynamic_update_slice on the flattened page view) before
    the attention, so the chunk attends to prior context + its own causal
    prefix through one multi-query flash-decode.
    """
    h = rmsnorm(layer["ln_attn"], x, cfg.norm_eps)
    q, k, v = attn_lib.qkv_project(layer["attn"], cfg, h, positions,
                                   fuse_qkv=rc.fuse_qkv)
    bsz, n_pages, page = kv["k"].shape[0], kv["k"].shape[1], kv["k"].shape[2]
    smax = n_pages * page
    quant = "k_scale" in kv
    kd = (kv_quant_lib.dequantize_pages(kv["k"], kv["k_scale"]) if quant
          else kv["k"])
    vd = (kv_quant_lib.dequantize_pages(kv["v"], kv["v_scale"]) if quant
          else kv["v"])
    kf = kd.reshape(bsz, smax, cfg.n_kv_heads, cfg.head_dim)
    vf = vd.reshape(bsz, smax, cfg.n_kv_heads, cfg.head_dim)

    def write(buf, new, p):
        return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype),
                                            (p, 0, 0))

    kf = jax.vmap(write)(kf, k, pos)
    vf = jax.vmap(write)(vf, v, pos)
    o = attn_lib.chunk_prefill_attention(
        q, kf, vf, pos, logit_softcap=cfg.attn_logit_softcap)
    x = x + o.reshape(bsz, -1, cfg.q_dim) @ layer["attn"]["wo"]
    h = rmsnorm(layer["ln_mlp"], x, cfg.norm_eps)
    if moe_mlp:
        y, _ = moe.moe_apply_ep(layer["moe"], cfg, h)
        x = x + y
    else:
        x = x + mlp_apply(layer["mlp"], cfg, h)
    if quant:
        kq, ks = kv_quant_lib.requantize_pages(kf.reshape(kd.shape),
                                               kv["k_scale"])
        vq, vs = kv_quant_lib.requantize_pages(vf.reshape(vd.shape),
                                               kv["v_scale"])
        return x, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return x, {"k": kf.reshape(kv["k"].shape), "v": vf.reshape(kv["v"].shape)}


def prefill_step_cached(params: Dict, cfg: ModelConfig, rc: RunConfig,
                        tokens, cache: Dict,
                        param_specs: Dict) -> Tuple[jnp.ndarray, Dict]:
    """Chunked multi-token prefill that writes the paged KV cache in-graph.

    tokens: [B, C] int32 (audio: [B, K, C]). Every batch row ingests its C
    tokens starting at its own ``cache["pos"]``; returns (logits for all C
    chunk positions, updated cache with pos advanced by C). Attention
    families (dense/moe/audio) run one parallel chunk forward per layer;
    recurrent families (vlm/hybrid/ssm) fall back to an in-graph
    ``lax.scan`` over ``decode_step`` — still a single dispatch per chunk.
    """
    fam = cfg.family
    if fam not in ("dense", "moe", "audio"):
        return _prefill_scan_cached(params, cfg, rc, tokens, cache,
                                    param_specs)
    pos = cache["pos"]
    x = embed_apply(params["embed"], cfg, tokens)
    b, c = x.shape[0], x.shape[1]
    positions = (pos.reshape(b, 1).astype(jnp.int32)
                 + jnp.arange(c, dtype=jnp.int32)[None])
    if fam == "audio" or not cfg.use_rope:
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)

    def body(x, layer, kv):
        return _block_prefill_cached(layer, cfg, rc, x, positions, pos, kv,
                                     moe_mlp=(fam == "moe"))

    key = stacked_key(cfg)
    x, kv_out = sr.stream_layers(
        body, x, params[key], param_specs[key], n_layers=cfg.n_layers,
        prefetch_depth=rc.sr_prefetch_depth, granularity=rc.sr_granularity,
        mode="infer", remat=False, stacked_extras=cache["kv"],
        unroll=rc.scan_unroll)
    new_cache = dict(cache)
    new_cache["kv"] = kv_out
    new_cache["pos"] = pos + c
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_apply(params["embed"], cfg, x)
    return logits, new_cache


def _prefill_scan_cached(params, cfg, rc, tokens, cache, param_specs):
    """Sequential-family prefill: scan decode_step over the chunk in-graph."""

    def step(cache, tok):
        logits, cache = decode_step(params, cfg, rc, tok[:, None], cache,
                                    param_specs)
        return cache, logits

    cache, ls = jax.lax.scan(step, cache, jnp.moveaxis(tokens, -1, 0))
    # ls: [C, B, 1, V] -> [B, C, V]
    logits = jnp.moveaxis(ls[:, :, 0], 0, 1)
    return logits, cache


# ---------------------------------------------------------------------------
# on-device sampling (fused into the serving hot path)
# ---------------------------------------------------------------------------


def last_token_logits(logits: jnp.ndarray) -> jnp.ndarray:
    """Final-position logits row per batch element: [B, V].

    [B, S, V] -> last position; audio [B, K, S, V] -> codebook-0 last
    position (the serving engine feeds one shared token to all codebooks).
    """
    if logits.ndim == 4:
        return logits[:, 0, -1]
    return logits[:, -1]


def sample_tokens(logits_row: jnp.ndarray, key,
                  temperature: float) -> jnp.ndarray:
    """Greedy / temperature sampling on device. logits_row: [B, V] -> [B].

    Deterministic for a given PRNG key — no host RNG anywhere, so results
    cannot vary with the host numpy version. Temperature sampling draws one
    uniform per row and inverts the softmax CDF: exact categorical sampling
    with B PRNG evaluations instead of the B*V gumbel draws
    ``jax.random.categorical`` needs (~4x cheaper per tick at serving-scale
    vocabs on CPU).
    """
    row = logits_row.astype(jnp.float32)
    if temperature and temperature > 0:
        p = jax.nn.softmax(row / temperature, axis=-1)
        cdf = jnp.cumsum(p, axis=-1)
        u = jax.random.uniform(key, (row.shape[0],), dtype=jnp.float32)
        return (cdf < u[:, None] * cdf[:, -1:]).sum(axis=-1).astype(
            jnp.int32)
    return jnp.argmax(row, axis=-1).astype(jnp.int32)


def prefill_step(params: Dict, cfg: ModelConfig, rc: RunConfig, batch: Dict,
                 param_specs: Dict) -> jnp.ndarray:
    """Prefill forward. Returns last-position logits (the cache write path
    is exercised in decode; prefill here validates the long-context forward
    at scale — in serving, repro.serving.engine folds prefill KV into pages).
    """
    tokens = batch["tokens"]
    x = embed_apply(params["embed"], cfg, tokens)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    if cfg.family == "audio" or not cfg.use_rope:
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    x = _constrain_act(x, rc, rc.seq_shard_attn)
    shared = ({"params": params["shared"], "emb": x}
              if cfg.family == "hybrid" else None)
    body = _body_train(cfg, rc, positions, rc.seq_shard_attn, shared=shared,
                       vision=batch.get("vision_embeds"))
    key = stacked_key(cfg)
    (x, _), _ = sr.stream_layers(
        body, (x, jnp.zeros((), jnp.float32)), params[key],
        param_specs[key], n_layers=n_stacked(cfg),
        prefetch_depth=rc.sr_prefetch_depth, granularity=rc.sr_granularity,
        mode="infer" if rc.sr_prefetch_depth else "train", remat=False,
        unroll=rc.scan_unroll)
    x = rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    return unembed_apply(params["embed"], cfg, x)
