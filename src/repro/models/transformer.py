"""Dense transformer blocks (self-attention and cross-attention variants).

Block params are single-layer dicts; the model assembler stacks them with a
leading layer axis for the SR streaming scan.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (dense_init, mlp_apply, mlp_init, pdtype,
                                 rmsnorm, rmsnorm_init)


def block_init(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 4)
    return {"ln_attn": rmsnorm_init(cfg.d_model, pdtype(cfg)),
            "attn": attn.attn_init(ks[0], cfg),
            "ln_mlp": rmsnorm_init(cfg.d_model, pdtype(cfg)),
            "mlp": mlp_init(ks[1], cfg)}


def cross_block_init(key, cfg: ModelConfig) -> Dict:
    """Cross-attention image layer (llama-3.2-vision style): gated."""
    ks = jax.random.split(key, 4)
    return {"ln_attn": rmsnorm_init(cfg.d_model, pdtype(cfg)),
            "attn": attn.attn_init(ks[0], cfg),
            "attn_gate": jnp.zeros((), dtype=pdtype(cfg)),
            "ln_mlp": rmsnorm_init(cfg.d_model, pdtype(cfg)),
            "mlp": mlp_init(ks[1], cfg),
            "mlp_gate": jnp.zeros((), dtype=pdtype(cfg))}


def block_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray, *, causal: bool = True,
                fuse_qkv: bool = True, q_block: int = 512,
                kv_block: int = 512,
                return_kv: bool = False, use_pallas: bool = False):
    """Full-sequence forward (train / prefill). x: [B, S, d]."""
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    q, k, v = attn.qkv_project(params["attn"], cfg, h, positions,
                               fuse_qkv=fuse_qkv)
    if use_pallas:
        from repro.kernels.flash_attention.ops import attention as _fa
        o = _fa(q, k, v, causal=causal,
                q_block=min(q_block, q.shape[1]),
                kv_block=min(kv_block, k.shape[1]),
                logit_softcap=cfg.attn_logit_softcap)
    else:
        o = attn.chunked_attention(q, k, v, causal=causal, q_block=q_block,
                                   kv_block=kv_block,
                                   logit_softcap=cfg.attn_logit_softcap)
    b, s, _, _ = o.shape
    x = x + o.reshape(b, s, cfg.q_dim) @ params["attn"]["wo"]
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    x = x + mlp_apply(params["mlp"], cfg, h)
    if return_kv:
        return x, (k, v)
    return x


def block_decode(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                 pos: jnp.ndarray, kv_cache: Tuple[jnp.ndarray, jnp.ndarray],
                 *, fuse_qkv: bool = True, kv_block: int = 2048):
    """Single-token decode. x: [B, 1, d]; kv_cache: ([B,Smax,Hkv,D], ...).

    Writes the new KV at ``pos`` then attends over [0, pos]."""
    k_cache, v_cache = kv_cache
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    positions = jnp.broadcast_to(pos, (x.shape[0], 1)).astype(jnp.int32)
    q, k, v = attn.qkv_project(params["attn"], cfg, h, positions,
                               fuse_qkv=fuse_qkv)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    o = attn.decode_attention(q, k_cache, v_cache, kv_len=pos + 1,
                              kv_block=kv_block,
                              logit_softcap=cfg.attn_logit_softcap)
    x = x + o.reshape(x.shape[0], 1, cfg.q_dim) @ params["attn"]["wo"]
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    x = x + mlp_apply(params["mlp"], cfg, h)
    return x, (k_cache, v_cache)


def block_decode_paged(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                       pos: jnp.ndarray, kv: Dict, *, batch_axes, page_axes,
                       fuse_qkv: bool = True, kv_block: int = 2048):
    """Single-token decode against a page-sharded cache.

    kv: {"k","v"} each [B, n_pages, page, Hkv, D] sharded over
    (batch_axes, page_axes); int8 caches carry sibling "k_scale"/
    "v_scale" leaves [B, n_pages, Hkv] (see models/kv_quant.py). The
    attention (and the KV write) run distributed via
    paged_decode_attention — no cache resharding.
    """
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    positions = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1, 1), (x.shape[0], 1))
    q, k, v = attn.qkv_project(params["attn"], cfg, h, positions,
                               fuse_qkv=fuse_qkv)
    if "k_scale" in kv:
        o, k_pages, v_pages, k_scale, v_scale = attn.paged_decode_attention(
            q, kv["k"], kv["v"], k, v, pos, batch_axes=batch_axes,
            page_axes=page_axes, kv_block=kv_block,
            logit_softcap=cfg.attn_logit_softcap,
            k_scale=kv["k_scale"], v_scale=kv["v_scale"])
        kv_out = {"k": k_pages, "v": v_pages, "k_scale": k_scale,
                  "v_scale": v_scale}
    else:
        o, k_pages, v_pages = attn.paged_decode_attention(
            q, kv["k"], kv["v"], k, v, pos, batch_axes=batch_axes,
            page_axes=page_axes, kv_block=kv_block,
            logit_softcap=cfg.attn_logit_softcap)
        kv_out = {"k": k_pages, "v": v_pages}
    x = x + o.reshape(x.shape[0], 1, cfg.q_dim) @ params["attn"]["wo"]
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    x = x + mlp_apply(params["mlp"], cfg, h)
    return x, kv_out


def cross_block_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                      vision_kv: Tuple[jnp.ndarray, jnp.ndarray],
                      *, q_block: int = 512) -> jnp.ndarray:
    """Gated cross-attention layer; vision_kv from precomputed embeddings."""
    k, v = vision_kv
    h = rmsnorm(params["ln_attn"], x, cfg.norm_eps)
    positions = jnp.zeros(h.shape[:2], jnp.int32)
    q, _, _ = attn.qkv_project(params["attn"], cfg, h, positions, rope=False)
    o = attn.chunked_attention(q, k, v, causal=False, q_block=q_block,
                               kv_block=min(512, k.shape[1]))
    b, s, _, _ = o.shape
    gate = jnp.tanh(params["attn_gate"].astype(jnp.float32)).astype(x.dtype)
    x = x + gate * (o.reshape(b, s, cfg.q_dim) @ params["attn"]["wo"])
    h = rmsnorm(params["ln_mlp"], x, cfg.norm_eps)
    gate = jnp.tanh(params["mlp_gate"].astype(jnp.float32)).astype(x.dtype)
    x = x + gate * mlp_apply(params["mlp"], cfg, h)
    return x


def vision_kv(params: Dict, cfg: ModelConfig,
              vision_embeds: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute cross-attention K/V from (stubbed) vision embeddings."""
    h = vision_embeds
    k = (h @ params["attn"]["wk"]).reshape(
        h.shape[0], h.shape[1], cfg.n_kv_heads, cfg.head_dim)
    v = (h @ params["attn"]["wv"]).reshape(
        h.shape[0], h.shape[1], cfg.n_kv_heads, cfg.head_dim)
    return k, v
