"""Attention: chunked (flash-style) causal/full attention + decode step.

``chunked_attention`` is the framework's default sequence-mixing path: an
online-softmax scan over KV blocks that never materializes the [S, S] score
matrix — algorithmically identical to the Pallas flash kernel in
``repro.kernels.flash_attention`` (which is the TPU fast path; this jnp
version is also its oracle shape). Memory per step is O(S·block) instead of
O(S^2), which is what lets the 32k-prefill dry-run cells fit.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (dense_init, head_rmsnorm, apply_rope,
                                 inner_unroll, pdtype)

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    d, dt = cfg.d_model, pdtype(cfg)
    ks = jax.random.split(key, 6)
    p = {"wq": dense_init(ks[0], d, cfg.q_dim, dt),
         "wk": dense_init(ks[1], d, cfg.kv_dim, dt),
         "wv": dense_init(ks[2], d, cfg.kv_dim, dt),
         "wo": dense_init(ks[3], cfg.q_dim, d, dt)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype=dt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype=dt)
    return p


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def qkv_project(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray, fuse_qkv: bool = True,
                rope: bool = True):
    """x: [B, S, d] -> q [B,S,H,D], k/v [B,S,Hkv,D] with qk-norm + RoPE."""
    from repro._compat.jax_compat import SHARDED_CONCAT_SAFE
    if fuse_qkv and not SHARDED_CONCAT_SAFE:
        fuse_qkv = False    # jax 0.4.x: sharded-axis concat is miscompiled
    if fuse_qkv:
        wqkv = jnp.concatenate([params["wq"], params["wk"], params["wv"]],
                               axis=1)
        qkv = x @ wqkv
        q, k, v = jnp.split(qkv, [cfg.q_dim, cfg.q_dim + cfg.kv_dim], axis=-1)
    else:
        q, k, v = x @ params["wq"], x @ params["wk"], x @ params["wv"]
    q = _split_heads(q, cfg.n_heads, cfg.head_dim)
    k = _split_heads(k, cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(v, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = head_rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if rope and cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, q_block: int = 512,
                      kv_block: int = 512,
                      logit_softcap: float = 0.0) -> jnp.ndarray:
    """Flash-style attention. q: [B,Sq,H,D], k/v: [B,Skv,Hkv,D].

    GQA: H must be a multiple of Hkv. Returns [B, Sq, H, D].
    Causal masking assumes q and k cover the same [0, S) positions.
    """
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    assert sq % q_block == 0
    kv_valid = skv
    if skv % kv_block:                   # pad + mask (e.g. 1601 vision toks)
        pad = kv_block - skv % kv_block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv += pad
    nq, nkv = sq // q_block, skv // kv_block
    scale = 1.0 / (d ** 0.5)

    # [B, nq, Bq, Hkv, G, D] / [B, nkv, Bk, Hkv, D]
    qb = q.reshape(b, nq, q_block, hkv, group, d)
    kb = k.reshape(b, nkv, kv_block, hkv, d)
    vb = v.reshape(b, nkv, kv_block, hkv, d)

    q_pos = (jnp.arange(nq)[:, None] * q_block
             + jnp.arange(q_block)[None, :])            # [nq, Bq]

    def kv_step(carry, inputs):
        acc, m_prev, l_prev = carry                     # acc [B,nq,Bq,Hkv,G,D]
        kj, vj, j = inputs                              # kj [B,Bk,Hkv,D]
        s = jnp.einsum("bnqhgd,bkhd->bnqhgk", qb, kj,
                       preferred_element_type=jnp.float32) * scale
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        kv_pos = j * kv_block + jnp.arange(kv_block)           # [Bk]
        if causal:
            mask = q_pos[:, :, None] >= kv_pos[None, None, :]  # [nq,Bq,Bk]
            s = jnp.where(mask[None, :, :, None, None, :], s, NEG_INF)
        if kv_valid != skv:
            vmask = kv_pos < kv_valid                          # [Bk]
            s = jnp.where(vmask[None, None, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bnqhgk,bkhd->bnqhgd", p,
                        vj.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, nq, q_block, hkv, group, d), jnp.float32)
    m0 = jnp.full((b, nq, q_block, hkv, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, q_block, hkv, group), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        kv_step, (acc0, m0, l0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nkv)),
        unroll=inner_unroll())
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def _flash_decode_partial(q: jnp.ndarray, k_cache: jnp.ndarray,
                          v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                          kv_block: int = 2048,
                          logit_softcap: float = 0.0):
    """Unnormalized flash-decode over one (possibly local) cache.

    q: [B,1,H,D]; caches [B,Smax,Hkv,D]; ``kv_len`` (scalar or [B]) masks
    the unwritten tail. Returns the online-softmax partials
    (acc [B,Hkv,G,D], m [B,Hkv,G], l [B,Hkv,G]) — combinable across
    shards/pages.
    """
    b, _, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    group = h // hkv
    kv_block = min(kv_block, smax)
    assert smax % kv_block == 0
    nkv = smax // kv_block
    scale = 1.0 / (d ** 0.5)
    qh = q.reshape(b, hkv, group, d)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len), (b,))

    kb = jnp.moveaxis(k_cache.reshape(b, nkv, kv_block, hkv, d), 1, 0)
    vb = jnp.moveaxis(v_cache.reshape(b, nkv, kv_block, hkv, d), 1, 0)

    def kv_step(carry, inputs):
        acc, m_prev, l_prev = carry
        kj, vj, j = inputs
        s = jnp.einsum("bhgd,bkhd->bhgk", qh, kj,
                       preferred_element_type=jnp.float32) * scale
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        pos = j * kv_block + jnp.arange(kv_block)             # [Bk]
        mask = pos[None, :] < kv_len[:, None]                 # [B, Bk]
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhgk,bkhd->bhgd", p, vj.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l_new), None

    # initial carry derived from q AND k so its varying-manual-axes cover
    # every axis the scan body produces when this runs inside a shard_map
    # region (paged decode: q varies over batch axes, k over page axes)
    zk = (k_cache.reshape(-1)[0] * 0).astype(jnp.float32)
    q0 = qh.astype(jnp.float32)
    acc0 = q0 * 0.0 + zk                              # [B,Hkv,G,D]
    m0 = q0[..., 0] * 0.0 + zk + NEG_INF              # [B,Hkv,G]
    l0 = q0[..., 0] * 0.0 + zk
    (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nkv)),
                                  unroll=inner_unroll())
    return acc, m, l


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, kv_len: jnp.ndarray,
                     kv_block: int = 2048,
                     logit_softcap: float = 0.0) -> jnp.ndarray:
    """Single-token flash-decode. q: [B,1,H,D]; caches [B,Smax,Hkv,D]."""
    b, _, h, d = q.shape
    acc, m, l = _flash_decode_partial(q, k_cache, v_cache, kv_len,
                                      kv_block, logit_softcap)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def chunk_prefill_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, pos: jnp.ndarray,
                            kv_block: int = 2048,
                            logit_softcap: float = 0.0) -> jnp.ndarray:
    """Multi-query flash-decode for chunked prefill against a live cache.

    q: [B, C, H, D] — a chunk of C fresh tokens whose K/V were already
    written into the caches at [pos, pos+C) (per-row ``pos``, int32 [B]).
    caches: [B, Smax, Hkv, D]. Query i of row b attends to cache positions
    <= pos[b] + i (prior context plus the intra-chunk causal prefix).
    Returns [B, C, H, D].
    """
    b, c, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    group = h // hkv
    kv_block = min(kv_block, smax)
    assert smax % kv_block == 0
    nkv = smax // kv_block
    scale = 1.0 / (d ** 0.5)
    qh = q.reshape(b, c, hkv, group, d)
    limit = (jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))[:, None]
             + jnp.arange(c, dtype=jnp.int32)[None])          # [B, C]

    kb = jnp.moveaxis(k_cache.reshape(b, nkv, kv_block, hkv, d), 1, 0)
    vb = jnp.moveaxis(v_cache.reshape(b, nkv, kv_block, hkv, d), 1, 0)

    def kv_step(carry, inputs):
        acc, m_prev, l_prev = carry                 # acc [B,C,Hkv,G,D]
        kj, vj, j = inputs                          # kj [B,Bk,Hkv,D]
        s = jnp.einsum("bchgd,bkhd->bchgk", qh, kj,
                       preferred_element_type=jnp.float32) * scale
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        kv_pos = j * kv_block + jnp.arange(kv_block)            # [Bk]
        mask = kv_pos[None, None, :] <= limit[:, :, None]       # [B, C, Bk]
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bchgk,bkhd->bchgd", p, vj.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, c, hkv, group, d), jnp.float32)
    m0 = jnp.full((b, c, hkv, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, c, hkv, group), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nkv)),
                                  unroll=inner_unroll())
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, c, h, d).astype(q.dtype)


def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, new_k: jnp.ndarray,
                           new_v: jnp.ndarray, pos: jnp.ndarray, *,
                           batch_axes, page_axes,
                           kv_block: int = 2048,
                           logit_softcap: float = 0.0,
                           force_shard_map: bool = False,
                           k_scale: Optional[jnp.ndarray] = None,
                           v_scale: Optional[jnp.ndarray] = None):
    """Distributed flash-decode over a page-sharded KV cache (shard_map).

    q: [B,1,H,D]; new_k/new_v: [B,1,Hkv,D]; pages: [B,P,page,Hkv,D] with
    the page axis sharded over ``page_axes``. Each rank owns a contiguous
    token range: the rank holding page(pos) writes the new KV (the paper's
    HDM decoder routes the store to the owning root port/EP), every rank
    runs a local flash-decode over its own pages, and the online-softmax
    partials combine with one tiny pmax/psum pair over ``page_axes`` — the
    cross-root-port read combine. Returns (o [B,1,H,D], k_pages',
    v_pages').

    Quantized cache (``kv_quant="int8"``): pass int8 pages plus fp32
    ``k_scale``/``v_scale`` [B,P,Hkv]. The pages are dequantized before
    the write + flash-decode (decode math stays fp32) and requantized
    with monotone per-page scale growth afterwards, so untouched pages
    round-trip bit-exactly. Returns a 5-tuple (o, k_pages', v_pages',
    k_scale', v_scale') in that case.

    ``force_shard_map`` disables the single-rank fast path so the
    shard_map body runs even on degenerate (size-1) axes — the two paths
    must be numerically identical, and the differential parity suite
    exercises exactly that.
    """
    from jax.sharding import PartitionSpec as P

    from repro.models import kv_quant as kvq

    quantized = k_scale is not None
    b, _, h, d = q.shape
    hkv = k_pages.shape[3]
    group = h // hkv

    def _axes_size(axes):
        mesh = jax.sharding.get_abstract_mesh()
        if axes is None or mesh is None or mesh.empty:
            return 1
        sizes = dict(mesh.shape)
        group_ = axes if isinstance(axes, tuple) else (axes,)
        n = 1
        for a in group_:
            n *= sizes.get(a, 1)
        return n

    # divisibility fallbacks (tiny smoke caches / odd batches)
    if k_pages.shape[1] % max(_axes_size(page_axes), 1):
        page_axes = None
    if b % max(_axes_size(batch_axes), 1):
        batch_axes = None

    # single-rank fast path: with no page or batch parallelism the
    # shard_map wrapper, rank masking and cross-rank combine are pure
    # overhead — write the new KV with one contiguous per-row
    # dynamic_update_slice and run the flash-decode directly (identical
    # math; the serving decode tick is latency-critical)
    if not force_shard_map and _axes_size(page_axes) <= 1 \
            and _axes_size(batch_axes) <= 1:
        hkv_ = k_pages.shape[3]
        smax = k_pages.shape[1] * k_pages.shape[2]
        pb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        kd = (kvq.dequantize_pages(k_pages, k_scale) if quantized
              else k_pages)
        vd = (kvq.dequantize_pages(v_pages, v_scale) if quantized
              else v_pages)
        kf = kd.reshape(b, smax, hkv_, d)
        vf = vd.reshape(b, smax, hkv_, d)

        def write(buf, new, p):
            return jax.lax.dynamic_update_slice(
                buf, new.astype(buf.dtype), (p, 0, 0))

        kf = jax.vmap(write)(kf, new_k, pb)
        vf = jax.vmap(write)(vf, new_v, pb)
        acc, m, l = _flash_decode_partial(q, kf, vf, pb + 1, kv_block,
                                          logit_softcap)
        out = (acc / jnp.maximum(l[..., None], 1e-30)).reshape(
            b, 1, hkv_ * group, d).astype(q.dtype)
        if quantized:
            kq, ks = kvq.requantize_pages(kf.reshape(kd.shape), k_scale)
            vq, vs = kvq.requantize_pages(vf.reshape(vd.shape), v_scale)
            return out, kq, vq, ks, vs
        return (out, kf.reshape(k_pages.shape), vf.reshape(v_pages.shape))

    q_spec = P(batch_axes, None, None, None)
    kv_spec = P(batch_axes, page_axes, None, None, None)
    scale_spec = P(batch_axes, page_axes, None)       # [B, P, Hkv]
    pos_spec = P(batch_axes)                          # per-slot positions

    def local(qb, kp, vp, nk, nv, p_, ks_, vs_):
        bl, pl, page, _, _ = kp.shape
        L = pl * page
        if page_axes:
            rank = jax.lax.axis_index(page_axes)
        else:
            rank = jnp.zeros((), jnp.int32)
        start = rank.astype(jnp.int32) * L
        # per-slot positions (continuous batching): p_ is [B] (or scalar)
        pb = jnp.broadcast_to(jnp.asarray(p_, jnp.int32), (bl,))
        off = pb - start                              # [B]
        in_range = (off >= 0) & (off < L)
        offc = jnp.clip(off, 0, L - 1)
        # quantized cache: dequantize the local pages before the write +
        # flash-decode; scales are sharded exactly like the pages so each
        # rank sees the scales of its own page shard
        kdl = kvq.dequantize_pages(kp, ks_) if quantized else kp
        vdl = kvq.dequantize_pages(vp, vs_) if quantized else vp
        kf = kdl.reshape(bl, L, hkv, d)
        vf = vdl.reshape(bl, L, hkv, d)
        # owner-only write at each slot's own offset (scatter: in-place)
        rows = jnp.arange(bl)
        old_k = kf[rows, offc]                        # [B, Hkv, D]
        old_v = vf[rows, offc]
        sel = in_range[:, None, None]
        kf = kf.at[rows, offc].set(
            jnp.where(sel, nk[:, 0].astype(kf.dtype), old_k))
        vf = vf.at[rows, offc].set(
            jnp.where(sel, nv[:, 0].astype(vf.dtype), old_v))
        valid = jnp.clip(pb + 1 - start, 0, L)        # [B] visible tokens
        acc, m, l = _flash_decode_partial(qb, kf, vf, valid, kv_block,
                                          logit_softcap)
        if page_axes:
            m_g = jax.lax.pmax(m, page_axes)
            scale = jnp.exp(m - m_g)
            l_g = jax.lax.psum(l * scale, page_axes)
            acc_g = jax.lax.psum(acc * scale[..., None], page_axes)
        else:
            l_g, acc_g = l, acc
        out = acc_g / jnp.maximum(l_g[..., None], 1e-30)
        out = out.reshape(bl, 1, hkv * group, d).astype(qb.dtype)
        if quantized:
            kq, ks2 = kvq.requantize_pages(kf.reshape(kdl.shape), ks_)
            vq, vs2 = kvq.requantize_pages(vf.reshape(vdl.shape), vs_)
            return out, kq, vq, ks2, vs2
        return out, kf.reshape(kp.shape), vf.reshape(vp.shape)

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    if quantized:
        return jax.shard_map(
            local,
            in_specs=(q_spec, kv_spec, kv_spec, q_spec, q_spec, pos_spec,
                      scale_spec, scale_spec),
            out_specs=(q_spec, kv_spec, kv_spec, scale_spec, scale_spec))(
                q, k_pages, v_pages, new_k, new_v, pos, k_scale, v_scale)
    return jax.shard_map(
        lambda qb, kp, vp, nk, nv, p_: local(qb, kp, vp, nk, nv, p_, None,
                                             None),
        in_specs=(q_spec, kv_spec, kv_spec, q_spec, q_spec, pos_spec),
        out_specs=(q_spec, kv_spec, kv_spec))(
            q, k_pages, v_pages, new_k, new_v, pos)


def naive_attention(q, k, v, causal=True, logit_softcap: float = 0.0):
    """Reference O(S^2) attention (oracle for tests)."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qh = q.reshape(b, sq, hkv, group, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k,
                   preferred_element_type=jnp.float32) / (d ** 0.5)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
