"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, sequential recurrence), per arXiv:2405.04517.

mLSTM per head: C_t = f_t C_{t-1} + i_t v_t k_t^T, n_t = f_t n_{t-1} + i_t
k_t, h_t = (C_t q_t) / max(|n_t.q_t|, exp(-m_t)) with exponential gates
stabilized by m_t. Train/prefill uses a chunkwise form (intra-chunk
quadratic + inter-chunk state carry, like the SSD scan); decode is the
recurrent step. Output gating uses the block's silu branch.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (dense_init, inner_unroll, mlp_apply,
                                 pdtype, rmsnorm, rmsnorm_init)

NEG = -1e30


def _dims(cfg: ModelConfig):
    d_in = cfg.mlstm_expand * cfg.d_model
    nh = cfg.n_heads
    return d_in, nh, d_in // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig) -> Dict:
    d, dt = cfg.d_model, pdtype(cfg)
    d_in, nh, dh = _dims(cfg)
    ks = jax.random.split(key, 7)
    return {"ln": rmsnorm_init(d, dt),
            "w_up1": dense_init(ks[0], d, d_in, dt),
            "w_up2": dense_init(ks[1], d, d_in, dt),
            "conv_w": (jax.random.normal(ks[2], (4, d_in)) * 0.1).astype(dt),
            "w_qkv": dense_init(ks[3], d_in, 3 * d_in, dt),
            "w_gates": dense_init(ks[4], d_in, 2 * nh, jnp.float32),
            "gate_bias": jnp.concatenate(
                [jnp.zeros((nh,)), 3.0 + jnp.arange(nh) * 0.5]
            ).astype(jnp.float32),
            "ln_head": rmsnorm_init(d_in, dt),
            "w_down2": dense_init(ks[5], d_in, d, dt)}


def _causal_conv(x, w):
    width = w.shape[0]
    out = x * w[-1]
    for j in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[width - 1 - j]
    return out


def _mlstm_qkvg(params, cfg, x):
    d_in, nh, dh = _dims(cfg)
    b, s, _ = x.shape
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    u = h @ params["w_up1"]
    zg = h @ params["w_up2"]
    c = jax.nn.silu(_causal_conv(u, params["conv_w"]))
    qkv = c @ params["w_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    v = u  # value branch takes the pre-conv projection (paper Fig. 10)
    gates = c.astype(jnp.float32) @ params["w_gates"] + params["gate_bias"]
    ig, fg = jnp.split(gates, 2, axis=-1)       # [B,S,nh] raw (log-space)
    fg = jax.nn.log_sigmoid(fg)                 # forget in (0,1), log-space
    shape = (b, s, nh, dh)
    return (q.reshape(shape), k.reshape(shape), v.reshape(shape),
            ig, fg, zg)


def mlstm_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                chunk: int = 256) -> jnp.ndarray:
    """Chunked-parallel mLSTM. x: [B,S,d] -> [B,S,d]."""
    d_in, nh, dh = _dims(cfg)
    b, s, _ = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    q, k, v, ig, fg, zg = _mlstm_qkvg(params, cfg, x)
    scale = 1.0 / (dh ** 0.5)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)

    qc, kc, vc = map(to_chunks, (q.astype(jnp.float32) * scale,
                                 k.astype(jnp.float32),
                                 v.astype(jnp.float32)))
    igc, fgc = to_chunks(ig), to_chunks(fg)
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def chunk_step(carry, inp):
        C, n, m = carry            # [B,nh,dh,dh], [B,nh,dh], [B,nh]
        qq, kk, vv, ii, ff = inp
        F = jnp.cumsum(ff, axis=1)                    # [B,Q,nh]
        # intra-chunk log weights D[t,s] = F[t]-F[s]+i[s]
        logd = (F[:, :, None, :] - F[:, None, :, :]
                + ii[:, None, :, :])                  # [B,Q,Q,nh]
        logd = jnp.where(causal[None, :, :, None], logd, NEG)
        b_inter = F + m[:, None, :]                   # [B,Q,nh]
        m_loc = jnp.maximum(logd.max(axis=2), b_inter)
        m_loc = jax.lax.stop_gradient(m_loc)
        dmat = jnp.exp(logd - m_loc[:, :, None, :])   # [B,Q,Q,nh]
        sc = jnp.einsum("bqhd,bshd->bqsh", qq, kk)    # [B,Q,Q,nh]
        w_inter = jnp.exp(b_inter - m_loc)            # [B,Q,nh]
        num = jnp.einsum("bqsh,bqsh,bshd->bqhd", sc, dmat, vv) \
            + jnp.einsum("bqh,bhde,bqhe->bqhd", w_inter, C, qq)
        den_vec = jnp.einsum("bqsh,bshd->bqhd", dmat, kk)  # sum dmat*k
        den = jnp.einsum("bqhd,bqhd->bqh", den_vec, qq) \
            + w_inter * jnp.einsum("bhd,bqhd->bqh", n, qq)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_loc))
        hq = num / den[..., None]                     # [B,Q,nh,dh]
        # state update to chunk end
        F_last = F[:, -1, :]                          # [B,nh]
        w_end = jnp.exp(F_last[:, None, :] - F + ii)  # [B,Q,nh]
        m_new = jnp.maximum(F_last + m,
                            (F_last[:, None, :] - F + ii).max(axis=1))
        m_new = jax.lax.stop_gradient(m_new)
        r = jnp.exp(F_last + m - m_new)               # carry rescale
        w_end = jnp.exp((F_last[:, None, :] - F + ii)
                        - m_new[:, None, :])
        C_new = r[..., None, None] * C \
            + jnp.einsum("bqh,bqhd,bqhe->bhde", w_end, vv, kk)
        n_new = r[..., None] * n \
            + jnp.einsum("bqh,bqhd->bhd", w_end, kk)
        return (C_new, n_new, m_new), hq

    C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    m0 = jnp.full((b, nh), -1e9, jnp.float32)
    _, hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, igc, fgc),
                         unroll=inner_unroll())
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d_in).astype(x.dtype)
    h = rmsnorm(params["ln_head"], h, cfg.norm_eps)
    h = h * jax.nn.silu(zg)
    return x + h @ params["w_down2"]


def mlstm_state_init(cfg: ModelConfig, batch: int):
    d_in, nh, dh = _dims(cfg)
    return {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.full((batch, nh), -1e9, jnp.float32),
            "conv": jnp.zeros((batch, 3, d_in), jnp.float32)}


def mlstm_step(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
               state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """Recurrent decode step. x: [B,1,d]."""
    d_in, nh, dh = _dims(cfg)
    b = x.shape[0]
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    u = (h @ params["w_up1"])[:, 0]
    zg = (h @ params["w_up2"])[:, 0]
    window = jnp.concatenate(
        [state["conv"], u[:, None].astype(jnp.float32)], axis=1)
    c = jax.nn.silu(jnp.einsum("bwc,wc->bc", window,
                               params["conv_w"].astype(jnp.float32)))
    qkv = c.astype(x.dtype) @ params["w_qkv"]
    q, k, _ = jnp.split(qkv, 3, axis=-1)
    v = u
    gates = c @ params["w_gates"] + params["gate_bias"]
    ig, fg = jnp.split(gates, 2, axis=-1)        # [B, nh]
    fg = jax.nn.log_sigmoid(fg)
    q = q.reshape(b, nh, dh).astype(jnp.float32) / (dh ** 0.5)
    k = k.reshape(b, nh, dh).astype(jnp.float32)
    v = v.reshape(b, nh, dh).astype(jnp.float32)
    m_new = jnp.maximum(fg + state["m"], ig)
    i_s = jnp.exp(ig - m_new)
    f_s = jnp.exp(fg + state["m"] - m_new)
    C = f_s[..., None, None] * state["C"] \
        + i_s[..., None, None] * jnp.einsum("bhd,bhe->bhde", v, k)
    n = f_s[..., None] * state["n"] + i_s[..., None] * k
    num = jnp.einsum("bhde,bhe->bhd", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                      jnp.exp(-m_new))
    hq = (num / den[..., None]).reshape(b, 1, d_in).astype(x.dtype)
    hq = rmsnorm(params["ln_head"], hq, cfg.norm_eps)
    hq = hq * jax.nn.silu(zg)[:, None]
    out = x + hq @ params["w_down2"]
    return out, {"C": C, "n": n, "m": m_new, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig) -> Dict:
    d, dt = cfg.d_model, pdtype(cfg)
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 5)
    ff = max(1, int(d * 4 / 3) // 64 * 64)
    return {"ln": rmsnorm_init(d, dt),
            "conv_w": (jax.random.normal(ks[0], (4, d)) * 0.1).astype(dt),
            "w_gates": dense_init(ks[1], d, 4 * d, dt),
            "r_gates": (jax.random.normal(ks[2], (nh, dh, 4 * dh))
                        * 0.02).astype(jnp.float32),
            "gate_bias": jnp.zeros((4 * d,), jnp.float32),
            "w_out": dense_init(ks[3], d, d, dt),
            "ln_ff": rmsnorm_init(d, dt),
            "ffn": {"w_gate": dense_init(ks[4], d, ff, dt),
                    "w_up": dense_init(jax.random.fold_in(ks[4], 1), d, ff,
                                       dt),
                    "w_down": dense_init(jax.random.fold_in(ks[4], 2), ff, d,
                                         dt)}}


def slstm_state_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6,
            "m": jnp.full((batch, nh, dh), -1e9, jnp.float32),
            "conv": jnp.zeros((batch, 3, d), jnp.float32)}


def _slstm_cell(gates, state, nh, dh):
    """gates: [B, 4*d] raw; state dict; returns (h, new_state)."""
    b = gates.shape[0]
    g = gates.reshape(b, nh, dh, 4)
    ig, fg, zg, og = g[..., 0], g[..., 1], g[..., 2], g[..., 3]
    m_new = jnp.maximum(fg + state["m"], ig)
    i_s = jnp.exp(ig - m_new)
    f_s = jnp.exp(fg + state["m"] - m_new)
    c = f_s * state["c"] + i_s * jnp.tanh(zg)
    n = f_s * state["n"] + i_s
    h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1e-6)
    return h, {"h": h, "c": c, "n": n, "m": m_new}


def slstm_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray):
    """Sequential sLSTM over the full sequence. x: [B,S,d]."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    b, s, _ = x.shape
    hpre = rmsnorm(params["ln"], x, cfg.norm_eps)
    c_in = jax.nn.silu(_causal_conv(hpre, params["conv_w"]))
    wx = (c_in @ params["w_gates"]).astype(jnp.float32) \
        + params["gate_bias"]                                # [B,S,4d]

    st0 = slstm_state_init(cfg, b)
    st0.pop("conv")

    def step(st, wxt):
        rec = jnp.einsum("bhd,hde->bhe", st["h"],
                         params["r_gates"]).reshape(b, 4 * d)
        h, st_new = _slstm_cell(wxt + rec, st, nh, dh)
        return st_new, h

    _, hs = jax.lax.scan(step, st0, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    x = x + h @ params["w_out"]
    h2 = rmsnorm(params["ln_ff"], x, cfg.norm_eps)
    ff = params["ffn"]
    y = jax.nn.silu(h2 @ ff["w_gate"]) * (h2 @ ff["w_up"])
    return x + y @ ff["w_down"]


def slstm_step(params: Dict, cfg: ModelConfig, x: jnp.ndarray, state: Dict):
    """Decode step. x: [B,1,d]."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    b = x.shape[0]
    hpre = rmsnorm(params["ln"], x, cfg.norm_eps)[:, 0]
    window = jnp.concatenate(
        [state["conv"], hpre[:, None].astype(jnp.float32)], axis=1)
    c_in = jax.nn.silu(jnp.einsum("bwc,wc->bc", window,
                                  params["conv_w"].astype(jnp.float32)))
    wx = (c_in.astype(x.dtype) @ params["w_gates"]).astype(jnp.float32) \
        + params["gate_bias"]
    rec = jnp.einsum("bhd,hde->bhe", state["h"],
                     params["r_gates"]).reshape(b, 4 * d)
    h, st_new = _slstm_cell(wx + rec, state, nh, dh)
    st_new["conv"] = window[:, 1:]
    h = h.reshape(b, 1, d).astype(x.dtype)
    x = x + h @ params["w_out"]
    h2 = rmsnorm(params["ln_ff"], x, cfg.norm_eps)
    ff = params["ffn"]
    y = jax.nn.silu(h2 @ ff["w_gate"]) * (h2 @ ff["w_up"])
    return x + y @ ff["w_down"], st_new
