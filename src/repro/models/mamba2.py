"""Mamba2 (SSD) blocks: chunked parallel scan for train/prefill, recurrent
step for decode. Used standalone and inside the zamba2 hybrid.

State per head: h in R^{P x N} (head_dim x state), per-step decay
a_t = exp(dt_t * A_h); h_t = a_t h_{t-1} + dt_t x_t (x) B_t; y_t = h_t C_t
+ D_h x_t. The chunked (SSD) form computes intra-chunk contributions with a
masked quadratic within each chunk and carries h across chunks — the same
structure as the Pallas kernel in repro.kernels.mamba2_scan.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (dense_init, inner_unroll, pdtype,
                                 rmsnorm, rmsnorm_init)


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_head_dim, cfg.ssm_state


def mamba_init(key, cfg: ModelConfig) -> Dict:
    d, dt = cfg.d_model, pdtype(cfg)
    d_in, nh, p, n = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in, dt),     # [z | x]
        "bc_proj": dense_init(ks[1], d, 2 * n, dt),        # [B | C]
        "dt_proj": dense_init(ks[2], d, nh, dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "conv_w": (jax.random.normal(ks[3], (cfg.ssm_conv, d_in + 2 * n))
                   * 0.1).astype(dt),
        "ln_out": rmsnorm_init(d_in, dt),
        "out_proj": dense_init(ks[4], d_in, d, dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds. x: [B,S,C]; w: [W,C]."""
    width = w.shape[0]
    out = x * w[-1]
    for j in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[width - 1 - j]
    return out


def _project(params, cfg, u):
    d_in, nh, p, n = _dims(cfg)
    zx = u @ params["in_proj"]
    z, x = jnp.split(zx, 2, axis=-1)
    bc = u @ params["bc_proj"]
    dt_raw = (u @ params["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])
    dt = jnp.clip(dt, 1e-4, 10.0)
    return z, x, bc, dt


def mamba_apply(params: Dict, cfg: ModelConfig, u: jnp.ndarray,
                chunk: int = 256) -> jnp.ndarray:
    """Full-sequence SSD. u: [B, S, d] -> [B, S, d]."""
    d_in, nh, p, n = _dims(cfg)
    b, s, _ = u.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    z, x, bc, dt = _project(params, cfg, u)
    conv_in = jnp.concatenate([x, bc], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, params["conv_w"]))
    x, bmat, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)
    xh = x.reshape(b, s, nh, p).astype(jnp.float32)
    a = -jnp.exp(params["A_log"])                             # [nh]
    log_a = dt * a[None, None, :]                             # [B,S,nh] (<0)
    xdt = xh * dt[..., None]                                  # [B,S,nh,P]

    # chunk views
    xc = xdt.reshape(b, nc, chunk, nh, p)
    bc_ = bmat.reshape(b, nc, chunk, n).astype(jnp.float32)
    cc_ = cmat.reshape(b, nc, chunk, n).astype(jnp.float32)
    la = jnp.cumsum(log_a.reshape(b, nc, chunk, nh), axis=2)  # [B,nc,Q,nh]

    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])                   # [Q, Q]

    def chunk_step(h, inputs):
        xq, bq, cq, laq = inputs      # [B,Q,nh,P],[B,Q,N],[B,Q,N],[B,Q,nh]
        # intra-chunk: masked quadratic
        g = jnp.einsum("bqn,bmn->bqm", cq, bq)                # [B,Q,Q]
        logdec = laq[:, :, None, :] - laq[:, None, :, :]
        logdec = jnp.where(causal[None, :, :, None], logdec, -1e30)
        decay = jnp.exp(logdec)
        y = jnp.einsum("bqm,bqmh,bmhp->bqhp", g, decay, xq)
        # inter-chunk: incoming state decayed to each position
        y = y + jnp.einsum("bqn,bhpn,bqh->bqhp", cq, h, jnp.exp(laq))
        # state update for the next chunk
        la_last = laq[:, -1:, :]                              # [B,1,nh]
        w = jnp.exp(la_last - laq)                            # [B,Q,nh]
        h_new = jnp.einsum("bh,bhpn->bhpn",
                           jnp.exp(la_last[:, 0, :]), h) \
            + jnp.einsum("bqhp,bqn,bqh->bhpn", xq, bq, w)
        return h_new, y

    h0 = jnp.zeros((b, nh, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(bc_, 1, 0),
         jnp.moveaxis(cc_, 1, 0), jnp.moveaxis(la, 1, 0)),
        unroll=inner_unroll())
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, p)
    y = y + xh * params["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(u.dtype)
    y = rmsnorm(params["ln_out"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"]


def mamba_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_in, nh, p, n = _dims(cfg)
    return {"h": jnp.zeros((batch, nh, p, n), dtype),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in + 2 * n),
                              dtype)}


def mamba_step(params: Dict, cfg: ModelConfig, u: jnp.ndarray,
               state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """Recurrent decode step. u: [B, 1, d]."""
    d_in, nh, p, n = _dims(cfg)
    b = u.shape[0]
    z, x, bc, dt = _project(params, cfg, u)    # z,x: [B,1,d_in]; dt [B,1,nh]
    conv_in = jnp.concatenate([x, bc], axis=-1)[:, 0]         # [B, C]
    window = jnp.concatenate(
        [state["conv"], conv_in[:, None].astype(state["conv"].dtype)],
        axis=1)                                               # [B, W, C]
    w = params["conv_w"]
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                                  w.astype(jnp.float32)))
    x1, b1, c1 = jnp.split(conv, [d_in, d_in + n], axis=-1)
    xh = x1.reshape(b, nh, p)
    dt1 = dt[:, 0]                                            # [B, nh]
    a = jnp.exp(dt1 * (-jnp.exp(params["A_log"]))[None, :])   # [B, nh]
    h = state["h"] * a[..., None, None] \
        + jnp.einsum("bhp,bn,bh->bhpn", xh, b1, dt1)
    y = jnp.einsum("bhpn,bn->bhp", h, c1) \
        + xh * params["D"][None, :, None]
    y = y.reshape(b, 1, d_in).astype(u.dtype)
    y = rmsnorm(params["ln_out"], y * jax.nn.silu(z), cfg.norm_eps)
    new_state = {"h": h, "conv": window[:, 1:]}
    return y @ params["out_proj"], new_state


def mamba_ref(params: Dict, cfg: ModelConfig, u: jnp.ndarray) -> jnp.ndarray:
    """Sequential-oracle SSD (for tests): step through time with mamba-step
    semantics but full-sequence conv."""
    d_in, nh, p, n = _dims(cfg)
    b, s, _ = u.shape
    z, x, bc, dt = _project(params, cfg, u)
    conv_in = jnp.concatenate([x, bc], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, params["conv_w"]))
    x, bmat, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)
    xh = x.reshape(b, s, nh, p).astype(jnp.float32)
    a = jnp.exp(dt * (-jnp.exp(params["A_log"]))[None, None, :])

    def step(h, inp):
        xt, bt, ct, at, dtt = inp
        h = h * at[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", xt, bt, dtt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((b, nh, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (
        jnp.moveaxis(xh, 1, 0), jnp.moveaxis(bmat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(cmat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(a, 1, 0), jnp.moveaxis(dt, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1) + xh * params["D"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(u.dtype)
    y = rmsnorm(params["ln_out"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"]
