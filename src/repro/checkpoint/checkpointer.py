"""Async sharded checkpointing with elastic restore.

The deterministic-store discipline applied to persistence: a step's state
is "complete" the moment its shards land in the staging area (snapshot =
device_get of each process's addressable shards, off the step path); the
serialization to disk drains in a background thread, and a checkpoint
becomes visible only when its manifest commit-marker is atomically
renamed into place — a crash mid-write can never yield a half checkpoint.

Restore is *elastic*: shards are saved per-leaf as full host arrays plus
the PartitionSpec; loading onto a different mesh shape (scale up/down)
re-shards through jax.device_put with the target sharding.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _np_dtype(name: str) -> np.dtype:
    """Resolve a recorded dtype name, including the ml_dtypes extended
    floats (bfloat16, float8_*) numpy itself cannot parse."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


class Checkpointer:
    """Directory layout: <dir>/step_<n>/{manifest.json, leaf_<i>.npy}."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot now, write in the background (async by default)."""
        self.wait()
        leaves, treedef = _flatten(state)
        # snapshot: pull shards off device immediately (cheap, bounded)
        host_leaves = [np.asarray(l) if l is not None else None
                       for l in leaves]
        payload = (step, host_leaves, treedef, extra or {})
        self._thread = threading.Thread(target=self._write, args=(payload,),
                                        daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, payload: Tuple) -> None:
        step, host_leaves, treedef, extra = payload
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, leaf in enumerate(host_leaves):
            if leaf is not None:
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), leaf)
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        manifest = {"step": step, "n_leaves": len(host_leaves),
                    "none_leaves": [i for i, l in enumerate(host_leaves)
                                    if l is None],
                    # .npy round-trips ml_dtypes extended floats (bf16,
                    # fp8) as opaque void records — record each leaf's
                    # true dtype so restore can view the bits back
                    "dtypes": [None if l is None else str(l.dtype)
                               for l in host_leaves],
                    "extra": extra}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)              # atomic commit
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *,
                shardings: Any = None) -> Tuple[int, Any, Dict]:
        """Returns (step, state, extra). ``shardings`` (a pytree matching
        the state) re-shards onto the CURRENT mesh — elastic restore."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        none_set = set(manifest["none_leaves"])
        dtypes = manifest.get("dtypes") or [None] * manifest["n_leaves"]
        leaves = []
        for i in range(manifest["n_leaves"]):
            if i in none_set:
                leaves.append(None)
                continue
            arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
            if dtypes[i] is not None and str(arr.dtype) != dtypes[i]:
                want = _np_dtype(dtypes[i])
                # void records are the same bits under the wrong label
                arr = arr.view(want) if arr.dtype.kind == "V" \
                    else arr.astype(want)
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s)
                if x is not None and s is not None else x,
                state, shardings,
                is_leaf=lambda x: x is None or isinstance(x, np.ndarray))
        return step, state, manifest["extra"]
