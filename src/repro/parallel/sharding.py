"""Sharding rules: map param-tree paths -> PartitionSpec for the production mesh.

Axes: optional "pod" (multi-pod), "data" (DP + FSDP pool tier), "model" (TP/EP).

Tier semantics (the paper's HDM map, DESIGN.md §4.1):
  DEVICE tier  -> replicated over data axis (always resident, like GPU HBM)
  POOL tier    -> additionally sharded over the data axis (the CXL DRAM-EP
                  analogue: the "expander" is the rest of the mesh; layers are
                  gathered on use via speculative read)
  HOST tier    -> pinned_host memory kind on top of POOL sharding (SSD-EP
                  analogue; TPU only — gated by RunConfig.enable_host_tier)
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (regex over param path, spec WITHOUT the leading layer-stack axis)
# "F" marks the FSDP-shardable axis (replaced by fsdp axis for POOL tier,
# None for DEVICE tier). "M" is the tensor-parallel axis.
_RULES = [
    # embeddings
    (r"embedding$",            ("M", "F")),
    (r"unembed$",              ("F", "M")),
    # attention
    (r"\bwq$|\bwk$|\bwv$",     ("F", "M")),
    (r"\bwo$",                 ("M", "F")),
    (r"q_norm$|k_norm$",       (None,)),
    # dense mlp
    (r"w_gate$|w_up$",         ("F", "M")),
    (r"w_down$",               ("M", "F")),
    # moe
    (r"router$",               ("F", None)),
    (r"e_gate$|e_up$",         ("M", "F", None)),
    (r"e_down$",               ("M", None, "F")),
    # mamba2
    (r"in_proj$",              ("F", "M")),
    (r"out_proj$",             ("M", "F")),
    (r"conv_w$",               (None, "M")),
    (r"A_log$|\bD$|dt_bias$",  ("M",)),
    # xlstm (mLSTM / sLSTM)
    (r"w_up1$|w_up2$|w_qkv$|w_gates$",  ("F", "M")),
    (r"w_down2$|w_out$",       ("M", "F")),
    (r"r_gates$",              ("M", None, None)),
    # vlm cross-attention follows attention rules (same names)
    # norms / scalars / gates
    (r"scale$|bias$|gate$",    (None,)),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


# production mesh axis sizes — the divisibility guard below drops a mesh
# axis from a dim it does not divide (e.g. granite's vocab 49155 % 16 != 0,
# xlstm's 2*nh gate dim). Guarding against the production sizes keeps the
# specs identical between smoke (1x1) and production (16x16 / 2x16x16)
# meshes.
AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _divisible(axes, dim: int) -> bool:
    if axes is None:
        return True
    group = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in group:
        n *= AXIS_SIZES.get(a, 1)
    return dim % n == 0


def spec_for(path_str: str, shape, *, fsdp_axis, stacked: bool) -> P:
    """Resolve the PartitionSpec for one param leaf."""
    ndim = len(shape)
    for pat, spec in _RULES:
        if re.search(pat, path_str):
            out = []
            for s in spec:
                if s == "F":
                    out.append(fsdp_axis)
                elif s == "M":
                    out.append("model")
                else:
                    out.append(None)
            # normalize to actual rank (norm scales etc. may be rank-1)
            base = len(out)
            eff_ndim = ndim - (1 if stacked else 0)
            if eff_ndim < base:
                out = out[-eff_ndim:] if eff_ndim > 0 else []
            elif eff_ndim > base:
                out = [None] * (eff_ndim - base) + out
            if stacked:
                out = [None] + out
            out = [a if _divisible(a, shape[i]) else None
                   for i, a in enumerate(out)]
            return P(*out)
    # default: replicate
    return P(*([None] * ndim))


def param_specs(params_shape: Any, *, tier: str = "pool",
                multi_pod_fsdp: bool = False, stacked_prefixes=("blocks",
                                                                "groups")):
    """PartitionSpecs for a (possibly eval_shape'd) param tree.

    tier: "device" => no FSDP axis (replicated over data);
          "pool"/"host" => FSDP-shard over data (pool = DRAM EP analogue).
    """
    fsdp_axis = None
    if tier in ("pool", "host"):
        fsdp_axis = ("pod", "data") if multi_pod_fsdp else "data"

    def f(path, leaf):
        ps = _path_str(path)
        stacked = any(ps.startswith(p) or f"/{p}" in ps
                      for p in stacked_prefixes)
        return spec_for(ps, leaf.shape, fsdp_axis=fsdp_axis,
                        stacked=stacked)

    return jax.tree_util.tree_map_with_path(f, params_shape)


def gathered_specs(specs: Any, *, fsdp_axes=("data", "pod")) -> Any:
    """Specs with the FSDP axis removed — the materialized (gathered) form
    used inside the layer body after a speculative-read gather."""
    def strip(spec: P) -> P:
        out = []
        for s in spec:
            if s in fsdp_axes:
                out.append(None)
            elif isinstance(s, tuple):
                kept = tuple(a for a in s if a not in fsdp_axes)
                out.append(kept if kept else None)
            else:
                out.append(s)
        return P(*out)
    return jax.tree_util.tree_map(
        strip, specs, is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh_axes, seq_shard: bool = False) -> P:
    dp = ("pod", "data") if "pod" in mesh_axes else "data"
    return P(dp, "model" if seq_shard else None)


def shardings_from_specs(mesh: Mesh, specs: Any, memory_kind: Optional[str]
                         = None) -> Any:
    def mk(spec):
        if memory_kind is not None:
            return NamedSharding(mesh, spec, memory_kind=memory_kind)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(mk, specs,
                                  is_leaf=lambda x: isinstance(x, P))


def constrain(tree: Any, specs: Any) -> Any:
    """with_sharding_constraint over a pytree of PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s)
        if hasattr(x, "shape") else x,
        tree, specs)
