"""Config system: model architecture, input shapes, mesh, and run configs.

Every assigned architecture is a `ModelConfig` in src/repro/configs/<id>.py.
Input shapes are the four assigned (shape-set × arch) cells; `decode_*` /
`long_*` lower `serve_step` (single-token step against a KV cache), the
others lower `train_step`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (superset over all assigned families)."""

    arch_id: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True  # musicgen uses additive sinusoidal instead
    attn_logit_softcap: float = 0.0

    # MLP details
    activation: str = "swiglu"  # swiglu | geglu | gelu (plain 2-matrix MLP)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # hybrid / SSM (zamba2-style mamba2 + shared attention block)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    shared_block_period: int = 0  # >0: shared attn block every N mamba layers

    # xLSTM
    slstm_every: int = 0  # >0: sLSTM block every N layers (rest mLSTM)
    mlstm_expand: int = 2

    # VLM (cross-attention image layers; modality frontend is a stub)
    cross_attn_period: int = 0  # >0: every Nth layer is a cross-attn layer
    n_vision_tokens: int = 0

    # audio (decoder over EnCodec tokens)
    n_codebooks: int = 0

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # ---------------------------------------------------------- properties
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing => long_500k is runnable."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "audio":
            embed = self.n_codebooks * self.vocab_size * d * 2
        per_layer = 0
        # attention (dense / moe / vlm / audio); hybrid & ssm handled below
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        n_glu = 3 if self.activation in ("swiglu", "geglu") else 2
        if self.family == "moe":
            mlp = self.n_experts * n_glu * d * self.d_ff
            per_layer = attn + mlp + d * self.n_experts  # + router
        elif self.family in ("dense", "vlm", "audio"):
            mlp = n_glu * d * self.d_ff
            per_layer = attn + mlp
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            mamba = (d * (2 * d_in + 2 * self.ssm_state * 0 + nh)  # zx + dt
                     + d * 2 * (self.ssm_state + 0)                 # B,C proj
                     + d_in * d)                                    # out proj
            per_layer = mamba
        elif self.family == "ssm":
            # mLSTM block: up 2*(d->2d), qkv within, down 2d->d (approx)
            per_layer = 2 * d * (self.mlstm_expand * d) * 2
        total = embed + L * per_layer
        if self.family == "hybrid" and self.shared_block_period:
            total += (2 * d * d + attn + n_glu * d * self.d_ff + d * d)
        if self.family == "vlm" and self.cross_attn_period:
            n_cross = L // self.cross_attn_period
            total += n_cross * (attn + n_glu * d * self.d_ff)
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        d, L = self.d_model, self.n_layers
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp = self.top_k * 3 * d * self.d_ff
        return embed + L * (attn + mlp + d * self.n_experts)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell is runnable; else reason for the skip."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: O(L^2) attention at 524288 "
                       "is degenerate; skipped per assignment (sub-quadratic "
                       "mixing required). See DESIGN.md §7.")
    return True, ""


# ---------------------------------------------------------------------------
# Mesh / run configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self):
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs besides the architecture itself."""

    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = MeshConfig()

    # --- the paper's technique ------------------------------------------
    # parameter/optimizer tier policy: "device" (replicate over data axis),
    # "pool" (FSDP over data axis = CXL DRAM-EP analogue), "host"
    # (pinned_host = SSD-EP analogue; TPU only)
    param_tier: str = "pool"
    optimizer_tier: str = "pool"
    enable_host_tier: bool = False  # CPU backend cannot compile pinned_host
    # speculative read: 0 = off (plain CXL config), 1 = double buffer,
    # 2 = triple buffer
    sr_prefetch_depth: int = 1
    sr_granularity: int = 1  # sub-gathers per layer (1 = whole layer)
    # deterministic store: grads leave backward as reduce-scatter shards
    ds_enabled: bool = True
    staging_ring_slots: int = 8

    # --- training -------------------------------------------------------
    microbatches: int = 1  # gradient accumulation steps
    remat: bool = True
    remat_policy: str = "none"  # none (nothing saveable) | dots
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_compression: str = "none"  # none | int8_ef (cross-pod reductions)
    seed: int = 0

    # --- serving --------------------------------------------------------
    kv_page_size: int = 256
    decode_microbatch: int = 0  # 0 = whole batch
    kv_quant: str = "none"      # none | int8 (per-page scales; fp8 reserved)
                                # — see models/kv_quant.py

    # --- hillclimb knobs --------------------------------------------------
    seq_shard_attn: bool = False   # shard long-context KV over data axis
    fuse_qkv: bool = True          # single fused QKV projection matmul
    scan_unroll: int = 0           # 0 = auto; >0 forces layer-scan unroll
                                   # (cost extraction sets it = n_stacked)
    use_pallas: bool = False       # route attention through the Pallas
                                   # kernels (TPU fast path; interpret on
                                   # CPU — see kernels/)


# hardware constants for the roofline (TPU v5e target, per assignment)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~effective per chip here)
HBM_PER_CHIP = 16 * 1024**3   # v5e: 16 GiB
