"""xlstm-125m [ssm] — 12L d_model=768 4H, mLSTM blocks with sLSTM every 6th
layer, no separate FFN (d_ff=0), vocab=50304. [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50304,
    slstm_every=6, mlstm_expand=2, use_rope=False, tie_embeddings=True,
)
