"""musicgen-large [audio] — 48L d_model=2048 32H (MHA kv=32) d_ff=8192,
decoder-only over EnCodec tokens: 4 codebooks, vocab=2048 each, additive
sinusoidal positions. The EnCodec frontend is a STUB: input_specs() supplies
the token grid. [arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, n_codebooks=4,
    use_rope=False, activation="gelu",
)
