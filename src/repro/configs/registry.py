"""Architecture registry: --arch <id> -> ModelConfig, plus reduced smoke
configs (same family/topology, tiny dims) for CPU tests."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig

from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3_moe
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.qwen3_1_7b import CONFIG as _qwen3
from repro.configs.gemma_2b import CONFIG as _gemma
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.glm4_9b import CONFIG as _glm4
from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_vis
from repro.configs.musicgen_large import CONFIG as _musicgen

ARCHS: Dict[str, ModelConfig] = {c.arch_id: c for c in [
    _qwen3_moe, _granite, _zamba2, _qwen3, _gemma, _starcoder2, _glm4,
    _xlstm, _llama_vis, _musicgen]}


def get(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def smoke(arch_id: str) -> ModelConfig:
    """Reduced config of the same family: few layers, small width, few
    experts, tiny vocab — runs a real forward/train step on CPU."""
    cfg = get(arch_id)
    updates = dict(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=max(
            1, min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4),
        head_dim=16, d_ff=128 if cfg.d_ff else 0, vocab_size=256,
    )
    if cfg.family == "moe":
        updates.update(n_experts=8, top_k=2, d_ff=64)
    if cfg.family == "hybrid":
        updates.update(shared_block_period=2, ssm_state=16, ssm_head_dim=16,
                       n_layers=4, n_heads=4, n_kv_heads=4, head_dim=16)
    if cfg.family == "ssm":
        updates.update(slstm_every=2, n_layers=4, n_heads=4, n_kv_heads=4,
                       head_dim=16, d_ff=0)
    if cfg.family == "vlm":
        updates.update(cross_attn_period=2, n_vision_tokens=8, n_layers=4)
    if cfg.family == "audio":
        updates.update(n_codebooks=2, vocab_size=64)
    # MQA archs keep their kv=1 topology
    if cfg.n_kv_heads == 1:
        updates["n_kv_heads"] = 1
    return dataclasses.replace(cfg, **updates)
