"""zamba2-2.7b [hybrid] — 54L d_model=2560, Mamba2 backbone + ONE shared
attention block (32H kv=32, d_ff=10240) invoked every 6 mamba layers,
ssm_state=64, vocab=32000. [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    shared_block_period=6, activation="geglu",
)
