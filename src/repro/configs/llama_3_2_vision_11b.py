"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; every 5th layer is a cross-attention image layer. The vision
frontend is a STUB: input_specs() supplies precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    rope_theta=500000.0, activation="swiglu",
    cross_attn_period=5, n_vision_tokens=1601,
)
