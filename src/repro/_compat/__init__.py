"""Runtime compatibility layer (jax 0.4.x shims, hypothesis fallback)."""
from repro._compat import jax_compat

jax_compat.install()
