"""Seeded-random fallback for the ``hypothesis`` property-testing API.

The test suite uses a small slice of hypothesis (``given``, ``settings``
and five strategies).  When the real package is installed (CI does so via
``requirements-dev.txt``) it is always preferred; this fallback exists so
the suite still collects and runs in environments where it is absent —
each ``@given`` test then executes against ``max_examples`` deterministic
pseudo-random draws instead of hypothesis' guided search.

Activation lives in ``tests/conftest.py``::

    try:
        import hypothesis
    except ImportError:
        from repro._compat import hypothesis_fallback
        hypothesis_fallback.register()
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
from typing import Any, Callable, List, Sequence

_DEFAULT_MAX_EXAMPLES = 25
_SEED = 0xC71_6B0


class Strategy:
    """A value generator: ``draw(rng) -> example``."""

    def __init__(self, draw: Callable[[random.Random], Any], name: str):
        self._draw = draw
        self.name = name

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Strategy({self.name})"


def integers(min_value: int = -(1 << 16), max_value: int = 1 << 16,
             ) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value),
                    f"integers({min_value},{max_value})")


def floats(min_value: float = -1e6, max_value: float = 1e6,
           allow_nan: bool = False, allow_infinity: bool = False,
           ) -> Strategy:
    del allow_nan, allow_infinity  # bounded draws are always finite
    return Strategy(lambda rng: rng.uniform(min_value, max_value),
                    f"floats({min_value},{max_value})")


def lists(elements: Strategy, *, min_size: int = 0, max_size: int = 10,
          ) -> Strategy:
    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return Strategy(draw, f"lists({elements.name})")


def tuples(*parts: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(p.draw(rng) for p in parts),
                    "tuples(%s)" % ",".join(p.name for p in parts))


def sampled_from(options: Sequence[Any]) -> Strategy:
    opts = list(options)
    return Strategy(lambda rng: rng.choice(opts), f"sampled_from[{len(opts)}]")


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5, "booleans")


def one_of(*strategies: Strategy) -> Strategy:
    # real hypothesis accepts one_of(a, b) and one_of([a, b])
    if len(strategies) == 1 and isinstance(strategies[0], (list, tuple)):
        strategies = tuple(strategies[0])
    opts = list(strategies)
    return Strategy(lambda rng: rng.choice(opts).draw(rng),
                    "one_of(%s)" % ",".join(s.name for s in opts))


def just(value: Any) -> Strategy:
    return Strategy(lambda rng: value, f"just({value!r})")


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    """Run the wrapped test once per generated example (seeded, so runs
    are reproducible; the failing example's values appear in the
    AssertionError chain via the re-raise note)."""

    def decorate(fn: Callable) -> Callable:
        sig = inspect.signature(fn)
        params = list(sig.parameters)
        # hypothesis maps positional strategies to the RIGHTMOST params;
        # anything left over (e.g. pytest fixtures) stays in the wrapper's
        # visible signature so pytest still injects it.
        pos_names = params[len(params) - len(arg_strategies):] \
            if arg_strategies else []
        by_name = dict(zip(pos_names, arg_strategies), **kw_strategies)

        @functools.wraps(fn)
        def wrapper(**kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED + hash(fn.__qualname__) % (1 << 20))
            for i in range(n):
                drawn = {k: s.draw(rng) for k, s in by_name.items()}
                try:
                    fn(**kwargs, **drawn)
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}") from exc

        wrapper._is_fallback_given = True
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in by_name])
        # pytest must not unwrap to the original signature
        wrapper.__dict__.pop("__wrapped__", None)
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Record max_examples on the (possibly not-yet-wrapped) test."""

    def decorate(fn: Callable) -> Callable:
        fn._max_examples = max_examples
        return fn

    return decorate


def register() -> None:
    """Install this module as ``hypothesis`` + ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:  # real package (or already registered)
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = lambda cond: bool(cond)
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "tuples", "sampled_from",
                 "booleans", "one_of", "just"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
