"""Compatibility shims for jax < 0.5.

The codebase targets the jax >= 0.5 sharding surface (``jax.set_mesh``,
``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.sharding.
get_abstract_mesh`` and ``jax.make_mesh(..., axis_types=...)``).  On
jax 0.4.x those names either do not exist or have a narrower signature;
``install()`` patches equivalents onto the jax namespace so the rest of
the code (and the tests) can use one API everywhere.

All shims are no-ops when the running jax already provides the name, so
this module is safe to import under any jax version.
"""
from __future__ import annotations

import contextlib
import enum
import functools

import jax


class _AxisType(enum.Enum):
    """Stand-in for jax.sharding.AxisType (jax >= 0.5).

    jax 0.4.x has no axis-type concept — every mesh axis behaves like
    ``Auto`` — so the values only need to exist for call sites that pass
    ``axis_types=(AxisType.Auto,) * n``.
    """

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _physical_mesh():
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


def _get_abstract_mesh():
    return _physical_mesh().abstract_mesh


@contextlib.contextmanager
def _set_mesh(mesh):
    # On 0.4.x entering the Mesh context is the equivalent of set_mesh
    # with Auto axes: shard_map and get_abstract_mesh pick it up.
    with mesh:
        yield mesh


def _make_mesh_compat(axis_shapes, axis_names, *, axis_types=None,
                      devices=None, **kw):
    del axis_types  # implicit on 0.4.x
    return _real_make_mesh(axis_shapes, axis_names, devices=devices, **kw)


def _shard_map_compat(f=None, *, mesh=None, in_specs=None, out_specs=None,
                      **kw):
    from jax.experimental.shard_map import shard_map as _sm

    if f is None:  # used as decorator factory
        return functools.partial(_shard_map_compat, mesh=mesh,
                                 in_specs=in_specs, out_specs=out_specs,
                                 **kw)
    if mesh is None:
        mesh = _physical_mesh()
        if mesh.empty:
            raise ValueError(
                "jax.shard_map shim: no mesh argument and no active mesh "
                "context (enter one with jax.set_mesh(mesh))")
    # 0.4.x rejects some collective layouts under replication checking
    # that 0.5+ accepts; match the newer, laxer behaviour.
    kw.setdefault("check_rep", False)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


_real_make_mesh = jax.make_mesh


def _version_tuple() -> tuple:
    try:
        return tuple(int(p) for p in jax.__version__.split(".")[:2])
    except ValueError:  # pragma: no cover - dev builds
        return (0, 0)


# jax 0.4.x GSPMD miscompiles concatenate when the operands are sharded
# along the concatenated axis (it stitches the LOCAL shards and labels the
# result with the global sharding — wrong values, silently). The fused-QKV
# projection concatenates model-sharded weight matrices, so that fusion
# must fall back to unfused matmuls on 0.4.x.
SHARDED_CONCAT_SAFE = _version_tuple() >= (0, 5)


def install() -> None:
    """Idempotently add the jax >= 0.5 sharding surface to jax 0.4.x."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = _get_abstract_mesh
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _set_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_compat
    try:
        import inspect

        if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
            jax.make_mesh = _make_mesh_compat
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        pass


install()
