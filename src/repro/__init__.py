"""repro: CXL-GPU reproduction package.

Importing the package installs the jax < 0.5 compatibility shims so every
entry point (tests, benchmarks, examples, launch scripts) sees the same
jax sharding surface regardless of the installed jax version.
"""
from repro import _compat  # noqa: F401  (side effect: install shims)
