"""Jitted wrapper for the paged weight-streaming matmul."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.hdm_stream.kernel import paged_matmul


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def stream_matmul(x, w_pages, page_ids, *, block_m: int = 256,
                  block_n: int = 256):
    """y = x @ vstack(w_pages[page_ids]). See kernel.py."""
    return paged_matmul(x, w_pages, page_ids, block_m=block_m,
                        block_n=block_n, interpret=_interpret())
