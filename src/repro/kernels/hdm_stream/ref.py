"""Pure-jnp oracle for the paged streaming matmul."""
from __future__ import annotations

import jax.numpy as jnp


def paged_matmul_ref(x, w_pages, page_ids):
    """x: [M, K]; w_pages: [n_pages, page_k, N]; page_ids: [K // page_k]."""
    n_pages, page_k, n = w_pages.shape
    w = w_pages[jnp.asarray(page_ids)].reshape(-1, n)   # [K, N]
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)
