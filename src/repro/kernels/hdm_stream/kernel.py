"""Pallas TPU paged weight-streaming matmul — speculative read in a kernel.

The HDM tier holds weights as *pages* in HBM (the EP's backend); a logical
weight matrix is assembled from a page table. The page ids ride in
scalar-prefetch memory, so the BlockSpec index map resolves the next
page's address BEFORE its DMA is issued — the kernel-level MemSpecRd: the
address is pre-shared, and Mosaic's automatic double buffering overlaps
the page fetch (HBM -> VMEM) with the MXU work on the current page,
exactly the compute-shadow overlap of the paper's SR.

y[m, n] = sum_k x[m, k_tile(k)] @ W_pages[page_ids[k]][n_tile]

Grid: (M_blocks, N_blocks, K_pages) with K innermost; accumulation in a
VMEM scratch tile, one output write on the last K page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stream_kernel(pid_ref, x_ref, w_ref, y_ref, acc_ref, *, n_k: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kj == n_k - 1)
    def _finish():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def paged_matmul(x: jnp.ndarray, w_pages: jnp.ndarray,
                 page_ids: jnp.ndarray, *, block_m: int = 256,
                 block_n: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: [M, K]; w_pages: [n_pages, page_k, N]; page_ids: [K // page_k].

    The logical weight is vstack(w_pages[page_ids]); pages may live
    anywhere in the pool (the HDM map). Returns y [M, N] = x @ W.
    """
    m, k = x.shape
    n_pages, page_k, n = w_pages.shape
    n_k = k // page_k
    assert page_ids.shape == (n_k,)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0

    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_stream_kernel, n_k=n_k)

    # x tiles follow the LOGICAL k index; w pages are looked up through
    # the prefetched page table (the pre-shared address)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, page_k),
                             lambda mi, ni, kj, pid: (mi, kj)),
                pl.BlockSpec((1, page_k, block_n),
                             lambda mi, ni, kj, pid: (pid[kj], 0, ni)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda mi, ni, kj, pid: (mi, ni)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(jnp.asarray(page_ids, jnp.int32), x, w_pages)
