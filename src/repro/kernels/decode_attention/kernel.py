"""Pallas TPU paged flash-decode — single-token attention over KV pages.

This is the per-device kernel behind the distributed paged decode
(models.attention.paged_decode_attention): each device holds a page-
sharded slice of the KV cache (its "endpoint" in the paper's terms) and
scans its local pages with an online softmax; the cross-device combine is
a tiny psum outside the kernel.

Grid: (batch, kv_head, pages) with the page axis innermost; accumulator
state in VMEM scratch; `kv_len` rides in scalar-prefetch memory — the
address pre-share of the paper's MemSpecRd: the page index map can consult
it before the DMA is issued, so out-of-range pages are never fetched
(their iterations clamp to page 0 and the body is skipped).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, *refs, page: int,
                   n_pages: int, scale: float, logit_softcap: float,
                   quant: bool = False):
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        o_ref, acc_ref, m_ref, l_ref = refs
    pj = pl.program_id(2)

    @pl.when(pj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]
    run = pj * page < kv_len

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                    # [G, D]
        k = k_ref[0, 0, 0]                 # [page, D]
        v = v_ref[0, 0, 0]                 # [page, D]
        if quant:
            # int8 pages: dequantize in-kernel with this page's fp32
            # scale (scalar per (b, hkv, page)); math stays f32
            k = k.astype(jnp.float32) * ks_ref[0, 0, 0]
            v = v.astype(jnp.float32) * vs_ref[0, 0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G, page]
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        pos = pj * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(pj == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_flash_decode(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, kv_len: jnp.ndarray, *,
                       logit_softcap: float = 0.0,
                       interpret: bool = False,
                       k_scale: jnp.ndarray | None = None,
                       v_scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """q: [B, Hkv, G, D]; pages: [B, Hkv, P, page, D]; kv_len scalar int32.

    Quantized cache: pass int8 pages plus fp32 ``k_scale``/``v_scale``
    [B, Hkv, P] (one symmetric scale per page per head); the kernel
    dequantizes each page block in VMEM right after the DMA, so only the
    int8 bytes cross the memory tiers. Returns [B, Hkv, G, D] (f32
    accumulation, q dtype out).
    """
    b, hkv, g, d = q.shape
    n_pages, page = k_pages.shape[2], k_pages.shape[3]
    scale = 1.0 / (d ** 0.5)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)
    quant = k_scale is not None

    grid = (b, hkv, n_pages)
    kernel = functools.partial(
        _decode_kernel, page=page, n_pages=n_pages, scale=scale,
        logit_softcap=logit_softcap, quant=quant)

    # pages already read are never refetched; the index map clamps
    # out-of-range pages to 0 (their body is skipped via kv_len)
    def page_map(bi, hi, pj, len_ref):
        return (bi, hi, pj, 0, 0)

    def scale_map(bi, hi, pj, len_ref):
        return (bi, hi, pj)

    in_specs = [
        pl.BlockSpec((1, 1, g, d),
                     lambda bi, hi, pj, len_ref: (bi, hi, 0, 0)),
        pl.BlockSpec((1, 1, 1, page, d), page_map),
        pl.BlockSpec((1, 1, 1, page, d), page_map),
    ]
    operands = [kv_len, q, k_pages, v_pages]
    if quant:
        in_specs += [pl.BlockSpec((1, 1, 1), scale_map),
                     pl.BlockSpec((1, 1, 1), scale_map)]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, 1, g, d), lambda bi, hi, pj, len_ref: (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(*operands)
