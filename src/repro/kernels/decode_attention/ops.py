"""Jitted wrapper for the paged flash-decode kernel (model layout)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import paged_flash_decode


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("logit_softcap",))
def decode(q, k_pages, v_pages, kv_len, *, logit_softcap: float = 0.0):
    """q: [B, 1, H, D]; pages: [B, P, page, Hkv, D]; kv_len scalar.

    Returns [B, 1, H, D] — the local-shard result (combine across page
    shards outside).
    """
    b, _, h, d = q.shape
    p, page, hkv = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    g = h // hkv
    qk = q.reshape(b, hkv, g, d)
    kp = jnp.moveaxis(k_pages, 3, 1)          # [B, Hkv, P, page, D]
    vp = jnp.moveaxis(v_pages, 3, 1)
    o = paged_flash_decode(qk, kp, vp, kv_len, logit_softcap=logit_softcap,
                           interpret=_interpret())
    return o.reshape(b, 1, h, d)
