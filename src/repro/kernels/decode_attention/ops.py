"""Jitted wrapper for the paged flash-decode kernel (model layout)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import paged_flash_decode


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("logit_softcap",))
def decode(q, k_pages, v_pages, kv_len, *, logit_softcap: float = 0.0,
           k_scale=None, v_scale=None):
    """q: [B, 1, H, D]; pages: [B, P, page, Hkv, D]; kv_len scalar.

    int8 pages take fp32 ``k_scale``/``v_scale`` [B, P, Hkv] (per-page,
    per-head symmetric scales — models/kv_quant.py layout); the kernel
    dequantizes in-VMEM. Returns [B, 1, H, D] — the local-shard result
    (combine across page shards outside).
    """
    b, _, h, d = q.shape
    p, page, hkv = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    g = h // hkv
    qk = q.reshape(b, hkv, g, d)
    kp = jnp.moveaxis(k_pages, 3, 1)          # [B, Hkv, P, page, D]
    vp = jnp.moveaxis(v_pages, 3, 1)
    ks = None if k_scale is None else jnp.moveaxis(k_scale, 2, 1)
    vs = None if v_scale is None else jnp.moveaxis(v_scale, 2, 1)
    o = paged_flash_decode(qk, kp, vp, kv_len, logit_softcap=logit_softcap,
                           interpret=_interpret(), k_scale=ks, v_scale=vs)
    return o.reshape(b, 1, h, d)
