"""Pure-jnp oracle for the paged flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_flash_decode_ref(q, k_pages, v_pages, kv_len):
    """q: [B, Hkv, G, D]; pages: [B, Hkv, P, page, D] -> [B, Hkv, G, D]."""
    b, hkv, g, d = q.shape
    p, page = k_pages.shape[2], k_pages.shape[3]
    k = k_pages.reshape(b, hkv, p * page, d).astype(jnp.float32)
    v = v_pages.reshape(b, hkv, p * page, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32), k) / (d ** 0.5)
    pos = jnp.arange(p * page)
    s = jnp.where(pos[None, None, None] < kv_len, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgk,bhkd->bhgd", w, v).astype(q.dtype)
