"""Pure-jnp oracle for the paged flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_flash_decode_ref(q, k_pages, v_pages, kv_len):
    """q: [B, Hkv, G, D]; pages: [B, Hkv, P, page, D] -> [B, Hkv, G, D]."""
    b, hkv, g, d = q.shape
    p, page = k_pages.shape[2], k_pages.shape[3]
    k = k_pages.reshape(b, hkv, p * page, d).astype(jnp.float32)
    v = v_pages.reshape(b, hkv, p * page, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32), k) / (d ** 0.5)
    pos = jnp.arange(p * page)
    s = jnp.where(pos[None, None, None] < kv_len, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgk,bhkd->bhgd", w, v).astype(q.dtype)


def paged_flash_decode_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                 kv_len):
    """Dequantize-then-ref oracle for the int8 kernel path.

    int8 pages [B, Hkv, P, page, D] + fp32 scales [B, Hkv, P]; the oracle
    dequantizes in fp32 and runs the exact-softmax reference, so any
    kernel/oracle mismatch is a kernel bug, not a quantization artifact.
    """
    k = k_pages.astype(jnp.float32) * k_scale.astype(
        jnp.float32)[..., None, None]
    v = v_pages.astype(jnp.float32) * v_scale.astype(
        jnp.float32)[..., None, None]
    return paged_flash_decode_ref(q, k, v, kv_len)
