"""Jitted wrapper: mamba2 model layout -> SSD kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_scan.kernel import ssd_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(xdt, bmat, cmat, log_a, *, chunk: int = 256):
    """Model layout: xdt [B,S,H,P]; b/c [B,S,N]; log_a [B,S,H].

    Returns y [B,S,H,P] (f32).
    """
    b, s, h, p = xdt.shape
    n = bmat.shape[2]
    chunk = min(chunk, s)
    assert s % chunk == 0
    c = s // chunk
    xk = jnp.moveaxis(xdt.reshape(b, c, chunk, h, p), 3, 1)   # [B,H,C,Q,P]
    bk = bmat.reshape(b, c, chunk, n).astype(jnp.float32)
    ck = cmat.reshape(b, c, chunk, n).astype(jnp.float32)
    la = jnp.cumsum(log_a.reshape(b, c, chunk, h), axis=2)
    la = jnp.moveaxis(la, 3, 1)                               # [B,H,C,Q]
    y = ssd_scan(xk.astype(jnp.float32), bk, ck, la,
                 interpret=_interpret())
    return jnp.moveaxis(y, 1, 3).reshape(b, s, h, p)
