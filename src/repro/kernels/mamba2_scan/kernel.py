"""Pallas TPU chunked SSD (Mamba2) scan.

Grid: (batch, heads, chunks) with the chunk axis innermost; the SSM state
h [P, N] persists in VMEM scratch across chunk iterations (the recurrent
carry), while each chunk's intra contribution is a masked quadratic on the
MXU — the same decomposition as the jnp path in repro.models.mamba2.

Inputs are pre-chunked by ops.py:
  xdt [B, H, C, Q, P]   (x * dt, f32)
  bc  [B, C, Q, N]      B matrix (shared across heads)
  cc  [B, C, Q, N]      C matrix
  la  [B, H, C, Q]      cumsum(log a) within chunk
Output: y [B, H, C, Q, P].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _ssd_kernel(xdt_ref, b_ref, c_ref, la_ref, y_ref, h_ref, *,
                chunk: int):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xq = xdt_ref[0, 0, 0]          # [Q, P]
    bq = b_ref[0, 0]               # [Q, N]
    cq = c_ref[0, 0]               # [Q, N]
    laq = la_ref[0, 0, 0]          # [Q]
    h = h_ref[...]                 # [P, N]

    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = idx >= jdx

    # intra-chunk: (C B^T) ⊙ decay, masked causal, times xdt
    g = jax.lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    logdec = laq[:, None] - laq[None, :]
    dec = jnp.where(causal, jnp.exp(logdec), 0.0)
    y = jax.lax.dot_general(g * dec, xq, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, P]

    # inter-chunk: incoming state decayed to each position
    ch = jax.lax.dot_general(cq, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, P]
    y = y + ch * jnp.exp(laq)[:, None]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update to the chunk end
    la_last = laq[chunk - 1]
    w = jnp.exp(la_last - laq)                                   # [Q]
    h_new = jnp.exp(la_last) * h + jax.lax.dot_general(
        xq * w[:, None], bq, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                      # [P, N]
    h_ref[...] = h_new


def ssd_scan(xdt: jnp.ndarray, bc: jnp.ndarray, cc: jnp.ndarray,
             la: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """See module docstring for layouts. Returns y [B, H, C, Q, P]."""
    b, h, c, q, p = xdt.shape
    n = bc.shape[3]
    grid = (b, h, c)
    kernel = functools.partial(_ssd_kernel, chunk=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p),
                         lambda bi, hi, cj: (bi, hi, cj, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, hi, cj: (bi, cj, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, hi, cj: (bi, cj, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, hi, cj: (bi, hi, cj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q, p),
                               lambda bi, hi, cj: (bi, hi, cj, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, c, q, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xdt, bc, cc, la)
