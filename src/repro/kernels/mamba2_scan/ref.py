"""Pure-jnp oracle for the SSD scan kernel: sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(xdt, bc, cc, la):
    """Sequential state-space recurrence (time-step oracle).

    xdt [B,H,C,Q,P]; bc/cc [B,C,Q,N]; la [B,H,C,Q] (within-chunk cumsum of
    log a). Returns y [B,H,C,Q,P].
    """
    b, h, c, q, p = xdt.shape
    n = bc.shape[3]
    # undo the chunk cumsum into per-step log a
    la_flat = la.reshape(b, h, c * q)
    prev = jnp.concatenate(
        [jnp.zeros((b, h, c, 1)), la[..., :-1]], axis=-1).reshape(b, h,
                                                                  c * q)
    step_log_a = (la_flat - prev)                       # [B,H,T]
    x = xdt.reshape(b, h, c * q, p)
    bm = bc.reshape(b, c * q, n)
    cm = cc.reshape(b, c * q, n)

    def step(hstate, inp):
        xt, bt, ct, lat = inp
        hstate = hstate * jnp.exp(lat)[..., None, None] \
            + jnp.einsum("bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", hstate, ct)
        return hstate, y

    h0 = jnp.zeros((b, h, p, n))
    _, ys = jax.lax.scan(
        step, h0, (jnp.moveaxis(x, 2, 0), jnp.moveaxis(bm, 1, 0),
                   jnp.moveaxis(cm, 1, 0), jnp.moveaxis(step_log_a, 2, 0)))
    return jnp.moveaxis(ys, 0, 2).reshape(b, h, c, q, p)
