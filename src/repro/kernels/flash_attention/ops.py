"""Jitted public wrapper: model layout <-> kernel layout adaptation.

On non-TPU backends the kernel body runs under ``interpret=True`` so the
same code path is validated everywhere; the TPU target compiles the Mosaic
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "q_block",
                                             "kv_block", "logit_softcap"))
def attention(q, k, v, *, causal: bool = True, q_block: int = 256,
              kv_block: int = 256, logit_softcap: float = 0.0):
    """Model-layout entry point. q: [B, S, H, D]; k/v: [B, S, Hkv, D]."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qk = jnp.moveaxis(q.reshape(b, s, hkv, g, d), 1, 3)   # [B,Hkv,G,S,D]
    kk = jnp.moveaxis(k, 1, 2)                            # [B,Hkv,S,D]
    vk = jnp.moveaxis(v, 1, 2)
    o = flash_attention(qk, kk, vk, causal=causal, q_block=q_block,
                        kv_block=kv_block, logit_softcap=logit_softcap,
                        interpret=_interpret())
    return jnp.moveaxis(o, 3, 1).reshape(b, s, h, d)
