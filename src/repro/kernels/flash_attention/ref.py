"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        logit_softcap: float = 0.0):
    """q: [B, Hkv, G, S, D]; k/v: [B, Hkv, S, D] -> [B, Hkv, G, S, D]."""
    b, hkv, g, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[2]), bool))
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
