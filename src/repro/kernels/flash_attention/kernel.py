"""Pallas TPU flash attention (blockwise causal GQA) — prefill/train path.

Grid: (batch, kv_head, q_blocks, kv_blocks); the kv_blocks axis is
innermost so the online-softmax state lives in VMEM scratch across
iterations and the output block is written once, on the last visited kv
block. Causal blocks above the diagonal are skipped with `pl.when`
(their iterations are no-ops, which XLA's Mosaic pipeline elides).

Block shapes keep the MXU happy: the (q_block, head_dim) operand tiles are
multiples of (8, 128) for f32/bf16, and the GQA group dimension rides in
the sublane axis with the q block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 causal: bool, q_block: int, kv_block: int, n_kv: int,
                 scale: float, logit_softcap: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: the block is skipped entirely when its kv range is wholly
    # above the diagonal of the q range
    run = (not causal) or (kj * kv_block <= qi * q_block + q_block - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0]                   # [G, Bq, D]
        k = k_ref[0, 0]                   # [Bk, D]
        v = v_ref[0, 0]                   # [Bk, D]
        g, bq, d = q.shape
        s = jax.lax.dot_general(
            q.reshape(g * bq, d), k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G*Bq, Bk]
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        if causal:
            q_pos = qi * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (g, bq, k.shape[0]), 1).reshape(g * bq, -1)
            kv_pos = kj * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (g * bq, k.shape[0]), 1)
            s = jnp.where(q_pos >= kv_pos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        g, bq, d = q_ref.shape[2], q_ref.shape[3], q_ref.shape[4]
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.reshape(g, bq, d).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, q_block: int = 256,
                    kv_block: int = 256, logit_softcap: float = 0.0,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hkv, G, S, D]; k/v: [B, Hkv, S, D] -> [B, Hkv, G, S, D]."""
    b, hkv, g, s, d = q.shape
    skv = k.shape[2]
    q_block = min(q_block, s)
    kv_block = min(kv_block, skv)
    assert s % q_block == 0 and skv % kv_block == 0
    nq, nkv = s // q_block, skv // kv_block
    scale = 1.0 / (d ** 0.5)

    grid = (b, hkv, nq, nkv)
    kernel = functools.partial(
        _attn_kernel, causal=causal, q_block=q_block, kv_block=kv_block,
        n_kv=nkv, scale=scale, logit_softcap=logit_softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, q_block, d),
                         lambda bi, hi, qi, kj: (bi, hi, 0, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda bi, hi, qi, kj: (bi, hi, kj, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda bi, hi, qi, kj: (bi, hi, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, q_block, d),
                               lambda bi, hi, qi, kj: (bi, hi, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * q_block, d), jnp.float32),
            pltpu.VMEM((g * q_block, 1), jnp.float32),
            pltpu.VMEM((g * q_block, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(b, hkv, g, s, d), k, v)
