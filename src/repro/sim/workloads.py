"""Table-1b workload traces for the simulator.

Each workload is characterized by its (compute_ratio, load_ratio) from the
paper's Table 1b plus an address-pattern class:

  Seq    — monotonically advancing addresses (1D vector / 2D row-major
           kernels: vadd, saxpy, gemm, conv3, rsum, stencil);
  Around — spatially local but direction-changing (binary-tree sort,
           Gaussian elimination backsubstitution);
  Rand   — pointer-chasing over the working set (path, bfs).

Real-world workloads are composites, exactly as the paper builds them:
gnn = bfs + vadd + gemm, mri = sort + conv3.

A trace is a numpy record array of ops: kind (0 compute, 1 load, 2 store)
and byte address. Input sizes follow the paper's setup: the working set is
10x the GPU's local memory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

MEM_REQ = 64  # CXL.mem granule


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One Table 1b workload: instruction-mix ratios (fractions of the
    trace) and its dominant access pattern class."""

    name: str
    category: str        # compute | load | store | real
    compute_ratio: float
    load_ratio: float
    pattern: str         # Seq | Around | Rand | composite
    parts: Tuple[str, ...] = ()


TABLE_1B: Dict[str, WorkloadSpec] = {s.name: s for s in [
    WorkloadSpec("rsum",    "compute", .314, .533, "SeqL"),
    WorkloadSpec("stencil", "compute", .375, .725, "SeqL"),
    WorkloadSpec("sort",    "compute", .381, .987, "Around"),
    WorkloadSpec("gemm",    "load",    .116, .999, "Seq"),
    WorkloadSpec("vadd",    "load",    .156, .691, "Seq"),
    WorkloadSpec("saxpy",   "load",    .162, .692, "Seq"),
    WorkloadSpec("conv3",   "load",    .218, .786, "Seq"),
    WorkloadSpec("path",    "load",    .270, .927, "Rand"),
    WorkloadSpec("cfd",     "store",   .209, .426, "Seq"),
    WorkloadSpec("gauss",   "store",   .235, .485, "Around"),
    WorkloadSpec("bfs",     "store",   .293, .432, "Rand"),
    WorkloadSpec("gnn",     "real",    .274, .738, "composite",
                 ("bfs", "vadd", "gemm")),
    WorkloadSpec("mri",     "real",    .292, .533, "composite",
                 ("sort", "conv3")),
]}

CATEGORY = {n: s.category for n, s in TABLE_1B.items()}
ORDER = list(TABLE_1B)  # paper order (ascending memory-access ratio)


def _pattern_addresses(pattern: str, n: int, working_set: int,
                       rng: np.random.Generator) -> np.ndarray:
    ws_blocks = working_set // MEM_REQ
    if pattern == "Seq":
        # several parallel sequential streams (vector operands)
        n_streams = 3
        base = (rng.integers(0, ws_blocks, n_streams)
                * np.ones((n // n_streams + 1, n_streams), np.int64))
        step = np.arange(n // n_streams + 1)[:, None]
        addr = ((base + step) % ws_blocks).reshape(-1)[:n]
        return addr * MEM_REQ
    if pattern == "SeqL":
        # sequential with window reuse (stencil neighbourhoods, rolling
        # reductions): the LLC absorbs most accesses
        front = np.arange(n) // 6
        jitter = rng.integers(-2, 3, n)
        return ((front + jitter) % ws_blocks) * MEM_REQ
    if pattern == "Around":
        # local walk that reverses direction (sort/gauss): next access is
        # +/- a small stride around a slowly advancing front
        front = np.cumsum(rng.integers(0, 2, n)) % ws_blocks
        jitter = rng.integers(-8, 9, n)
        return ((front + jitter) % ws_blocks) * MEM_REQ
    if pattern == "Rand":
        # graph traversal: hot structures (frontier, offsets, visited) are
        # re-touched constantly; neighbour expansions hit cold pages
        hot_blocks = max(ws_blocks // 32, 1)
        hot = rng.integers(0, hot_blocks, n)
        cold = rng.integers(0, ws_blocks, n)
        pick_cold = rng.random(n) < 0.05
        return np.where(pick_cold, cold, hot) * MEM_REQ
    raise ValueError(pattern)


def generate(name: str, n_ops: int = 60_000,
             working_set: int = 640 << 20, seed: int = 0) -> np.ndarray:
    """Build the op trace: structured array (kind: u1, addr: i8)."""
    spec = TABLE_1B[name]
    rng = np.random.default_rng(seed + hash(name) % (1 << 16))
    if spec.pattern == "composite":
        parts = [generate(p, n_ops // len(spec.parts), working_set,
                          seed + 1) for p in spec.parts]
        out = np.concatenate(parts)
        # the paper characterizes the WHOLE application (Table 1b): keep
        # the parts' address locality, resample op kinds to the app's
        # measured compute/load ratios
        n = len(out)
        out["kind"] = np.where(
            rng.random(n) < spec.compute_ratio, 0,
            np.where(rng.random(n) < spec.load_ratio, 1, 2)
        ).astype(np.uint8)
        return out

    kind = np.where(
        rng.random(n_ops) < spec.compute_ratio, 0,
        np.where(rng.random(n_ops) < spec.load_ratio, 1, 2)).astype(np.uint8)
    addr = _pattern_addresses(spec.pattern, n_ops, working_set, rng)
    out = np.zeros(n_ops, dtype=[("kind", "u1"), ("addr", "i8")])
    out["kind"] = kind
    out["addr"] = addr
    return out


def pattern_class(name: str) -> str:
    """Access-pattern class of a workload ("Seq"/"Around"/... or
    "mixed" for composites)."""
    p = TABLE_1B[name].pattern
    if p == "composite":
        return "mixed"
    return p


# --------------------------------------------------------------------- cache
# Traces are deterministic in (name, n_ops, working_set, seed); a sweep
# replays the same trace against many config x media scenarios, so both
# engines share one generation per key. Treat cached traces as read-only.

_TRACE_CACHE: Dict[Tuple[str, int, int, int], np.ndarray] = {}
_TRACE_CACHE_MAX = 64


def generate_cached(name: str, n_ops: int = 60_000,
                    working_set: int = 640 << 20,
                    seed: int = 0) -> np.ndarray:
    """Memoized :func:`generate`: one trace per key, shared across the
    sweep's engines/configs. Returned arrays are read-only by contract."""
    key = (name, n_ops, working_set, seed)
    tr = _TRACE_CACHE.get(key)
    if tr is None:
        if len(_TRACE_CACHE) >= _TRACE_CACHE_MAX:
            _TRACE_CACHE.pop(next(iter(_TRACE_CACHE)))
        tr = _TRACE_CACHE[key] = generate(name, n_ops, working_set, seed)
    return tr
