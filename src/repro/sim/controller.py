"""Root-port queue logic — the paper's CXL controller, cycle-approximate.

Implements (OPTIMIZATION OF CXL CONTROLLER FOR GPUs):
  * SR queue + memory queue (32 entries each) under the root port;
  * MemSpecRd aggregation: 2 repurposed LSBs encode 1-4 x 256B, so one SR
    covers 256B..1KB (granularity from the DevLoad ladder);
  * ring buffer of issued SRs — a request matching a previously issued SR
    is forwarded as a standard memory read (no duplicate SR);
  * DevLoad-driven load control (ll/ol/mo/so -> grow/hold/shrink/halt) via
    the shared ``repro.core.qos.QoSController`` (the same state machine the
    JAX runtime uses);
  * address-window control (Fig. 7) via ``repro.core.qos.address_window``;
  * deterministic store (Fig. 8): fire-and-forget dual write, stack-
    organized staging in reserved GPU memory with an SRAM-resident index,
    divert-on-congestion, background flush, read-through.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.qos import (DevLoad, QoSController, SR_GRANULARITIES,
                            address_window, MEM_REQ_BYTES, SR_OFFSET_UNIT)
from repro.sim.media import Endpoint

CXL_RTT_NS = 80.0          # silicon controller round trip (two-digit ns)
GPU_MEM_NS = 120.0         # local GPU memory access
QUEUE_DEPTH = 32
TXN_SLOTS = 32             # outstanding CXL.mem transactions per root port
#   Demand reads occupy a transaction slot until the response returns, so
#   under a slow EP they QUEUE at the root port. MemSpecRd flits are
#   fire-and-forget and bypass the wait — that head start is precisely the
#   paper's speculative-read lead ("SR requests for requests waiting in
#   the GPU's memory queue").


@dataclasses.dataclass
class SRStats:
    """Speculative-read engine counters (windows issued / deduped /
    halted by QoS, and total MemSpecRd bytes requested)."""

    issued: int = 0
    deduped: int = 0
    halted: int = 0
    bytes: int = 0


class RootPortController:
    """One root port + CXL controller in front of one EP."""

    def __init__(self, ep: Endpoint, *, sr_mode: str = "off",
                 ds_enabled: bool = False,
                 staging_capacity: int = 16384):
        assert sr_mode in ("off", "naive", "dyn", "sr")
        self.ep = ep
        self.sr_mode = sr_mode
        self.ds_enabled = ds_enabled
        self.qos = QoSController()
        self.memory_queue: Deque[int] = deque(maxlen=QUEUE_DEPTH)
        self.sr_queue: Deque[int] = deque(maxlen=QUEUE_DEPTH)
        # ring buffer of issued SR windows (start, end), newest last.
        # _cov is its inverted index: covered unit -> number of live ring
        # windows containing it, so coverage tests are O(1) instead of an
        # O(ring) interval scan (the simulator's hottest path). NAIVE
        # windows are single 64B requests; DYN/SR windows are always
        # SR_OFFSET_UNIT-aligned multiples of it, so the unit size is
        # per-mode and membership stays exactly "any(s <= a0 < e)".
        self.sr_ring: Deque[Tuple[int, int]] = deque(maxlen=64)
        self._cov: Dict[int, int] = {}
        self._cov_shift = 6 if sr_mode == "naive" else 8
        self.sr_stats = SRStats()
        # DS staging: stack + address index (the paper keeps the index in
        # the system bus SRAM as a red-black tree; a dict is our stand-in)
        self.staging: List[int] = []
        self.staging_index: Dict[int, int] = {}
        self.staging_capacity = staging_capacity
        self.txn: List[float] = [0.0] * TXN_SLOTS   # slot-free times (heap)
        self._last_addr: Optional[int] = None
        self._dir_ewma = 0.0        # smoothed access direction (Fig. 7)
        self.ds_stats = {"fire_and_forget": 0, "diverted": 0, "flushed": 0,
                         "read_through": 0, "blocked": 0}

    def _acquire_txn(self, now: float) -> float:
        """Wait for a transaction slot; returns the request's EP arrival."""
        free = heapq.heappop(self.txn)
        return max(now, free) + CXL_RTT_NS / 2

    def _release_txn(self, done: float) -> None:
        heapq.heappush(self.txn, done)

    # ---------------------------------------------------------------- SR
    def _covered(self, addr: int) -> bool:
        # ring windows are unions of whole units (64B in naive mode, 256B
        # otherwise), so unit membership in the inverted index is exactly
        # "any(s <= a0 < e)" over the ring
        return addr >> self._cov_shift in self._cov

    def _ring_append(self, start: int, end: int) -> None:
        ring, cov, sh = self.sr_ring, self._cov, self._cov_shift
        if len(ring) == ring.maxlen:            # evict oldest window
            s0, e0 = ring.popleft()
            for u in range(s0 >> sh, e0 >> sh):
                n = cov[u] - 1
                if n:
                    cov[u] = n
                else:
                    del cov[u]
        ring.append((start, end))
        for u in range(start >> sh, end >> sh):
            cov[u] = cov.get(u, 0) + 1

    def _first_uncovered(self, addr: int, limit: int = 16) -> int:
        a = addr - addr % SR_OFFSET_UNIT
        for _ in range(limit):
            if not self._covered(a):
                return a
            a += SR_OFFSET_UNIT
        return a

    def on_load_issue(self, now: float, addr: int) -> None:
        """Queue-side SR generation at load-issue time.

        CXL-DYN sizes the window by DevLoad but keeps "the starting
        address of the original memory request" (forward, run-ahead from
        the first uncovered offset unit). CXL-SR additionally decides
        "whether to send MemSpecRd requests for addresses before or after
        the current one" from the queue-derived window (Fig. 7) — here
        realized with the recent-request direction as the queue signal."""
        if self.sr_mode == "off" or self.ep.is_dram:
            return
        last = self._last_addr
        self._last_addr = addr
        if self.qos.sr_halted and self.sr_mode in ("dyn", "sr"):
            self.sr_stats.halted += 1
            return
        g = self.qos.granularity
        if self.sr_mode == "naive":
            if self._covered(addr):
                self.sr_stats.deduped += 1
                return
            start = addr - addr % MEM_REQ_BYTES
            end = start + MEM_REQ_BYTES
        elif self.sr_mode == "dyn":
            if self._covered(addr) and self._covered(addr + g // 2):
                self.sr_stats.deduped += 1
                return
            start = self._first_uncovered(addr)
            end = start + g
        else:  # "sr"
            if last is not None and addr != last:
                self._dir_ewma = 0.9 * self._dir_ewma \
                    + 0.1 * (1.0 if addr > last else -1.0)
            d = self._dir_ewma
            if d < -0.3:            # backward run: window ends at addr
                probe = max(addr - g // 2, 0)
                if self._covered(addr) and self._covered(probe):
                    self.sr_stats.deduped += 1
                    return
                start = max(addr - addr % SR_OFFSET_UNIT - g
                            + SR_OFFSET_UNIT, 0)
                end = start + g
            elif d > 0.3:           # forward run: run ahead of coverage
                if self._covered(addr) and self._covered(addr + g // 2):
                    self.sr_stats.deduped += 1
                    return
                start = self._first_uncovered(addr)
                end = start + g
            else:                   # Around: centre the window (Fig. 7)
                lo = max(addr - g // 2, 0)
                if self._covered(lo) and self._covered(addr) and \
                        self._covered(addr + g // 2):
                    self.sr_stats.deduped += 1
                    return
                start = max((addr - g // 2) - (addr - g // 2)
                            % SR_OFFSET_UNIT, 0)
                end = start + g
        self.sr_queue.append(addr)
        self.ep.prefetch(now, start, end - start)
        self._ring_append(start, end)
        self.sr_stats.issued += 1
        self.sr_stats.bytes += end - start
        if self.sr_queue:
            self.sr_queue.popleft()

    # -------------------------------------------------------------- load
    def load(self, now: float, addr: int) -> float:
        """Service a load; returns completion time."""
        if self.ds_enabled and addr in self.staging_index:
            self.ds_stats["read_through"] += 1
            return now + GPU_MEM_NS
        self.memory_queue.append(addr)
        self.on_load_issue(now, addr)           # SR flit leaves immediately
        arrival = self._acquire_txn(now)        # demand read waits for a slot
        done = self.ep.read(arrival, addr) + CXL_RTT_NS / 2
        self._release_txn(done)
        if self.memory_queue:
            self.memory_queue.popleft()
        # profiler: DevLoad telemetry rides the response flit
        self.qos.update(self.ep.devload(done))
        return done

    # ------------------------------------------------------------- store
    def store(self, now: float, addr: int) -> float:
        """Service a store; returns the time the GPU may proceed."""
        if not self.ds_enabled:
            arrival = self._acquire_txn(now)
            done = self.ep.write(arrival, addr) + CXL_RTT_NS / 2
            self._release_txn(done)
            self.qos.update(self.ep.devload(done))
            return done
        # deterministic store: immediate completion into GPU memory
        congested = (not self.qos.flush_enabled) or self.ep.gc_pending() \
            or self.ep.devload(now) >= DevLoad.MODERATE
        if congested:
            if len(self.staging) >= self.staging_capacity:
                # staging exhausted: block like a plain CXL store
                self.ds_stats["blocked"] += 1
                arrival = self._acquire_txn(now)
                done = self.ep.write(arrival, addr) + CXL_RTT_NS / 2
                self._release_txn(done)
                self.qos.update(self.ep.devload(done))
                return done
            self.staging.append(addr)
            self.staging_index[addr] = len(self.staging) - 1
            self.ds_stats["diverted"] += 1
            return now + GPU_MEM_NS
        # dual write: GPU memory completes the request; EP write rides along
        self.ds_stats["fire_and_forget"] += 1
        self.ep.write(now + CXL_RTT_NS / 2, addr)
        self.qos.update(self.ep.devload(now))
        return now + GPU_MEM_NS

    # ------------------------------------------------------------- flush
    def background_flush(self, now: float, max_items: int = 16) -> None:
        """Drain the staging stack while the QoS state allows (Fig. 8 (3))."""
        if not self.ds_enabled or not self.staging:
            return
        if not self.qos.flush_enabled or \
                self.ep.devload(now) >= DevLoad.MODERATE:
            return
        for _ in range(min(max_items, len(self.staging))):
            addr = self.staging.pop()
            self.staging_index.pop(addr, None)
            self.ep.write(now, addr)
            self.ds_stats["flushed"] += 1
