"""Cycle-approximate CXL-GPU simulator.

Scalar oracle (``engine``), vectorized sweep engine (``vector``),
root-port controller (``controller``), media/endpoint models (``media``),
Table 1b trace generators (``workloads``) and scenario matrices
(``sweep``). ``engine`` also hosts the page-granular timing surface the
serving tier charges against (``PageStream`` / ``Topology``).
"""
from repro.sim.engine import (OpHandle, PageStream, RunResult, Topology,
                              replay_page_trace, run, slowdown_vs_ideal)
from repro.sim.media import (DRAM, MEDIA, NAND, OPTANE, ZNAND, Endpoint,
                             resolve_media)
from repro.sim.controller import RootPortController
from repro.sim.vector import run as run_vectorized
from repro.sim import sweep, workloads

__all__ = ["RunResult", "run", "run_vectorized", "slowdown_vs_ideal",
           "OpHandle", "PageStream", "Topology", "replay_page_trace",
           "DRAM", "MEDIA", "NAND", "OPTANE", "ZNAND", "Endpoint",
           "RootPortController", "resolve_media", "sweep", "workloads"]
