from repro.sim.engine import RunResult, run, slowdown_vs_ideal
from repro.sim.media import (DRAM, MEDIA, NAND, OPTANE, ZNAND, Endpoint,
                             resolve_media)
from repro.sim.controller import RootPortController
from repro.sim.vector import run as run_vectorized
from repro.sim import sweep, workloads

__all__ = ["RunResult", "run", "run_vectorized", "slowdown_vs_ideal",
           "DRAM", "MEDIA", "NAND", "OPTANE", "ZNAND", "Endpoint",
           "RootPortController", "resolve_media", "sweep", "workloads"]
