from repro.sim.engine import RunResult, run, slowdown_vs_ideal
from repro.sim.media import DRAM, MEDIA, NAND, OPTANE, ZNAND, Endpoint
from repro.sim.controller import RootPortController
from repro.sim import workloads

__all__ = ["RunResult", "run", "slowdown_vs_ideal", "DRAM", "MEDIA",
           "NAND", "OPTANE", "ZNAND", "Endpoint", "RootPortController",
           "workloads"]
