"""Execution engine: replay a workload trace against a GPU configuration.

Configurations (paper §EVALUATION):
  gpu-dram  — ideal: everything fits in local GPU memory.
  uvm       — unified virtual memory: on-demand page migration from host
              DRAM with ~500us host-runtime fault handling (ref. 11).
  gds       — GPUDirect storage: faults resolved from the SSD, same host
              runtime cost per fault.
  cxl       — the proposed CXL root complex (direct 64B loads/stores).
  cxl-naive — + naive SR (64B MemSpecRd per queued request)   [Fig. 9d]
  cxl-dyn   — + DevLoad-sized SR from the request address      [Fig. 9d]
  cxl-sr    — + address-window control (full SR)               [Fig. 9b-d]
  cxl-ds    — cxl-sr + deterministic store                     [Fig. 9b-e]

GPU model: a rolling timeline with memory-level parallelism — loads issue
into a 32-deep queue and only block when the queue is full or a value is
needed LOOKAHEAD ops later; stores block only when the 32-deep store
queue is full. An LLC (4 MiB, 64B lines, LRU) filters the trace exactly as
the paper's cache hierarchy does (compute-intensive workloads mostly hit).
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sim import workloads as wl
from repro.sim.controller import (CXL_RTT_NS, GPU_MEM_NS,
                                  RootPortController)
from repro.sim.media import (MEDIA, DRAM, Endpoint, MediaModel,
                             resolve_media)

COMPUTE_NS = 8.0
LLC_NS = 4.0
FAULT_NS = 12_000.0           # UVM/GDS host-runtime fault service (ref. 11
                              # measures tens of us per fault; the paper's
                              # ~500us figure amortizes batched groups)
PCIE_NS_PER_B = 1.0 / 32.0    # PCIe 5.0 x8 ~ 32 GB/s
PAGE = 4 << 10                # UVM base migration granule
LLC_LINES = (4 << 20) // 64
MLP = 64                      # outstanding loads (8 cores x 8 threads with
                              # warp switching: issue continues until the
                              # scoreboard is exhausted)
STORE_Q = 16
WARMUP_FRAC = 0.33            # caches/pages warm before timing starts


class LRU:
    """Bounded LRU set of keys (LLC lines / UVM pages), capacity in keys."""

    __slots__ = ("cap", "d")

    def __init__(self, cap: int):
        self.cap = cap
        self.d: OrderedDict = OrderedDict()

    def hit(self, key) -> bool:
        """Probe + refresh recency; True iff ``key`` is resident."""
        if key in self.d:
            self.d.move_to_end(key)
            return True
        return False

    def fill(self, key) -> None:
        """Insert ``key``, evicting the least recently used past ``cap``."""
        if key in self.d:
            self.d.move_to_end(key)
            return
        if len(self.d) >= self.cap:
            self.d.popitem(last=False)
        self.d[key] = True


@dataclasses.dataclass
class RunResult:
    """One engine run's outcome: timed window + controller telemetry.

    ``exec_ns`` is the post-warmup simulated execution time in ns over
    ``n_ops`` trace entries; ``sr``/``ds`` hold the controller's SR and
    deterministic-store counters when a CXL config ran.
    """

    config: str
    workload: str
    media: str
    exec_ns: float
    n_ops: int
    ep_hit_rate: float
    sr: Optional[dict] = None
    ds: Optional[dict] = None
    samples: Optional[list] = None    # (t, latency, kind) for Fig. 9e

    @property
    def latency_per_op(self) -> float:
        """Mean simulated ns per (post-warmup) trace op."""
        return self.exec_ns / self.n_ops


def run(config: str, workload: str, media_name: str = "dram", *,
        n_ops: int = 60_000, gpu_mem_frac: float = 0.1,
        working_set: int = 640 << 20, seed: int = 0,
        record_samples: bool = False, mlp: int = MLP,
        store_q: int = STORE_Q,
        trace: Optional[np.ndarray] = None) -> RunResult:
    """Scalar reference engine (per-access event loop) — the oracle the
    vectorized engine in ``repro.sim.vector`` is validated against.

    mlp / store_q are the GPU's outstanding-load and store-queue depths
    (sweepable); ``media_name`` accepts scaled variants ("znand@2"); an
    explicit ``trace`` (structured kind/addr array) overrides the named
    workload's generated trace.
    """
    if trace is None:
        trace = wl.generate_cached(workload, n_ops, working_set, seed)
    media = resolve_media(media_name)
    llc = LRU(LLC_LINES)
    gpu_mem = int(working_set * gpu_mem_frac)

    t = 0.0
    loads_q: List[Tuple[float, int]] = []   # (completion, op_idx) heap
    stores_q: List[float] = []
    samples: List[Tuple[float, float, int]] = []
    hbm = [0.0] * 8                         # local-memory banks (finite BW)

    def hbm_access(now: float) -> float:
        b = min(range(8), key=lambda i: hbm[i])
        done = max(now, hbm[b]) + GPU_MEM_NS
        hbm[b] = max(now, hbm[b]) + GPU_MEM_NS / 4   # pipelined banks
        return done

    ep: Optional[Endpoint] = None
    ctl: Optional[RootPortController] = None
    pages: Optional[LRU] = None

    if config == "gpu-dram":
        pass
    elif config in ("uvm", "gds"):
        pages = LRU(max(gpu_mem // PAGE, 1))
    else:
        ep = Endpoint(media, dram_cache_bytes=gpu_mem // 4)
        sr_mode = {"cxl": "off", "cxl-naive": "naive", "cxl-dyn": "dyn",
                   "cxl-sr": "sr", "cxl-ds": "sr"}[config]
        ctl = RootPortController(ep, sr_mode=sr_mode,
                                 ds_enabled=(config == "cxl-ds"))

    def drain_loads() -> None:
        nonlocal t
        while loads_q and len(loads_q) >= mlp:
            done, _ = heapq.heappop(loads_q)
            t = max(t, done)

    def fault(addr: int) -> float:
        """UVM/GDS page fault: host runtime + page move."""
        page = addr // PAGE
        if pages.hit(page):
            return GPU_MEM_NS
        pages.fill(page)
        move = PAGE * PCIE_NS_PER_B
        if config == "gds":
            move += media.read_ns + PAGE / media.bw_gbps
        else:
            move += DRAM.read_ns
        return FAULT_NS + move

    kinds = trace["kind"]
    addrs = trace["addr"]
    warm_i = int(len(trace) * WARMUP_FRAC)
    t_warm = 0.0
    for i in range(len(trace)):
        if i == warm_i:
            t_warm = t
        kind = int(kinds[i])
        if kind == 0:
            t += COMPUTE_NS
            if ctl is not None and i % 16 == 0:
                ctl.background_flush(t)
            continue
        addr = int(addrs[i])
        line = addr // 64
        if llc.hit(line):
            t += LLC_NS
            continue
        llc.fill(line)
        if kind == 1:                                   # ---- load
            drain_loads()
            if config == "gpu-dram":
                done = hbm_access(t)
            elif config in ("uvm", "gds"):
                lat = fault(addr)
                if lat > GPU_MEM_NS:                    # blocking fault
                    t += lat
                    done = t
                else:
                    done = t + lat
            else:
                done = ctl.load(t, addr)
            heapq.heappush(loads_q, (done, i))
            if record_samples:
                samples.append((t, done - t, 1))
            t += LLC_NS
        else:                                           # ---- store
            while stores_q and (len(stores_q) >= store_q):
                t = max(t, heapq.heappop(stores_q))
            if config == "gpu-dram":
                done = hbm_access(t)
            elif config in ("uvm", "gds"):
                lat = fault(addr)
                if lat > GPU_MEM_NS:
                    t += lat
                    done = t
                else:
                    done = t + lat
            else:
                done = ctl.store(t, addr)
            heapq.heappush(stores_q, done)
            if record_samples:
                samples.append((t, done - t, 2))
            t += LLC_NS

    while loads_q:
        done, _ = heapq.heappop(loads_q)
        t = max(t, done)
    while stores_q:
        t = max(t, heapq.heappop(stores_q))

    return RunResult(
        config=config, workload=workload,
        media=getattr(media_name, "name", media_name),
        exec_ns=t - t_warm, n_ops=len(trace) - warm_i,
        ep_hit_rate=ep.hit_rate() if ep else 0.0,
        sr=dataclasses.asdict(ctl.sr_stats) if ctl else None,
        ds=dict(ctl.ds_stats) if ctl else None,
        samples=samples if record_samples else None)


def slowdown_vs_ideal(config: str, workload: str, media: str = "dram",
                      **kw) -> float:
    """Execution-time ratio of ``config`` vs the gpu-dram ideal (Fig. 9)."""
    base = run("gpu-dram", workload, media, **kw).exec_ns
    return run(config, workload, media, **kw).exec_ns / base


def category_mean(results: Dict[str, float], category: str) -> float:
    """Mean of per-workload ``results`` over one Table 1b category."""
    names = [n for n, s in wl.TABLE_1B.items() if s.category == category]
    vals = [results[n] for n in names if n in results]
    return float(np.mean(vals)) if vals else float("nan")


# ---------------------------------------------------------------------------
# Single-stream page timing (the serving tier's front-end)
# ---------------------------------------------------------------------------
#
# The serving engine moves KV pages, not 64B cache lines: a retired slot's
# pages flush to the expansion tier, a prefix restore pulls them back. The
# PageStream below is the reusable timing API both sides of that traffic
# share — one root port + EP (the same silicon model the trace engine
# drives). Two disciplines coexist on one port clock:
#
#  * blocking ops (``read``/``write``) stall the caller until the pages
#    land — the slot-synchronous model the serving tier started with;
#  * non-blocking ops (``issue``/``poll``) start the media work on the
#    port's service cursor and hand back an :class:`OpHandle` carrying the
#    completion timestamp; the caller's clock only moves when the per-port
#    in-flight cap forces an issue stall. Completions retire as simulated
#    time (``advance``) passes the handle's ``done_ns`` — the paper's
#    latency hiding: media work overlaps the decode ticks in between.

PAGE_ADVANCE = 0      # idle time passing between engine ticks (nbytes = ns)
PAGE_READ = 1         # demand page read (restore fetch)
PAGE_WRITE = 2        # page writeback (flush to the cold tier)
PAGE_PREFETCH = 3     # MemSpecRd stream for an upcoming restore
PAGE_READ_ASYNC = 4   # non-blocking demand read (charged = issue wait only)
PAGE_WRITE_ASYNC = 5  # non-blocking writeback (charged = issue wait only)

# fault-annotated variants: same timing discipline as their base kind but
# the op crossed the fault path (retried under a transient window, or hit
# a downed port). Replaying them requires the recording run's
# FaultSchedule — PageStream.op refuses them without one, and the
# closed-form engine (sim.vector.page_trace_closed_form) rejects them
# outright, exactly like the async kinds.
PAGE_READ_FAULT = 6         # blocking read that crossed the fault path
PAGE_WRITE_FAULT = 7        # blocking write that crossed the fault path
PAGE_READ_ASYNC_FAULT = 8   # non-blocking read, fault-annotated
PAGE_WRITE_ASYNC_FAULT = 9  # non-blocking write, fault-annotated

PAGE_FAULT_KINDS = (PAGE_READ_FAULT, PAGE_WRITE_FAULT,
                    PAGE_READ_ASYNC_FAULT, PAGE_WRITE_ASYNC_FAULT)
# fault kind -> the base kind whose timing discipline it replays with
_FAULT_BASE_KIND = {PAGE_READ_FAULT: PAGE_READ,
                    PAGE_WRITE_FAULT: PAGE_WRITE,
                    PAGE_READ_ASYNC_FAULT: PAGE_READ_ASYNC,
                    PAGE_WRITE_ASYNC_FAULT: PAGE_WRITE_ASYNC}

MAX_INFLIGHT_OPS = 4  # default per-port cap on outstanding async page ops

MAX_OP_RETRIES = 4         # bounded retry budget per page op (no livelock)
RETRY_BACKOFF_NS = 2_000.0  # first retry backoff; doubles per retry


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled endpoint fault, keyed to simulated ns.

    ``kind`` is ``"degrade"`` (media service time multiplied by ``mult``
    while the window ``[t_ns, until_ns)`` is active), ``"transient"``
    (each CXL.mem page-op attempt on the port fails with probability
    ``p_err`` inside the window, charged a bounded retry-with-backoff) or
    ``"hot_remove"`` (the port is down from ``t_ns`` on — permanent;
    ``until_ns`` is ignored). Use the :func:`degrade` / :func:`transient`
    / :func:`hot_remove` helpers rather than building events by hand.
    """

    t_ns: float
    port: int
    kind: str
    mult: float = 1.0
    p_err: float = 0.0
    until_ns: float = float("inf")


def degrade(t_ns: float, port: int, mult: float,
            until_ns: float = float("inf")) -> FaultEvent:
    """A latency-spike window: ``port``'s media service time is scaled by
    ``mult`` while ``t_ns <= now < until_ns``."""
    if mult <= 0:
        raise ValueError(f"degrade mult must be > 0 (got {mult})")
    return FaultEvent(t_ns=float(t_ns), port=int(port), kind="degrade",
                      mult=float(mult), until_ns=float(until_ns))


def transient(t_ns: float, port: int, p_err: float,
              until_ns: float = float("inf")) -> FaultEvent:
    """A transient-error window: page-op attempts on ``port`` fail with
    probability ``p_err`` while ``t_ns <= now < until_ns`` (decided by a
    seeded hash, so live runs and oracle replays agree exactly)."""
    if not 0.0 <= p_err <= 1.0:
        raise ValueError(f"transient p_err must be in [0, 1] (got {p_err})")
    return FaultEvent(t_ns=float(t_ns), port=int(port), kind="transient",
                      p_err=float(p_err), until_ns=float(until_ns))


def hot_remove(t_ns: float, port: int) -> FaultEvent:
    """A permanent endpoint removal: ``port`` is down from ``t_ns`` on;
    every page op on it fails instantly and costs nothing."""
    return FaultEvent(t_ns=float(t_ns), port=int(port), kind="hot_remove")


@dataclasses.dataclass(frozen=True)
class PortFaultState:
    """The folded fault state of one port at one instant of simulated
    time: ``down`` (and since when), the product of active degrade
    multipliers, and the max active transient error probability."""

    down: bool = False
    down_since: float = float("inf")
    mult: float = 1.0
    p_err: float = 0.0


class FaultSchedule:
    """A deterministic, replayable schedule of endpoint faults.

    The schedule is pure: :meth:`state` is a function of (port, time)
    alone and :meth:`op_fails` of (seed, port, attempt-ordinal) alone, so
    a live tier run and a fresh :func:`replay_page_trace` of its recorded
    trace — which walk identical op sequences on identical clocks — see
    identical degrade windows, identical transient failures and identical
    retry counts. That is what keeps the scalar oracle within 1% under
    fault injection.
    """

    def __init__(self, events, seed: int = 0):
        events = tuple(sorted(events, key=lambda e: e.t_ns))
        for e in events:
            if e.kind not in ("degrade", "transient", "hot_remove"):
                raise ValueError(f"unknown fault kind {e.kind!r}")
            if e.until_ns <= e.t_ns:
                raise ValueError(f"empty fault window: {e.kind} on port "
                                 f"{e.port} ends at {e.until_ns} ns but "
                                 f"starts at {e.t_ns} ns")
        self.events = events
        self.seed = int(seed)

    def ports(self):
        """The sorted set of ports named by any event in the schedule."""
        return sorted({e.port for e in self.events})

    def state(self, port: int, t_ns: float) -> PortFaultState:
        """Fold every event active on ``port`` at ``t_ns`` into one
        :class:`PortFaultState` (pure; safe to call repeatedly)."""
        down, down_since, mult, p_err = False, float("inf"), 1.0, 0.0
        for e in self.events:
            if e.port != port or t_ns < e.t_ns:
                continue
            if e.kind == "hot_remove":
                down = True
                down_since = min(down_since, e.t_ns)
            elif t_ns < e.until_ns:
                if e.kind == "degrade":
                    mult *= e.mult
                else:
                    p_err = max(p_err, e.p_err)
        return PortFaultState(down=down, down_since=down_since,
                              mult=mult, p_err=p_err)

    def ports_down(self, t_ns: float):
        """Ports hot-removed at or before ``t_ns`` (sorted list)."""
        return sorted({e.port for e in self.events
                       if e.kind == "hot_remove" and e.t_ns <= t_ns})

    def op_fails(self, port: int, attempt: int, p_err: float) -> bool:
        """Deterministic transient-failure draw for one op attempt.

        ``attempt`` is the port's monotone attempt ordinal (each service
        attempt of each page op consumes one), so the draw sequence is
        identical between a live run and its trace replay. The draw
        hashes (seed, port, attempt) — not time — making it robust to
        float jitter at window edges.
        """
        if p_err <= 0.0:
            return False
        h = hashlib.blake2b(f"{self.seed}:{port}:{attempt}".encode(),
                            digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64 < p_err


@dataclasses.dataclass
class OpHandle:
    """Completion handle for one non-blocking page op on one port.

    All timestamps are simulated ns on the issuing port's clock:
    ``issued_ns`` is the caller's clock when the op was issued (after any
    in-flight-cap stall), ``start_ns`` when the port began servicing it,
    ``done_ns`` its completion, and ``wait_ns`` the issue stall the
    in-flight cap charged the caller (0.0 when a slot was free). The op
    is complete once the port clock reaches ``done_ns`` (see
    :meth:`PageStream.poll`).
    """

    kind: int
    addr: int
    nbytes: int
    port: int
    issued_ns: float
    start_ns: float
    done_ns: float
    wait_ns: float
    retries: int = 0      # transient-error retries the op absorbed
    failed: bool = False  # retry budget exhausted, or port hot-removed

    @property
    def in_flight_ns(self) -> float:
        """Simulated ns the op was outstanding (issue -> completion)."""
        return self.done_ns - self.issued_ns


class PageStream:
    """Single-stream page timing over one root port + EP.

    ``repro.core.tier.CxlTier`` charges the serving engine's page traffic
    against this API incrementally; :func:`replay_page_trace` replays a
    recorded page trace against a fresh instance — the scalar oracle the
    tier's online accounting is differentially tested against.

    Each page op is decomposed into ``req_bytes``-spaced CXL.mem requests
    issued back-to-back (the next request leaves when the previous one
    completes). Reads go through ``RootPortController.load`` — so SR
    window generation, ring dedup and DevLoad telemetry all engage;
    writes go through ``RootPortController.store`` — deterministic stores
    complete at GPU-memory speed and divert to staging under congestion;
    prefetches stream straight to the EP's internal DRAM (the MemSpecRd
    fill), off the critical path, honoring the QoS halt state.

    Blocking ops (``read``/``write``) advance ``now`` to the completion;
    non-blocking ops (``issue``) advance only the port's service cursor
    (``busy_until``) and return an :class:`OpHandle` — ``now`` moves just
    for the in-flight-cap stall, so media work overlaps whatever the
    caller does until it ``poll``\\ s the handle. Both disciplines share
    one cursor: a blocking op issued behind outstanding async work queues
    behind it.
    """

    def __init__(self, media: str = "znand", *, sr: bool = True,
                 ds: bool = True, req_bytes: int = 256,
                 dram_cache_bytes: int = 8 << 20,
                 max_inflight: int = MAX_INFLIGHT_OPS,
                 faults: Optional[FaultSchedule] = None, port_id: int = 0):
        self.ep = Endpoint(resolve_media(media),
                           dram_cache_bytes=dram_cache_bytes)
        self.ctl = RootPortController(self.ep,
                                      sr_mode="sr" if sr else "off",
                                      ds_enabled=ds)
        self.req_bytes = int(req_bytes)
        self.now = 0.0
        self.busy_until = 0.0           # port service cursor (>= now only
        if int(max_inflight) < 1:       # while async ops are out)
            raise ValueError("max_inflight must be >= 1 "
                             f"(got {max_inflight})")
        self.max_inflight = int(max_inflight)
        self.inflight: List[OpHandle] = []
        self.prefetch_pages = 0
        self.prefetch_halted = 0
        # ---- fault injection (None = healthy port, zero overhead)
        self.faults = faults
        self.port_id = int(port_id)
        self.down = False               # hot-removed (permanent)
        self.down_since = float("inf")
        self.fault_retries = 0          # transient retries absorbed
        self.fault_failures = 0         # ops that exhausted the budget
        self.fault_backoff_ns = 0.0     # total retry backoff charged
        self.last_op_retries = 0        # annotation of the latest read/write
        self.last_op_failed = False
        self._base_media = self.ep.media
        self._applied_mult = 1.0
        self._attempts = 0              # monotone per-port attempt ordinal

    @property
    def degrade_mult(self) -> float:
        """The degrade multiplier currently applied to the port's media
        (1.0 = healthy; updated at fault-window boundaries)."""
        return self._applied_mult

    def _service(self, kind: int, addr: int, nbytes: int,
                 start: float) -> float:
        """Walk one page op's CXL.mem requests from ``start``; returns the
        completion time (ns). ``kind`` is PAGE_READ or PAGE_WRITE."""
        t = start
        if kind == PAGE_READ:
            for a in range(addr, addr + nbytes, self.req_bytes):
                t = self.ctl.load(t, a)
        else:
            for a in range(addr, addr + nbytes, self.req_bytes):
                t = self.ctl.store(t, a)
        return t

    def _retire_completed(self) -> None:
        """Drop handles the stream clock has passed (pure function of
        ``now`` — polling early never changes subsequent timing)."""
        if self.inflight:
            self.inflight = [h for h in self.inflight
                             if h.done_ns > self.now]

    def _fault_state(self, t: float) -> Optional[PortFaultState]:
        """Fold the schedule at ``t`` and apply its side effects: swap in
        the degraded (scaled) media at window boundaries and latch
        hot-removal — failing any in-flight op whose completion lies past
        the removal instant. Pure in ``t``, so the live tier and the
        trace replay (identical clocks) apply identical transitions."""
        if self.faults is None:
            return None
        st = self.faults.state(self.port_id, t)
        if st.down and not self.down:
            self.down = True
            self.down_since = st.down_since
            for h in self.inflight:
                if h.done_ns > st.down_since:
                    h.failed = True
        if st.mult != self._applied_mult:
            self.ep.media = (self._base_media if st.mult == 1.0 else
                             self._base_media.scaled(latency=st.mult))
            self._applied_mult = st.mult
        return st

    def _service_faulted(self, kind: int, addr: int, nbytes: int,
                         start: float,
                         st: Optional[PortFaultState]):
        """Fault-aware service: walk the op, retrying with exponential
        backoff on transient failures. Returns ``(done_ns, retries,
        failed)`` — ``failed`` set once the bounded retry budget
        (:data:`MAX_OP_RETRIES`) is exhausted; the clock cost of the
        failed attempts and their backoff is still charged (no free
        failures, no livelock)."""
        if st is None:
            return self._service(kind, addr, nbytes, start), 0, False
        t = start
        retries = 0
        while True:
            self._attempts += 1
            done = self._service(kind, addr, nbytes, t)
            if not self.faults.op_fails(self.port_id, self._attempts,
                                        st.p_err):
                return done, retries, False
            retries += 1
            self.fault_retries += 1
            if retries > MAX_OP_RETRIES:
                self.fault_failures += 1
                return done, retries, True
            backoff = RETRY_BACKOFF_NS * (2.0 ** (retries - 1))
            self.fault_backoff_ns += backoff
            t = done + backoff

    def read(self, addr: int, nbytes: int) -> float:
        """Demand-read a page span; returns the stall (ns) until it lands.

        Under a :class:`FaultSchedule` the op may retry (transient
        window) — ``last_op_retries`` / ``last_op_failed`` annotate the
        outcome; on a hot-removed port it fails instantly at zero cost.
        """
        start = max(self.now, self.busy_until)
        st = self._fault_state(start)
        if self.down:
            self.last_op_retries, self.last_op_failed = 0, True
            return 0.0
        t, retries, failed = self._service_faulted(PAGE_READ, addr, nbytes,
                                                   start, st)
        lat = t - self.now
        self.now = t
        self.busy_until = t
        self.last_op_retries, self.last_op_failed = retries, failed
        self._retire_completed()
        return lat

    def write(self, addr: int, nbytes: int) -> float:
        """Write a page span; returns the time (ns) the writer is held.

        Fault semantics match :meth:`read` (retry under transient
        windows, instant zero-cost failure on a downed port)."""
        start = max(self.now, self.busy_until)
        st = self._fault_state(start)
        if self.down:
            self.last_op_retries, self.last_op_failed = 0, True
            return 0.0
        t, retries, failed = self._service_faulted(PAGE_WRITE, addr, nbytes,
                                                   start, st)
        lat = t - self.now
        self.now = t
        self.busy_until = t
        self.last_op_retries, self.last_op_failed = retries, failed
        self._retire_completed()
        return lat

    def issue(self, kind: int, addr: int, nbytes: int) -> OpHandle:
        """Issue a page op without blocking on its completion.

        ``kind`` is PAGE_READ_ASYNC / PAGE_WRITE_ASYNC (the blocking
        kinds are accepted and mapped). The op's requests are scheduled
        back-to-back on the port's service cursor starting at
        ``max(now, busy_until)``; the caller's clock advances only when
        the per-port in-flight cap is exhausted — then the issue stalls
        until the oldest outstanding op frees a slot, and that stall is
        the handle's ``wait_ns`` (the only latency charged at issue).
        """
        issued = self.now
        self._retire_completed()
        wait = 0.0
        if len(self.inflight) >= self.max_inflight:
            # stall until enough outstanding ops complete to free a slot
            free_at = sorted(h.done_ns for h in self.inflight)[
                len(self.inflight) - self.max_inflight]
            wait = max(0.0, free_at - self.now)
            self.now += wait
            self._retire_completed()
        start = max(self.now, self.busy_until)
        st = self._fault_state(start)
        if self.down:
            # downed port: the op completes immediately as a failure and
            # never occupies a service slot (nothing left to service it)
            return OpHandle(kind=kind, addr=addr, nbytes=nbytes, port=0,
                            issued_ns=issued, start_ns=start,
                            done_ns=self.now, wait_ns=wait, failed=True)
        base = PAGE_READ if kind in (PAGE_READ, PAGE_READ_ASYNC) \
            else PAGE_WRITE
        done, retries, failed = self._service_faulted(base, addr, nbytes,
                                                      start, st)
        self.busy_until = done
        handle = OpHandle(kind=kind, addr=addr, nbytes=nbytes, port=0,
                          issued_ns=issued, start_ns=start, done_ns=done,
                          wait_ns=wait, retries=retries, failed=failed)
        self.inflight.append(handle)
        return handle

    def poll(self, handle: OpHandle) -> bool:
        """True once the stream clock has reached the op's completion.

        Pure observation: retiring a completed handle early never changes
        later timing (the in-flight set is a function of ``now`` alone).
        """
        self._retire_completed()
        return self.now >= handle.done_ns

    def inflight_depth(self) -> int:
        """Number of async ops still outstanding at the current clock."""
        self._retire_completed()
        return len(self.inflight)

    def prefetch(self, addr: int, nbytes: int) -> float:
        """Issue the MemSpecRd stream for a span; free on the demand path."""
        if self.down or self.ctl.sr_mode == "off" or self.ep.is_dram:
            return 0.0
        if self.ctl.qos.sr_halted:
            self.prefetch_halted += 1
            return 0.0
        self.prefetch_pages += 1
        self.ep.prefetch(self.now, addr, nbytes)
        return 0.0

    def advance(self, dt_ns: float) -> float:
        """Idle time between engine ticks: background flush windows open,
        announced internal tasks (GC) get their quiet window, and the
        periodic DevLoad sample keeps the QoS ladder live — without it a
        closed flush window could never reopen (no stores -> no response
        flits -> no telemetry), deadlocking the divert discipline."""
        self.now += dt_ns
        self._retire_completed()
        self._fault_state(self.now)
        if self.down:
            return 0.0
        self.ctl.qos.update(self.ep.devload(self.now))
        self.ctl.background_flush(self.now)
        return 0.0

    def op(self, kind: int, addr: int, nbytes: int) -> float:
        """Dispatch one recorded page op (the replay entry point).

        Async kinds replay as fresh issues — the returned latency is the
        in-flight-cap stall charged at issue, exactly what the online
        accounting recorded; the op's media work lands on the service
        cursor as it did live."""
        if kind in PAGE_FAULT_KINDS:
            # fault-annotated records carry the fault path's timing —
            # retries, backoff, or a downed port's zero-cost failure —
            # which only the recording run's schedule can reproduce
            if self.faults is None:
                raise ValueError(
                    f"fault-annotated page-op kind {kind} cannot replay "
                    "without the recording run's FaultSchedule; pass "
                    "faults= to replay_page_trace / PageStream")
            kind = _FAULT_BASE_KIND[kind]
        if kind == PAGE_READ:
            return self.read(addr, nbytes)
        if kind == PAGE_WRITE:
            return self.write(addr, nbytes)
        if kind == PAGE_PREFETCH:
            return self.prefetch(addr, nbytes)
        if kind == PAGE_ADVANCE:
            return self.advance(float(nbytes))
        if kind in (PAGE_READ_ASYNC, PAGE_WRITE_ASYNC):
            return self.issue(kind, addr, nbytes).wait_ns
        raise ValueError(f"unknown page-op kind {kind}")


class Topology:
    """N root ports, each fronting its own endpoint, with per-port clocks.

    The paper's headline system design: "multiple CXL root ports for
    integrating diverse storage media (DRAMs and/or SSDs)". Each port is
    one :class:`PageStream` (root port + EP + QoS state) with its *own*
    simulated clock (``ports[p].now``, ns), so page ops issued on
    different ports overlap in simulated time — the cross-port **issue**
    half. :meth:`sync` is the **drain** half: a barrier that realigns
    every port clock to the topology-wide maximum, called at engine-tick
    boundaries (:meth:`advance`) and wherever the caller needs blocking
    completions settled. Non-blocking ops (:meth:`issue`/:meth:`poll`)
    additionally overlap *within* a port: their media work rides the
    port's service cursor past the barrier and retires only when
    simulated time reaches the handle's completion timestamp.

    With one port this degenerates exactly to the single blocking
    ``PageStream`` (``sync`` is a no-op), which is what keeps the 1-port
    topology bit-identical to the pre-topology serving tier.

    Args:
        medias: per-port media specs (names, bins already resolved, or
            scaled variants like ``"znand@2"``); one EP per entry.
        sr/ds/req_bytes/dram_cache_bytes: per-port ``PageStream`` knobs
            (shared by every port).
    """

    def __init__(self, medias, *, sr: bool = True, ds: bool = True,
                 req_bytes: int = 256, dram_cache_bytes: int = 8 << 20,
                 max_inflight: int = MAX_INFLIGHT_OPS,
                 faults: Optional[FaultSchedule] = None):
        if not medias:
            raise ValueError("a Topology needs at least one port")
        self.faults = faults
        self.ports = [PageStream(m, sr=sr, ds=ds, req_bytes=req_bytes,
                                 dram_cache_bytes=dram_cache_bytes,
                                 max_inflight=max_inflight,
                                 faults=faults, port_id=i)
                      for i, m in enumerate(medias)]

    @property
    def n_ports(self) -> int:
        """Number of root ports (== EPs) in the topology."""
        return len(self.ports)

    @property
    def now(self) -> float:
        """Topology-wide simulated time (ns): the furthest port clock."""
        return max(p.now for p in self.ports)

    def sync(self) -> float:
        """Drain barrier: realign every port clock to the max; returns it.

        This is where completions from overlapped per-port ops are
        settled — after ``sync`` all ports agree on "now" (ns).
        """
        t = max(p.now for p in self.ports)
        for p in self.ports:
            p.now = t
        return t

    def advance(self, dt_ns: float) -> float:
        """Tick boundary: drain all ports, then pass ``dt_ns`` of idle
        time to each (QoS DevLoad samples + background flush windows, as
        ``PageStream.advance``). Returns 0.0 (free on the demand path)."""
        self.sync()
        for p in self.ports:
            p.advance(dt_ns)
        return 0.0

    def ports_down(self):
        """Ports whose endpoints are hot-removed so far (sorted list)."""
        return sorted(i for i, p in enumerate(self.ports) if p.down)

    def issue(self, port: int, kind: int, addr: int,
              nbytes: int) -> OpHandle:
        """Issue a non-blocking op on ``port``; returns its handle with
        ``handle.port`` stamped so :meth:`poll` can route back."""
        handle = self.ports[port].issue(kind, addr, nbytes)
        handle.port = port
        return handle

    def poll(self, handle: OpHandle) -> bool:
        """True once the handle's port clock reached its completion."""
        return self.ports[handle.port].poll(handle)

    def inflight_depth(self, port: Optional[int] = None) -> int:
        """Outstanding async ops on ``port`` (or topology-wide when
        ``port`` is None)."""
        if port is not None:
            return self.ports[port].inflight_depth()
        return sum(p.inflight_depth() for p in self.ports)

    def op(self, port: int, kind: int, addr: int, nbytes: int) -> float:
        """Dispatch one port-tagged page op; returns its latency (ns).

        ``port < 0`` (used for ``PAGE_ADVANCE`` records) broadcasts to the
        whole topology through :meth:`advance`.
        """
        if kind == PAGE_ADVANCE:
            return self.advance(float(nbytes))
        return self.ports[port].op(kind, addr, nbytes)


def replay_page_trace(ops, *, media: str = "znand", sr: bool = True,
                      ds: bool = True, req_bytes: int = 256,
                      dram_cache_bytes: int = 8 << 20,
                      max_inflight: int = MAX_INFLIGHT_OPS,
                      topology=None,
                      faults: Optional[FaultSchedule] = None) -> np.ndarray:
    """Scalar-oracle replay of a recorded page trace.

    ``ops`` is the ``CxlTier.ops`` recording: ``(kind, addr, nbytes)``
    tuples for a single-port tier, or port-tagged
    ``(port, kind, addr, nbytes)`` tuples when ``topology`` (a sequence
    of per-port media specs) is given. Returns the per-op latencies (ns)
    of a fresh :class:`PageStream` / :class:`Topology` walking the same
    trace — the cross-validation oracle for the tier's incremental
    accounting. Async op kinds replay too: the interleaved PAGE_ADVANCE
    records carry the simulated time that let them complete, so a replay
    reproduces issue stalls (``max_inflight`` must match the recording
    tier's cap) and service-cursor queueing exactly. Fault-annotated
    traces (kinds in :data:`PAGE_FAULT_KINDS`) additionally need the
    recording run's ``faults`` schedule — with it the replay reproduces
    every degrade window, transient retry and hot-removal at identical
    simulated instants; without it the replay raises rather than
    silently mis-charging.
    """
    if topology is not None:
        topo = Topology(topology, sr=sr, ds=ds, req_bytes=req_bytes,
                        dram_cache_bytes=dram_cache_bytes,
                        max_inflight=max_inflight, faults=faults)
        return np.asarray([topo.op(p, k, a, n) for p, k, a, n in ops],
                          np.float64)
    stream = PageStream(media, sr=sr, ds=ds, req_bytes=req_bytes,
                        dram_cache_bytes=dram_cache_bytes,
                        max_inflight=max_inflight, faults=faults)
    return np.asarray([stream.op(k, a, n) for k, a, n in ops], np.float64)
