"""Declarative scenario sweeps over the CXL-GPU simulator.

A :class:`Scenario` names one simulator run (config x workload x media x
GPU queue shape); :func:`matrix` builds cross products, :func:`fig9_matrix`
reproduces the paper's Figure-9 evaluation set, and :func:`run_sweep` fans
a scenario list out over the vectorized engine (optionally across worker
processes) with trace/LLC-mask precomputation shared per workload.

:func:`bench` is the perf/accuracy harness behind ``benchmarks/sweep.py``:
it replays a matrix on both engines, verifies the vectorized engine
against the scalar oracle per scenario, and emits the ``BENCH_sim.json``
artifact consumed by CI.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.sim import engine as scalar_engine
from repro.sim import vector as vector_engine
from repro.sim.engine import MLP, STORE_Q, RunResult
from repro.sim.workloads import ORDER

DEFAULT_N_OPS = int(os.environ.get("REPRO_SIM_OPS", "12000"))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One simulator run. ``media`` accepts scaled variants ("znand@2" =
    a 2x-latency tail bin — the media-latency-distribution axis)."""

    config: str
    workload: str
    media: str = "dram"
    n_ops: int = DEFAULT_N_OPS
    mlp: int = MLP
    store_q: int = STORE_Q
    seed: int = 0

    @property
    def key(self) -> str:
        """Stable scenario id ("config/workload/media[/...]") for results
        tables and artifact keys."""
        tail = f"/n{self.n_ops}"
        if (self.mlp, self.store_q) != (MLP, STORE_Q):
            tail += f"/mlp{self.mlp}sq{self.store_q}"
        if self.seed:
            tail += f"/s{self.seed}"
        return f"{self.config}/{self.workload}/{self.media}{tail}"


def matrix(configs: Sequence[str], workloads: Sequence[str],
           media: Sequence[str] = ("dram",), *,
           n_ops: int = DEFAULT_N_OPS, mlps: Sequence[int] = (MLP,),
           store_qs: Sequence[int] = (STORE_Q,),
           seeds: Sequence[int] = (0,)) -> List[Scenario]:
    """Cross-product scenario matrix, de-duplicated, in stable order."""
    out = []
    for w, m, cfg, mlp, sq, seed in itertools.product(
            workloads, media, configs, mlps, store_qs, seeds):
        out.append(Scenario(cfg, w, m, n_ops=n_ops, mlp=mlp, store_q=sq,
                            seed=seed))
    return list(dict.fromkeys(out))


def fig9_matrix(n_ops: int = DEFAULT_N_OPS) -> List[Scenario]:
    """The paper's Figure 9 evaluation set (9a-9e), grouped by workload so
    per-trace precomputation amortizes across configs/media."""
    out: List[Scenario] = []
    for w in ORDER:
        # 9a: DRAM expander vs ideal / UVM
        out += matrix(("gpu-dram", "uvm", "cxl"), (w,), ("dram",),
                      n_ops=n_ops)
        # 9b: SSD expander, SR/DS ladder
        out += matrix(("cxl", "cxl-sr", "cxl-ds"), (w,), ("znand",),
                      n_ops=n_ops)
    # 9c: backend-media sweep
    out += matrix(("cxl", "cxl-sr", "cxl-ds"), ("vadd", "path", "bfs"),
                  ("optane", "znand", "nand"), n_ops=n_ops)
    # 9d: SR ablation ladder per access pattern
    out += matrix(("cxl", "cxl-naive", "cxl-dyn", "cxl-sr"),
                  ("vadd", "sort", "path"), ("znand",), n_ops=n_ops)
    return list(dict.fromkeys(out))


def smoke_matrix(n_ops: int = 4000) -> List[Scenario]:
    """CI smoke set: all eight configs, all four media classes, a scaled
    media-latency bin and a narrow GPU queue shape — small but covering
    every engine path."""
    out: List[Scenario] = []
    out += matrix(("gpu-dram", "uvm", "gds"), ("vadd", "bfs"), ("dram",),
                  n_ops=n_ops)
    out += matrix(("gds",), ("vadd",), ("znand",), n_ops=n_ops)
    out += matrix(("cxl", "cxl-naive", "cxl-dyn", "cxl-sr", "cxl-ds"),
                  ("vadd", "bfs"), ("dram", "znand"), n_ops=n_ops)
    out += matrix(("cxl-sr", "cxl-ds"), ("rsum",),
                  ("optane", "nand", "znand@2"), n_ops=n_ops)
    out += matrix(("cxl-sr",), ("vadd",), ("znand",), n_ops=n_ops,
                  mlps=(16,), store_qs=(4,))
    return list(dict.fromkeys(out))


_ENGINES = {"vector": vector_engine.run, "scalar": scalar_engine.run}


def run_scenario(s: Scenario, engine: str = "vector") -> RunResult:
    """Run one scenario on the named engine ("vector" or "scalar")."""
    return _ENGINES[engine](s.config, s.workload, s.media, n_ops=s.n_ops,
                            mlp=s.mlp, store_q=s.store_q, seed=s.seed)


def _result_row(s: Scenario, r: RunResult) -> Dict:
    return {"config": s.config, "workload": s.workload, "media": s.media,
            "n_ops": s.n_ops, "mlp": s.mlp, "store_q": s.store_q,
            "exec_ns": float(r.exec_ns),
            "latency_per_op": float(r.latency_per_op),
            "ep_hit_rate": float(r.ep_hit_rate), "sr": r.sr, "ds": r.ds}


def _worker(args: Tuple[Scenario, str]) -> Tuple[str, Dict]:
    s, engine = args
    return s.key, _result_row(s, run_scenario(s, engine))


def run_sweep(scenarios: Iterable[Scenario], engine: str = "vector",
              workers: int = 0) -> Dict[str, Dict]:
    """Fan a scenario list out; returns {scenario.key: result row}.

    workers=0 runs in-process (traces/LLC masks shared via the bundle
    cache); workers>1 uses a process pool, with scenarios grouped by
    workload so each worker still amortizes precomputation.
    """
    scenarios = list(scenarios)
    if workers and workers > 1:
        import multiprocessing as mp

        grouped = sorted(scenarios,
                         key=lambda s: (s.workload, s.n_ops, s.seed))
        with mp.Pool(workers) as pool:
            chunk = max(1, len(grouped) // (workers * 4))
            pairs = pool.map(_worker, [(s, engine) for s in grouped],
                             chunksize=chunk)
        rows = dict(pairs)
        return {s.key: rows[s.key] for s in scenarios}
    return dict(_worker((s, engine)) for s in scenarios)


def bench(scenarios: Iterable[Scenario], *, compare: bool = True,
          equivalence_sample: Optional[int] = None,
          workers: int = 0) -> Dict:
    """Perf/accuracy harness -> BENCH_sim.json payload.

    Replays the matrix on the vectorized engine (timed), optionally on
    the scalar oracle (timed), and checks per-scenario cycle-total
    equivalence. ``equivalence_sample`` limits the oracle replay to the
    first N scenarios (CI smoke); ``compare=False`` skips it entirely.
    """
    scenarios = list(scenarios)

    t0 = time.perf_counter()
    rows = run_sweep(scenarios, engine="vector")
    vector_s = time.perf_counter() - t0

    fanout_s = None
    workers = workers or (os.cpu_count() or 1)
    if workers > 1:
        t0 = time.perf_counter()
        run_sweep(scenarios, engine="vector", workers=workers)
        fanout_s = time.perf_counter() - t0

    scalar_s = None
    eq: Dict[str, float] = {}
    if compare:
        sample = scenarios if equivalence_sample is None \
            else scenarios[:equivalence_sample]
        t0 = time.perf_counter()
        for s in sample:
            r = run_scenario(s, engine="scalar")
            ref = float(r.exec_ns)
            got = rows[s.key]["exec_ns"]
            eq[s.key] = float(abs(got - ref) / max(abs(ref), 1e-12))
        scalar_s = time.perf_counter() - t0

    out = {
        "matrix": {"n_scenarios": len(scenarios),
                   "n_ops": scenarios[0].n_ops if scenarios else 0,
                   "cpu_count": os.cpu_count()},
        "perf": {
            "vector_s": round(vector_s, 4),
            "vector_fanout_s": (round(fanout_s, 4)
                                if fanout_s is not None else None),
            "fanout_workers": workers if fanout_s is not None else None,
            "scalar_s": (round(scalar_s, 4)
                         if scalar_s is not None else None),
            "engine_speedup": (round(scalar_s / vector_s, 2)
                               if scalar_s and len(eq) == len(scenarios)
                               else None),
        },
        "accuracy": {
            "compared": len(eq),
            "max_rel_err": float(max(eq.values())) if eq else None,
            "tolerance": 0.01,
            "pass": bool(max(eq.values()) <= 0.01) if eq else None,
        },
        "results": rows,
    }
    if eq:
        worst = sorted(eq.items(), key=lambda kv: -kv[1])[:5]
        out["accuracy"]["worst"] = [
            {"scenario": k, "rel_err": v} for k, v in worst]
    return out


# page-trace closed-form scenarios (the ``page_trace`` section of
# BENCH_sim.json): synthetic tier-style op traces priced by both the
# vectorized closed form and the scalar oracle. The async configs are
# the ones the in-flight-cap issue-stall recurrence vectorizes; they
# carry the >= 5x wall-time speedup gate.
PAGE_TRACE_SCENARIOS = {
    "blocking-1port": {"ports": ("dram",), "async_frac": 0.0},
    "async-1port": {"ports": ("dram",), "async_frac": 0.6},
    "async-3port": {"ports": ("dram", "dram@2", "dram@4"),
                    "async_frac": 0.6},
}


def _synth_page_trace(ports: Sequence[str], n_ops: int,
                      async_frac: float, seed: int = 0) -> List[tuple]:
    """Deterministic synthetic page trace in ``CxlTier.ops`` format:
    ``(kind, addr, nbytes)`` tuples, port-tagged 4-tuples when more than
    one port is given (advance records use port -1, dt in nbytes)."""
    import random
    rng = random.Random(seed)
    tagged = len(ports) > 1
    ops: List[tuple] = []
    base = [0] * len(ports)
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.10:
            rec = (scalar_engine.PAGE_ADVANCE, 0, rng.randrange(500, 3000))
            ops.append((-1,) + rec if tagged else rec)
            continue
        port = rng.randrange(len(ports))
        nbytes = rng.randrange(1 << 10, 48 << 10)
        if r < 0.15:
            kind = scalar_engine.PAGE_PREFETCH
        elif r < 0.55:
            kind = scalar_engine.PAGE_READ_ASYNC \
                if rng.random() < async_frac else scalar_engine.PAGE_READ
        else:
            kind = scalar_engine.PAGE_WRITE_ASYNC \
                if rng.random() < async_frac else scalar_engine.PAGE_WRITE
        addr = base[port]
        base[port] += -(-nbytes // 4096) * 4096
        rec = (kind, addr, nbytes)
        ops.append((port,) + rec if tagged else rec)
    return ops


def page_trace_bench(n_ops: int = 4000) -> Dict:
    """Closed-form vs scalar-oracle page-trace replay (``page_trace``
    section of BENCH_sim.json).

    Per scenario: both engines price one synthetic trace; gates per-op
    max rel err <= 1% everywhere and a >= 5x wall-time speedup on the
    async configs (the blocking config collapses to pure algebra, so
    its speedup is reported but not gated).
    """
    scens = {}
    for name, spec in PAGE_TRACE_SCENARIOS.items():
        ports = spec["ports"]
        ops = _synth_page_trace(ports, n_ops, spec["async_frac"])
        tagged = len(ports) > 1
        t0 = time.perf_counter()
        vec = vector_engine.page_trace_closed_form(
            ops, list(ports) if tagged else ports[0])
        vector_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        oracle = scalar_engine.replay_page_trace(
            ops, media=ports[0], topology=list(ports) if tagged else None)
        scalar_s = time.perf_counter() - t0
        rel = float(np.max(np.abs(vec - oracle)
                           / np.maximum(np.abs(oracle), 1e-9)))
        speedup = scalar_s / max(vector_s, 1e-9)
        is_async = spec["async_frac"] > 0
        scens[name] = {
            "n_ops": len(ops),
            "ports": list(ports),
            "async": is_async,
            "max_rel_err": rel,
            "vector_s": round(vector_s, 5),
            "scalar_s": round(scalar_s, 5),
            "speedup": round(speedup, 1),
            "pass": bool(rel <= 0.01
                         and (speedup >= 5.0 if is_async else True)),
        }
    return {"scenarios": scens, "tolerance": 0.01, "speedup_floor": 5.0,
            "pass": all(s["pass"] for s in scens.values())}


def category_means(rows: Dict[str, Dict], baseline_config: str = "gpu-dram"
                   ) -> Dict[str, Dict[str, float]]:
    """Per-config mean slowdown vs the baseline config, by workload
    category (the aggregation Fig. 9's bar groups use)."""
    from repro.sim.workloads import CATEGORY

    base: Dict[Tuple[str, str], float] = {}
    for row in rows.values():
        if row["config"] == baseline_config:
            base[(row["workload"], row["media"])] = row["exec_ns"]
    agg: Dict[str, Dict[str, List[float]]] = {}
    for row in rows.values():
        b = base.get((row["workload"], "dram"))
        if not b or row["config"] == baseline_config:
            continue
        cat = CATEGORY.get(row["workload"], "other")
        agg.setdefault(row["config"], {}).setdefault(cat, []).append(
            row["exec_ns"] / b)
    return {cfg: {cat: float(np.mean(v)) for cat, v in cats.items()}
            for cfg, cats in agg.items()}
