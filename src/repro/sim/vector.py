"""Vectorized trace-replay engine.

The scalar engine (``repro.sim.engine``) replays one 64B access per Python
loop iteration; a full Fig. 9 sweep (config x workload x media) is
thousands of such replays and minutes of wall clock. This module replays
the same traces with the work hoisted out of the per-access loop:

 1. **Precomputed LLC + page masks.** LLC hit/miss (and the UVM/GDS page
    LRU) depend only on the address sequence, never on timing — so the
    masks are computed once per trace and shared by every config x media
    scenario in a sweep (``TraceBundle``).

 2. **Cumulative-sum base timeline.** Between stalls the GPU clock
    advances by a fixed per-op increment (COMPUTE_NS / LLC_NS); the whole
    no-stall timeline is one ``cumsum``. Stalls are represented as an
    additive offset stream on top of it.

 3. **Closed-form queue/bank/channel recurrences.** The HBM banks, the
    root-port transaction slots and the EP channels are FIFO servers with
    constant service time, whose completion recurrence
    ``done_i = max(a_i, done_{i-lag}) + L`` has the closed form
    ``done_i = (i+1)L + cummax(a_j - jL)`` — one vectorized cumulative-max
    pass (``repro.sim.media.channel_timeline``). The GPU's MLP /
    store-queue blocking couples back into issue times; that feedback is
    resolved by a (quickly converging) vectorized fixed-point iteration.
    This covers ``gpu-dram``, ``uvm``, ``gds`` and every ``cxl*`` config
    on DRAM-class media.

 4. **Compressed event loop** for ``cxl*`` on SSD media: the controller /
    endpoint state machines (SR windows, QoS ladder, GC feedback) are
    genuinely sequential, but only LLC *misses* (plus the background-flush
    ticks) ever reach them — compute ops and LLC hits are folded into the
    cumsum timeline and never enter Python. Controller semantics are the
    exact scalar ones (the very same ``RootPortController``/``Endpoint``
    objects drive the state), so this path is bit-identical to the scalar
    engine.

If a closed-form fixed point fails to converge (not observed on the
bundled workloads) the scalar engine is used as a fallback, so ``run``
never returns an unverified approximation.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.qos import SR_GRANULARITIES
from repro.sim import engine as se
from repro.sim import workloads as wl
from repro.sim.controller import (CXL_RTT_NS, GPU_MEM_NS,
                                  RootPortController, SRStats, TXN_SLOTS)
from repro.sim.engine import (COMPUTE_NS, FAULT_NS, LLC_LINES, LLC_NS, MLP,
                              PAGE, PCIE_NS_PER_B, STORE_Q, WARMUP_FRAC,
                              RunResult)
from repro.sim.media import DRAM, Endpoint, channel_timeline, resolve_media

_HBM_BANKS = 8
_HBM_SLOT_NS = GPU_MEM_NS / 4          # pipelined bank occupancy
_RTT2 = CXL_RTT_NS / 2

_SR_MODE = {"cxl": "off", "cxl-naive": "naive", "cxl-dyn": "dyn",
            "cxl-sr": "sr", "cxl-ds": "sr"}
CXL_CONFIGS = tuple(_SR_MODE)
ALL_CONFIGS = ("gpu-dram", "uvm", "gds") + CXL_CONFIGS


def _lru_hit_mask(keys: List[int], capacity: int) -> np.ndarray:
    """Exact LRU hit mask for an access sequence (hit -> touch, miss ->
    fill + evict-LRU), identical to engine.LRU's hit/fill pair."""
    out = np.empty(len(keys), dtype=bool)
    d: OrderedDict = OrderedDict()
    move = d.move_to_end
    pop = d.popitem
    for i, k in enumerate(keys):
        if k in d:
            move(k)
            out[i] = True
        else:
            out[i] = False
            if len(d) >= capacity:
                pop(last=False)
            d[k] = True
    return out


class TraceBundle:
    """Per-trace precomputation shared across every scenario of a sweep."""

    def __init__(self, trace: np.ndarray):
        self.trace = trace
        n = len(trace)
        kinds = np.asarray(trace["kind"])
        addrs = np.asarray(trace["addr"], dtype=np.int64)
        self.warm_i = int(n * WARMUP_FRAC)

        # base timeline: per-op increment, stalls excluded
        dt = np.where(kinds == 0, COMPUTE_NS, LLC_NS)
        self.cum = np.concatenate(([0.0], np.cumsum(dt)))

        mem = kinds != 0
        mem_idx = np.nonzero(mem)[0]
        hit = _lru_hit_mask((addrs[mem_idx] // 64).tolist(), LLC_LINES)

        self.miss_op = mem_idx[~hit]               # op index of each miss
        self.miss_addr = addrs[self.miss_op]
        self.miss_kind = kinds[self.miss_op]       # 1 load / 2 store
        self.miss_base = self.cum[self.miss_op]

        # controller background-flush ticks (compute ops at i % 16 == 0);
        # the scalar engine flushes AFTER the op's compute increment
        idx = np.arange(n)
        self.flush_op = idx[(kinds == 0) & (idx % 16 == 0)]
        self.flush_base = self.cum[self.flush_op + 1]

        self._page_masks: Dict[int, np.ndarray] = {}

    def page_hit_mask(self, page_capacity: int) -> np.ndarray:
        """UVM/GDS page-LRU hit mask over the miss sequence."""
        m = self._page_masks.get(page_capacity)
        if m is None:
            m = _lru_hit_mask((self.miss_addr // PAGE).tolist(),
                              page_capacity)
            self._page_masks[page_capacity] = m
        return m


_BUNDLES: Dict[Tuple, TraceBundle] = {}
_BUNDLES_MAX = 64


def bundle_for(workload: str, n_ops: int, working_set: int, seed: int,
               trace: Optional[np.ndarray] = None) -> TraceBundle:
    """Cached TraceBundle for a (workload, n_ops, working_set, seed)
    key; an explicit ``trace`` bypasses the cache (treat as read-only)."""
    if trace is not None:
        return TraceBundle(trace)
    key = (workload, n_ops, working_set, seed)
    b = _BUNDLES.get(key)
    if b is None:
        if len(_BUNDLES) >= _BUNDLES_MAX:
            _BUNDLES.pop(next(iter(_BUNDLES)))
        tr = wl.generate_cached(workload, n_ops, working_set, seed)
        b = _BUNDLES[key] = TraceBundle(tr)
    return b


# ---------------------------------------------------------------------------
# closed-form solver: base timeline + additive stalls + queue fixed point
# ---------------------------------------------------------------------------


def _running_kth_largest(vals: np.ndarray, m: int) -> np.ndarray:
    """out[k] = m-th largest of vals[:k] (-inf while fewer than m seen).

    This is the exact blocking value of a pop-min-when-full queue of
    depth m: the outstanding set after k pushes is provably the m largest
    completions seen so far (each push replaces the popped minimum with a
    value >= it, since a completion can never precede its issue). For the
    common case of non-decreasing completions the m-th largest of the
    prefix is simply the value m back — one vectorized shift; the bounded
    heap pass only runs for genuinely out-of-order completion streams
    (cross-channel contention).
    """
    n = len(vals)
    out = np.full(n, -np.inf)
    if n <= m:
        return out
    d = np.diff(vals)
    if not d.size or d.min() >= 0.0:        # monotone: FIFO == pop-min
        out[m:] = vals[:-m]
        return out
    h: List[float] = []
    push, replace = heapq.heappush, heapq.heapreplace
    for k, v in enumerate(vals.tolist()):
        if len(h) == m:
            out[k] = h[0]
            if v > h[0]:
                replace(h, v)
        else:
            push(h, v)
    return out


class _Solved:
    __slots__ = ("t", "done", "off", "total_off", "t_warm")


def _solve(bundle: TraceBundle, fault_extra: np.ndarray, is_load: np.ndarray,
           mlp: int, store_q: int, dones_fn,
           max_iter: int = 150) -> Optional[_Solved]:
    """Resolve issue times under MLP/store-queue blocking.

    fault_extra[k]: unconditional time added to the GPU clock by event k
    (UVM/GDS blocking faults); dones_fn(t) -> per-event completion times.

    A full queue blocks on B_k (the running depth-th largest completion of
    its kind). Given completion estimates, the sequential offset
    recurrence ``o_{k+1} = max(o_k, B_k - base_k) + F_k`` solves in closed
    form: with c = cumsum(F), ``o_k = c_k + relu(cummax_{j<k}(B_j -
    base_j - c_j))`` — one exclusive cumulative-max pass. The remaining
    coupling (completions depend on issue times) converges by fixed-point
    iteration, each round fully vectorized.
    """
    base = bundle.miss_base
    n = len(base)
    li = np.nonzero(is_load)[0]
    si = np.nonzero(~is_load)[0]
    c = np.concatenate(([0.0], np.cumsum(fault_extra)))   # prefix faults
    t = base + c[:-1]
    done = np.zeros(n)
    off = c[:-1]
    for _ in range(max_iter):
        done = dones_fn(t)
        B = np.full(n, -np.inf)
        if li.size > mlp:
            B[li] = _running_kth_largest(done[li], mlp)
        if si.size > store_q:
            B[si] = _running_kth_largest(done[si], store_q)
        g = B - base - c[:-1]
        p = np.maximum(np.maximum.accumulate(
            np.concatenate(([0.0], g)))[:-1], 0.0)        # exclusive
        off = c[:-1] + p
        t_new = np.maximum(base + off, B)
        if np.max(np.abs(t_new - t), initial=0.0) < 1e-6:
            t = t_new
            break
        t = t_new
    else:
        return None                             # no convergence: fall back
    out = _Solved()
    out.off = off
    p_total = max(float(np.max(B - base - c[:-1], initial=0.0)), 0.0) \
        if n else 0.0
    out.total_off = c[-1] + p_total
    out.t = t
    out.done = dones_fn(t)
    w = np.searchsorted(bundle.miss_op, bundle.warm_i)
    out.t_warm = bundle.cum[bundle.warm_i] + (out.off[w] if w < n
                                              else out.total_off)
    return out


def _finish(bundle: TraceBundle, sol: _Solved, config: str, media_name,
            record_samples: bool, *, ep_hit_rate: float = 0.0,
            sr: Optional[dict] = None, ds: Optional[dict] = None
            ) -> RunResult:
    t_end = bundle.cum[-1] + sol.total_off
    t_final = max(t_end, float(sol.done.max())) if len(sol.done) else t_end
    samples = None
    if record_samples:
        samples = [(float(t), float(d - t), int(k)) for t, d, k in
                   zip(sol.t, sol.done, bundle.miss_kind)]
    return RunResult(
        config=config, workload="", media=getattr(media_name, "name",
                                                  media_name),
        exec_ns=t_final - sol.t_warm,
        n_ops=len(bundle.trace) - bundle.warm_i,
        ep_hit_rate=ep_hit_rate, sr=sr, ds=ds, samples=samples)


# ---------------------------------------------------------------------------
# per-config closed forms
# ---------------------------------------------------------------------------


def _dones_gpu_dram(bundle: TraceBundle):
    """HBM: 8 pipelined banks, FCFS with constant 30ns bank occupancy —
    the lag-8 recurrence start_m = max(t_m, start_{m-8} + 30) decomposes
    into 8 independent running-max chains (residue classes)."""
    n = len(bundle.miss_base)

    def dones(t: np.ndarray) -> np.ndarray:
        start = np.empty(n)
        for r in range(_HBM_BANKS):
            u = t[r::_HBM_BANKS]
            i = np.arange(u.size)
            start[r::_HBM_BANKS] = i * _HBM_SLOT_NS + np.maximum.accumulate(
                u - i * _HBM_SLOT_NS)
        return start + GPU_MEM_NS

    return dones


def _run_uvm_gds(bundle: TraceBundle, config: str, media, gpu_mem: int,
                 mlp: int, store_q: int, record_samples: bool, media_name
                 ) -> Optional[RunResult]:
    pages_cap = max(gpu_mem // PAGE, 1)
    page_hit = bundle.page_hit_mask(pages_cap)
    move = PAGE * PCIE_NS_PER_B
    if config == "gds":
        move += media.read_ns + PAGE / media.bw_gbps
    else:
        move += DRAM.read_ns
    lat = np.where(page_hit, GPU_MEM_NS, FAULT_NS + move)
    fault_extra = np.where(page_hit, 0.0, lat)   # page misses block the GPU
    is_load = bundle.miss_kind == 1

    sol = _solve(bundle, fault_extra, is_load, mlp, store_q,
                 lambda t: t + lat)
    if sol is None:
        return None
    return _finish(bundle, sol, config, media_name, record_samples)


def _run_cxl_dram(bundle: TraceBundle, config: str, media, mlp: int,
                  store_q: int, record_samples: bool, media_name
                  ) -> Optional[RunResult]:
    """All five cxl* configs on a DRAM-class EP: SR never engages
    (``Endpoint.is_dram``), the QoS ladder stays LIGHT, and every media op
    is one constant-service channel access — fully closed-form."""
    ds = config == "cxl-ds"
    n = len(bundle.miss_base)
    is_load = bundle.miss_kind == 1
    service = media.read_ns + media.xfer_ns(64)
    chan = ((bundle.miss_addr // Endpoint.BLOCK) % media.channels).astype(
        np.int64)
    # transaction slots: demand loads always; stores only without DS
    # (DS stores are fire-and-forget dual writes that skip the root port's
    # transaction tracker)
    txn = is_load | (~is_load if not ds else np.zeros(n, bool))
    ti = np.nonzero(txn)[0]
    fault_extra = np.zeros(n)
    comp_prev = [np.zeros(n)]
    converged = [True]

    def dones(t: np.ndarray) -> np.ndarray:
        comp = comp_prev[0]
        for _ in range(40):
            arr = t + _RTT2                     # DS stores ride immediately
            if ti.size > TXN_SLOTS:
                free = _running_kth_largest(comp[ti], TXN_SLOTS)
                arr[ti] = np.maximum(t[ti], free) + _RTT2
            ep_done = channel_timeline(arr, chan, media.channels, service)
            comp_new = ep_done + _RTT2
            if ds:
                comp_new = np.where(is_load, comp_new, t + GPU_MEM_NS)
            if np.max(np.abs(comp_new - comp), initial=0.0) < 1e-9:
                comp_prev[0] = comp_new
                return comp_new
            comp = comp_new
        comp_prev[0] = comp
        converged[0] = False        # unconverged comp must not be trusted
        return comp

    # a saturated EP makes this fixed point converge slowly; bail early to
    # the exact one-pass loop instead of iterating
    sol = _solve(bundle, fault_extra, is_load, mlp, store_q, dones,
                 max_iter=8)
    if sol is None or not converged[0]:
        return None
    n_loads = int(is_load.sum())
    n_stores = n - n_loads
    ds_stats = {"fire_and_forget": n_stores if ds else 0, "diverted": 0,
                "flushed": 0, "read_through": 0, "blocked": 0}
    return _finish(bundle, sol, config, media_name, record_samples,
                   ep_hit_rate=0.0,
                   sr=dataclasses.asdict(SRStats()), ds=ds_stats)


# ---------------------------------------------------------------------------
# slim exact loops. The closed forms above cover the queue/bank/channel
# algebra; what remains sequential is driven by compressed per-miss loops
# with the scalar semantics inlined (locals instead of object dispatch).
# ``_run_cxl_events`` below keeps the object-driven form as the bridge
# oracle between these loops and the scalar engine.
# ---------------------------------------------------------------------------


def _event_arrays(bundle: TraceBundle, with_flush: bool):
    """Merged (op_idx, base_t, etype, addr) event stream in op order.
    etype: 0 background-flush tick, 1 load miss, 2 store miss."""
    if not with_flush:
        return (bundle.miss_op, bundle.miss_base, bundle.miss_kind,
                bundle.miss_addr)
    op_idx = np.concatenate((bundle.miss_op, bundle.flush_op))
    order = np.argsort(op_idx, kind="stable")
    base = np.concatenate((bundle.miss_base, bundle.flush_base))[order]
    etype = np.concatenate((bundle.miss_kind,
                            np.zeros(len(bundle.flush_op), np.uint8)))[order]
    addr = np.concatenate((bundle.miss_addr,
                           np.zeros(len(bundle.flush_op), np.int64)))[order]
    return op_idx[order], base, etype, addr


def _run_cxl_dram_loop(bundle: TraceBundle, config: str, media, mlp: int,
                       store_q: int, record_samples: bool, media_name
                       ) -> RunResult:
    """Exact one-pass loop for cxl* on a DRAM-class EP (fallback when the
    closed form's fixed point is slow to converge, i.e. saturated EPs).

    On a DRAM-class EP the SR engine never engages and the QoS ladder
    pins LIGHT, so the whole controller reduces to the transaction-slot
    heap plus the channel busy array — a handful of operations per miss.
    """
    ds = config == "cxl-ds"
    n_chan = media.channels
    l_read = media.read_ns + media.xfer_ns(64)
    l_write = media.write_ns + media.xfer_ns(64)
    chan_busy = [0.0] * n_chan
    txn = [0.0] * TXN_SLOTS
    heapq.heapify(txn)
    op_l, base, etype, addr_a = _event_arrays(bundle, with_flush=False)
    op_list = op_l.tolist()
    base_l = base.tolist()
    etype_l = etype.tolist()
    chan_l = ((addr_a // Endpoint.BLOCK) % n_chan).tolist()
    push, pop, pushpop = heapq.heappush, heapq.heappop, heapq.heappushpop

    warm_i = bundle.warm_i
    warm_off: Optional[float] = None
    offset = 0.0
    loads_q: List[float] = []
    stores_q: List[float] = []
    samples: List[Tuple[float, float, int]] = []
    n_loads = n_stores = 0

    for j in range(len(op_list)):
        if warm_off is None and op_list[j] >= warm_i:
            warm_off = offset
        t = base_l[j] + offset
        c = chan_l[j]
        if etype_l[j] == 1:
            n_loads += 1
            if len(loads_q) >= mlp:
                d = pop(loads_q)
                if d > t:
                    offset += d - t
                    t = d
            free = txn[0]
            arrival = (t if t > free else free) + _RTT2
            busy = chan_busy[c]
            e = (arrival if arrival > busy else busy) + l_read
            chan_busy[c] = e
            done = e + _RTT2
            pushpop(txn, done)
            push(loads_q, done)
            if record_samples:
                samples.append((t, done - t, 1))
        else:
            n_stores += 1
            if len(stores_q) >= store_q:
                d = pop(stores_q)
                if d > t:
                    offset += d - t
                    t = d
            if ds:              # fire-and-forget dual write
                busy = chan_busy[c]
                arr = t + _RTT2
                chan_busy[c] = (arr if arr > busy else busy) + l_write
                done = t + GPU_MEM_NS
            else:
                free = txn[0]
                arrival = (t if t > free else free) + _RTT2
                busy = chan_busy[c]
                e = (arrival if arrival > busy else busy) + l_write
                chan_busy[c] = e
                done = e + _RTT2
                pushpop(txn, done)
            push(stores_q, done)
            if record_samples:
                samples.append((t, done - t, 2))

    if warm_off is None:
        warm_off = offset
    t_final = bundle.cum[-1] + offset
    for q in (loads_q, stores_q):
        if q:
            t_final = max(t_final, max(q))
    ds_stats = {"fire_and_forget": n_stores if ds else 0, "diverted": 0,
                "flushed": 0, "read_through": 0, "blocked": 0}
    return RunResult(
        config=config, workload="",
        media=getattr(media_name, "name", media_name),
        exec_ns=t_final - (bundle.cum[warm_i] + warm_off),
        n_ops=len(bundle.trace) - warm_i, ep_hit_rate=0.0,
        sr=dataclasses.asdict(SRStats()), ds=ds_stats,
        samples=samples if record_samples else None)


def _run_cxl_ssd(bundle: TraceBundle, config: str, media, gpu_mem: int,
                 mlp: int, store_q: int, record_samples: bool, media_name
                 ) -> RunResult:
    """Compressed exact replay for cxl* on SSD media.

    Only LLC misses (and, with DS, the background-flush ticks) carry
    controller/endpoint state; they are replayed here with the
    ``RootPortController``/``Endpoint``/``QoSController`` semantics
    inlined into one loop over precomputed event arrays — no attribute
    dispatch, no dead bookkeeping (the root-port shadow queues, the
    prefetch-depth knob). ``_run_cxl_events`` keeps the object-driven
    form; the equivalence tests pin all three engines to identical cycle
    totals.
    """
    smode = ("off", "naive", "dyn", "sr").index(_SR_MODE[config])
    ds = config == "cxl-ds"
    # With SR and DS both off, the QoS ladder and the demand-pressure EWMA
    # feed nothing observable — only devload's GC-fire side effect stays
    # live. The loop below skips the dead updates in that case.
    qos_live = smode != 0 or ds

    # ---- endpoint state (media + internal DRAM cache)
    BLOCK = Endpoint.BLOCK
    n_chan = media.channels
    read_ns, write_ns, bw = media.read_ns, media.write_ns, media.bw_gbps
    gc_every, gc_ns = media.gc_every_bytes, media.gc_ns
    gc_thresh = 0.97 * gc_every
    cache: OrderedDict = OrderedDict()
    cache_get, cache_mte = cache.get, cache.move_to_end
    cache_pop = cache.popitem
    cache_cap = max((gpu_mem // 4) // BLOCK, 1)
    chan_busy = [0.0] * n_chan
    mshr = 0.0
    pressure = 0.0
    pressure_t = 0.0
    tau = 10.0 * (read_ns + 1.0)
    write_accum = 0
    written = 0
    gc_until = 0.0
    gc_start = 0.0
    last_write = 0.0
    n_reads = n_writes = n_hits = n_pref = n_gc = n_evict = n_fetch = 0
    DR55 = DRAM.read_ns
    DRX = DRAM.xfer_ns(64)
    DW55 = DRAM.write_ns
    ingress_limit = 64 * write_ns / 8          # ingress_depth = 64
    exp = math.exp

    # ---- controller state
    GRAN = SR_GRANULARITIES
    g_idx = GRAN.index(512)
    sr_halted = False
    flush_enabled = True
    ring: deque = deque()
    cov: Dict[int, int] = {}
    cov_shift = 6 if smode == 1 else 8
    sr_issued = sr_deduped = sr_halt_n = sr_bytes = 0
    last_addr: Optional[int] = None
    dir_ewma = 0.0
    staging: List[int] = []
    staging_index: Dict[int, int] = {}
    staging_cap = 16384
    txn = [0.0] * TXN_SLOTS
    heapq.heapify(txn)
    ds_faf = ds_div = ds_flu = ds_rt = ds_blk = 0

    def media_fetch(now: float, addr: int, nbytes: int,
                    write: bool) -> float:
        nonlocal n_fetch
        n_fetch += 1
        c = (addr // BLOCK) % n_chan
        b = chan_busy[c]
        start = now if now > b else b
        if gc_until > start:
            start = gc_until
        done = start + (write_ns if write else read_ns) + nbytes / bw
        chan_busy[c] = done
        return done

    def devload(now: float) -> int:
        """DevLoad with the endpoint's side effects (announced internal
        tasks fire once the write stream pauses; pressure decays)."""
        nonlocal written, gc_until, gc_start, n_gc, pressure, pressure_t
        if gc_every and written >= gc_thresh:
            if now - last_write > 8 * write_ns:
                written = 0
                n_gc += 1
                gc_start = now
                gc_until = now + gc_ns
            return 3                                     # SEVERE
        if now < gc_until:
            return 3
        if not qos_live:         # pressure feeds nothing observable
            return 0
        dt = now - pressure_t
        pressure_t = now
        if pressure != 0.0:
            pressure *= exp(-(dt if dt > 0.0 else 0.0) / tau)
        p = pressure
        if p > 3.0:
            return 3
        if p > 1.0:
            return 2
        if p > 0.25:
            return 1
        return 0

    def ep_write(now: float, addr: int) -> float:
        nonlocal n_writes, last_write, written, gc_until, gc_start, n_gc, \
            write_accum, n_evict
        n_writes += 1
        last_write = now
        written += 64
        if now < gc_until:       # mid-reclaim write thrashes the task
            g2 = gc_until + write_ns
            g3 = gc_start + 3 * gc_ns
            gc_until = g2 if g2 < g3 else g3
        if gc_every and written >= gc_every:
            written = 0
            n_gc += 1
            mx = max(chan_busy)
            s = now if now > mx else mx
            gc_start = s
            gc_until = s + gc_ns
        block = addr // BLOCK
        if block in cache:       # write-back fill: keep earliest ready
            cache_mte(block)
            old = cache[block]
            if now < old:
                cache[block] = now
        else:
            if len(cache) >= cache_cap:
                cache_pop(last=False)
                n_evict += 1
            cache[block] = now
        write_accum += 64
        flush_done = now
        if write_accum >= 4096:  # coalesced 4 KiB media program
            write_accum -= 4096
            flush_done = media_fetch(now, addr, 4096, True)
        backlog = sum(chan_busy) / n_chan - now
        if now < gc_until or backlog > ingress_limit:
            return flush_done if flush_done > gc_until else gc_until
        m = now if now > gc_until else gc_until
        return m + DW55

    def ep_prefetch(now: float, start_addr: int, nbytes: int) -> None:
        nonlocal n_pref, n_evict
        first = start_addr // BLOCK
        last = (start_addr + (nbytes if nbytes > 1 else 1) - 1) // BLOCK
        missing: List[int] = []
        for b in range(first, last + 1):
            if b in cache:
                cache_mte(b)
            else:
                missing.append(b)
        if not missing:
            return
        n_pref += 1
        s0 = prev = missing[0]
        for b in missing[1:]:
            if b != prev + 1:
                d = media_fetch(now, s0 * BLOCK, (prev - s0 + 1) * BLOCK,
                                False)
                for bb in range(s0, prev + 1):
                    if len(cache) >= cache_cap:
                        cache_pop(last=False)
                        n_evict += 1
                    cache[bb] = d
                s0 = b
            prev = b
        d = media_fetch(now, s0 * BLOCK, (prev - s0 + 1) * BLOCK, False)
        for bb in range(s0, prev + 1):
            if len(cache) >= cache_cap:
                cache_pop(last=False)
                n_evict += 1
            cache[bb] = d

    op_l, base, etype, addr_a = _event_arrays(bundle, with_flush=ds)
    op_list = op_l.tolist()
    base_l = base.tolist()
    etype_l = etype.tolist()
    addr_l = addr_a.tolist()
    push, pop, pushpop = heapq.heappush, heapq.heappop, heapq.heappushpop

    warm_i = bundle.warm_i
    warm_off: Optional[float] = None
    offset = 0.0
    loads_q: List[float] = []
    stores_q: List[float] = []
    samples: List[Tuple[float, float, int]] = []

    for j in range(len(op_list)):
        if warm_off is None and op_list[j] >= warm_i:
            warm_off = offset
        t = base_l[j] + offset
        et = etype_l[j]

        if et == 0:                              # ---- background flush
            if staging and flush_enabled and devload(t) < 2:
                for _ in range(16 if len(staging) >= 16 else len(staging)):
                    a2 = staging.pop()
                    staging_index.pop(a2, None)
                    ep_write(t, a2)
                    ds_flu += 1
            continue

        addr = addr_l[j]

        if et == 1:                              # ---- load miss
            if len(loads_q) >= mlp:
                d = pop(loads_q)
                if d > t:
                    offset += d - t
                    t = d
            if ds and addr in staging_index:
                ds_rt += 1
                done = t + GPU_MEM_NS
            else:
                if smode:                        # --- SR flit generation
                    last = last_addr
                    last_addr = addr
                    if sr_halted and smode >= 2:
                        sr_halt_n += 1
                    else:
                        g = GRAN[g_idx]
                        start = -1
                        end = 0
                        if smode == 1:           # naive: one 64B MemSpecRd
                            if (addr >> 6) in cov:
                                sr_deduped += 1
                            else:
                                start = addr - addr % 64
                                end = start + 64
                        elif smode == 2:         # dyn: run-ahead window
                            if (addr >> 8) in cov and \
                                    ((addr + g // 2) >> 8) in cov:
                                sr_deduped += 1
                            else:
                                a = addr - addr % 256
                                for _p in range(16):
                                    if (a >> 8) not in cov:
                                        break
                                    a += 256
                                start = a
                                end = a + g
                        else:                    # sr: queue-derived window
                            if last is not None and addr != last:
                                dir_ewma = 0.9 * dir_ewma \
                                    + (0.1 if addr > last else -0.1)
                            dd = dir_ewma
                            if dd < -0.3:        # backward run
                                probe = addr - g // 2
                                if probe < 0:
                                    probe = 0
                                if (addr >> 8) in cov and \
                                        (probe >> 8) in cov:
                                    sr_deduped += 1
                                else:
                                    start = addr - addr % 256 - g + 256
                                    if start < 0:
                                        start = 0
                                    end = start + g
                            elif dd > 0.3:       # forward run
                                if (addr >> 8) in cov and \
                                        ((addr + g // 2) >> 8) in cov:
                                    sr_deduped += 1
                                else:
                                    a = addr - addr % 256
                                    for _p in range(16):
                                        if (a >> 8) not in cov:
                                            break
                                        a += 256
                                    start = a
                                    end = a + g
                            else:                # Around: centred window
                                lo = addr - g // 2
                                if lo < 0:
                                    lo = 0
                                if (lo >> 8) in cov and (addr >> 8) in cov \
                                        and ((addr + g // 2) >> 8) in cov:
                                    sr_deduped += 1
                                else:
                                    s2 = addr - g // 2
                                    start = s2 - s2 % 256
                                    if start < 0:
                                        start = 0
                                    end = start + g
                        if start >= 0:
                            ep_prefetch(t, start, end - start)
                            if len(ring) == 64:
                                s0_, e0_ = ring.popleft()
                                for u in range(s0_ >> cov_shift,
                                               e0_ >> cov_shift):
                                    nv = cov[u] - 1
                                    if nv:
                                        cov[u] = nv
                                    else:
                                        del cov[u]
                            ring.append((start, end))
                            for u in range(start >> cov_shift,
                                           end >> cov_shift):
                                cov[u] = cov.get(u, 0) + 1
                            sr_issued += 1
                            sr_bytes += end - start
                free = txn[0]
                now = (t if t > free else free) + _RTT2
                # --- ep.read, inlined (the loop's hottest path)
                n_reads += 1
                block = addr // BLOCK
                ready = cache_get(block)
                if ready is not None:
                    cache_mte(block)
                    if ready <= now:
                        n_hits += 1
                    m = now if now > ready else ready
                    done = m + DR55 + DRX + _RTT2
                else:
                    start2 = now if now > mshr else mshr
                    fetched = media_fetch(start2, addr, BLOCK, False)
                    mshr = fetched
                    if len(cache) >= cache_cap:
                        cache_pop(last=False)
                        n_evict += 1
                    cache[block] = fetched
                    if qos_live:
                        wait = (start2 - now) / (read_ns + 1.0)
                        dt = now - pressure_t
                        pressure_t = now
                        if pressure != 0.0:
                            pressure *= exp(
                                -(dt if dt > 0.0 else 0.0) / tau)
                        pressure = 0.75 * pressure + 0.25 * wait
                    done = fetched + DR55 + _RTT2
                pushpop(txn, done)
                # --- devload + qos.update, inlined
                if gc_every and written >= gc_thresh:
                    if done - last_write > 8 * write_ns:
                        written = 0
                        n_gc += 1
                        gc_start = done
                        gc_until = done + gc_ns
                    dl = 3
                elif done < gc_until:
                    dl = 3
                elif not qos_live:
                    dl = 0
                else:
                    dt = done - pressure_t
                    pressure_t = done
                    if pressure != 0.0:
                        pressure *= exp(-(dt if dt > 0.0 else 0.0) / tau)
                    p = pressure
                    dl = 3 if p > 3.0 else 2 if p > 1.0 \
                        else 1 if p > 0.25 else 0
                if dl == 0:
                    sr_halted = False
                    flush_enabled = True
                    if g_idx < 3:
                        g_idx += 1
                elif dl == 1:
                    flush_enabled = True
                elif dl == 2:
                    if g_idx > 0:
                        g_idx -= 1
                    flush_enabled = False
                else:
                    sr_halted = True
                    flush_enabled = False
                    g_idx = 0
            push(loads_q, done)
            if record_samples:
                samples.append((t, done - t, 1))

        else:                                    # ---- store miss
            if len(stores_q) >= store_q:
                d = pop(stores_q)
                if d > t:
                    offset += d - t
                    t = d
            qos_dl = -1
            if not ds:
                free = txn[0]
                arrival = (t if t > free else free) + _RTT2
                done = ep_write(arrival, addr) + _RTT2
                pushpop(txn, done)
                qos_dl = devload(done)
            else:
                congested = not flush_enabled
                if not congested:
                    congested = bool(gc_every) and written >= gc_thresh
                if not congested:
                    congested = devload(t) >= 2
                if congested:
                    if len(staging) >= staging_cap:
                        ds_blk += 1       # staging exhausted: plain store
                        free = txn[0]
                        arrival = (t if t > free else free) + _RTT2
                        done = ep_write(arrival, addr) + _RTT2
                        pushpop(txn, done)
                        qos_dl = devload(done)
                    else:
                        staging.append(addr)
                        staging_index[addr] = len(staging) - 1
                        ds_div += 1
                        done = t + GPU_MEM_NS
                else:
                    ds_faf += 1           # dual write: EP copy rides along
                    ep_write(t + _RTT2, addr)
                    qos_dl = devload(t)
                    done = t + GPU_MEM_NS
            if qos_dl >= 0:
                if qos_dl == 0:
                    sr_halted = False
                    flush_enabled = True
                    if g_idx < 3:
                        g_idx += 1
                elif qos_dl == 1:
                    flush_enabled = True
                elif qos_dl == 2:
                    if g_idx > 0:
                        g_idx -= 1
                    flush_enabled = False
                else:
                    sr_halted = True
                    flush_enabled = False
                    g_idx = 0
            push(stores_q, done)
            if record_samples:
                samples.append((t, done - t, 2))

    if warm_off is None:
        warm_off = offset
    t_final = bundle.cum[-1] + offset
    for q in (loads_q, stores_q):
        if q:
            t_final = max(t_final, max(q))
    sr_stats = {"issued": sr_issued, "deduped": sr_deduped,
                "halted": sr_halt_n, "bytes": sr_bytes}
    ds_stats = {"fire_and_forget": ds_faf, "diverted": ds_div,
                "flushed": ds_flu, "read_through": ds_rt, "blocked": ds_blk}
    return RunResult(
        config=config, workload="",
        media=getattr(media_name, "name", media_name),
        exec_ns=t_final - (bundle.cum[bundle.warm_i] + warm_off),
        n_ops=len(bundle.trace) - bundle.warm_i,
        ep_hit_rate=(n_hits / n_reads if n_reads else 0.0),
        sr=sr_stats, ds=ds_stats,
        samples=samples if record_samples else None)


# ---------------------------------------------------------------------------
# compressed event loop (cxl* on SSD media): exact controller state machine
# ---------------------------------------------------------------------------


def _run_cxl_events(bundle: TraceBundle, config: str, media, gpu_mem: int,
                    mlp: int, store_q: int, record_samples: bool, media_name
                    ) -> RunResult:
    ep = Endpoint(media, dram_cache_bytes=gpu_mem // 4)
    ctl = RootPortController(ep, sr_mode=_SR_MODE[config],
                             ds_enabled=(config == "cxl-ds"))

    op_idx, base, etype, addr_a = _event_arrays(bundle, with_flush=True)
    addr_l = addr_a.tolist()
    base_l = base.tolist()
    etype_l = etype.tolist()
    op_l = op_idx.tolist()

    warm_i = bundle.warm_i
    warm_off: Optional[float] = None
    offset = 0.0
    loads_q: List[float] = []
    stores_q: List[float] = []
    samples: List[Tuple[float, float, int]] = []
    load, store, flush = ctl.load, ctl.store, ctl.background_flush
    push, pop = heapq.heappush, heapq.heappop

    for j in range(len(op_l)):
        if warm_off is None and op_l[j] >= warm_i:
            warm_off = offset
        t = base_l[j] + offset
        et = etype_l[j]
        if et == 0:
            flush(t)
            continue
        addr = addr_l[j]
        if et == 1:
            if len(loads_q) >= mlp:
                d = pop(loads_q)
                if d > t:
                    offset += d - t
                    t = d
            done = load(t, addr)
            push(loads_q, done)
            if record_samples:
                samples.append((t, done - t, 1))
        else:
            if len(stores_q) >= store_q:
                d = pop(stores_q)
                if d > t:
                    offset += d - t
                    t = d
            done = store(t, addr)
            push(stores_q, done)
            if record_samples:
                samples.append((t, done - t, 2))

    if warm_off is None:
        warm_off = offset
    t_final = bundle.cum[-1] + offset
    for q in (loads_q, stores_q):
        if q:
            t_final = max(t_final, max(q))
    t_warm = bundle.cum[warm_i] + warm_off
    return RunResult(
        config=config, workload="",
        media=getattr(media_name, "name", media_name),
        exec_ns=t_final - t_warm, n_ops=len(bundle.trace) - warm_i,
        ep_hit_rate=ep.hit_rate(),
        sr=dataclasses.asdict(ctl.sr_stats), ds=dict(ctl.ds_stats),
        samples=samples if record_samples else None)


def _saturated(bundle: TraceBundle, config: str, media) -> bool:
    """Cheap pre-test: when demand approaches EP-channel or root-port
    transaction capacity, the closed form's fixed point converges slowly
    (queueing couples every event); go straight to the one-pass loop."""
    n = len(bundle.miss_base)
    span = float(bundle.cum[-1])
    if n == 0 or span <= 0.0:
        return False
    service = media.read_ns + media.xfer_ns(64)
    util_chan = n * service / (media.channels * span)
    n_txn = n if config != "cxl-ds" else int((bundle.miss_kind == 1).sum())
    util_txn = n_txn * (service + CXL_RTT_NS) / (TXN_SLOTS * span)
    return max(util_chan, util_txn) > 0.5


# ---------------------------------------------------------------------------
# public API — signature-compatible with repro.sim.engine.run
# ---------------------------------------------------------------------------


def run(config: str, workload: str, media_name="dram", *,
        n_ops: int = 60_000, gpu_mem_frac: float = 0.1,
        working_set: int = 640 << 20, seed: int = 0,
        record_samples: bool = False, mlp: int = MLP,
        store_q: int = STORE_Q,
        trace: Optional[np.ndarray] = None) -> RunResult:
    """Vectorized replay. Same contract as ``repro.sim.engine.run``."""
    bundle = bundle_for(workload, n_ops, working_set, seed, trace)
    media = resolve_media(media_name)
    gpu_mem = int(working_set * gpu_mem_frac)
    out: Optional[RunResult] = None

    if config == "gpu-dram":
        sol = _solve(bundle, np.zeros(len(bundle.miss_base)),
                     bundle.miss_kind == 1, mlp, store_q,
                     _dones_gpu_dram(bundle))
        if sol is not None:
            out = _finish(bundle, sol, config, media_name, record_samples)
    elif config in ("uvm", "gds"):
        out = _run_uvm_gds(bundle, config, media, gpu_mem, mlp, store_q,
                           record_samples, media_name)
    elif config in _SR_MODE:
        # Endpoint.is_dram media: SR/QoS never engage, closed form applies
        # (lockstep with Endpoint.is_dram: scaled DRAM stays DRAM-class)
        dram_class = media.gc_every_bytes == 0
        if dram_class and media.read_ns == media.write_ns \
                and not _saturated(bundle, config, media):
            out = _run_cxl_dram(bundle, config, media, mlp, store_q,
                                record_samples, media_name)
        if out is None:
            if dram_class:
                out = _run_cxl_dram_loop(bundle, config, media, mlp,
                                         store_q, record_samples,
                                         media_name)
            else:
                out = _run_cxl_ssd(bundle, config, media, gpu_mem, mlp,
                                   store_q, record_samples, media_name)
    else:
        raise ValueError(config)

    if out is None:                 # fixed point did not converge: oracle
        return se.run(config, workload, media_name, n_ops=n_ops,
                      gpu_mem_frac=gpu_mem_frac, working_set=working_set,
                      seed=seed, record_samples=record_samples, mlp=mlp,
                      store_q=store_q, trace=trace)
    out.workload = workload
    return out


# ---------------------------------------------------------------------------
# Closed-form page-trace latencies (DRAM-class EP)
# ---------------------------------------------------------------------------

def _chan_store(ch, nc, addr, w, s, sw, rb):
    """Exact EP-channel busy updates for one deterministic-store page op.

    A fire-and-forget store completes GPU-side at ``GPU_MEM_NS`` but its
    EP-side media write still occupies the owning channel
    (``Endpoint._media_fetch`` sets ``chan_busy[c] = max(arrival, busy)
    + write_ns + xfer``). ``ch`` is the port's channel-busy vector,
    ``s`` the op's service-walk start, ``sw`` the EP-side write service
    time. Requests walk ``addr`` in ``rb``-byte steps at ``GPU_MEM_NS``
    cadence, cycling channels with period ``nc / gcd(rb // BLOCK, nc)``;
    only each channel's last hit persists unless hits chain (service
    time exceeding the revisit gap), which falls back to the exact
    per-hit recurrence."""
    half = CXL_RTT_NS / 2.0
    blk = Endpoint.BLOCK
    if rb % blk == 0:
        stride = rb // blk
        per = nc // math.gcd(stride, nc)
        gap = per * GPU_MEM_NS
        c0 = (addr // blk) % nc
        for j in range(w if w < per else per):
            c = (c0 + j * stride) % nc
            hits = (w - 1 - j) // per + 1
            a0 = s + j * GPU_MEM_NS + half
            r = ch[c]
            b = (r if r > a0 else a0) + sw
            if hits > 1:
                if b <= a0 + gap:       # no chaining: last hit wins
                    b = a0 + (hits - 1) * gap + sw
                else:                   # chained hits: exact recurrence
                    for m in range(1, hits):
                        a = a0 + m * gap
                        b = (b if b > a else a) + sw
            ch[c] = b
    else:                               # irregular stride: walk requests
        for i in range(w):
            c = ((addr + i * rb) // blk) % nc
            a = s + i * GPU_MEM_NS + half
            r = ch[c]
            ch[c] = (r if r > a else a) + sw


def _chan_load_wait(ch, nc, addr, w, s, dreq, rb):
    """Exact cumulative queueing a demand-read page op pays to residual
    channel occupancy left by fire-and-forget stores.

    Each request arrives ``CXL_RTT/2`` after its cursor slot and queues
    behind ``chan_busy`` (``Endpoint._media_fetch``); a wait shifts every
    later request of the op by the same amount. Only a channel's first
    hit can wait — the read's own fetch then re-stamps the channel with
    a completion the serialized walk has already passed, so touched
    channels are cleared. Returns the total shift (ns) to add to the
    op's service time."""
    half = CXL_RTT_NS / 2.0
    blk = Endpoint.BLOCK
    shift = 0.0
    if rb % blk == 0:
        stride = rb // blk
        per = nc // math.gcd(stride, nc)
        c0 = (addr // blk) % nc
        for j in range(w if w < per else per):
            c = (c0 + j * stride) % nc
            r = ch[c]
            if r > 0.0:
                a = s + shift + j * dreq + half
                if r > a:
                    shift += r - a
                ch[c] = 0.0
    else:
        for i in range(w):
            c = ((addr + i * rb) // blk) % nc
            r = ch[c]
            if r > 0.0:
                a = s + shift + i * dreq + half
                if r > a:
                    shift += r - a
                ch[c] = 0.0
    return shift


def page_trace_closed_form(ops, media_name="dram", *, ds: bool = True,
                           req_bytes: int = 256,
                           max_inflight: int = se.MAX_INFLIGHT_OPS
                           ) -> np.ndarray:
    """Closed-form per-op latencies for a page trace on DRAM-class EPs —
    the vectorized cross-check for the serving tier's ``dram`` media bin
    and for the DRAM-EP lanes of a multi-port topology. Covers blocking
    *and* async (``issue``/``poll``) op kinds; fault-annotated kinds are
    rejected (see below).

    Valid because a stream on a DRAM EP never queues inside the
    controller: every demand request finds its transaction slot free and
    the staging stack empty (DRAM DevLoad is always LIGHT), so each 64B
    CXL.mem request costs exactly

        read:   CXL_RTT + read_ns + xfer(64B)
        write:  GPU_MEM_NS              (deterministic store, dual write)
                CXL_RTT + write_ns + xfer(64B)   (ds disabled)

    and a page op of ``ceil(nbytes / req_bytes)`` requests is that many
    multiples — plus one exactly-modeled EP-side coupling: on scaled
    DRAM bins where a deterministic store's media write outlasts its
    GPU-side completion (``write_ns + xfer > GPU_MEM_NS``, e.g.
    ``dram@4``), the fire-and-forget write leaves residual channel
    occupancy that a closely-following demand read on the same channels
    queues behind (``Endpoint.chan_busy``). The closed form tracks
    per-port channel busy state and charges those waits exactly
    (:func:`_chan_store` / :func:`_chan_load_wait`, O(channels) per
    affected op); bins where the residual cannot outlive the request
    cadence skip the bookkeeping entirely. The same per-op algebra
    holds per *port* of a multi-port topology: ports front independent
    EPs, so each lane's ops cost the same whether or not other lanes
    run concurrently — pass port-tagged ``(port, kind, addr, nbytes)``
    ops plus a sequence of per-port media specs as ``media_name``.

    Blocking-only traces with no channel coupling collapse to pure
    per-op algebra (no clock state at all). Otherwise the scan keeps two
    scalars of state per port — the stream clock ``t`` and the service
    cursor ``u`` — plus, for async kinds, the in-flight cap's
    issue-stall recurrence ``wait_m = max(0, d_{m-cap} - t)`` against
    the port's (monotone) async completion times ``d``; request costs,
    per-port async ordinals and cap-lag taps are all precomputed
    vectorized, leaving an O(1)-per-op scan (no per-request controller
    walk, no heaps — the scalar oracle pays both).

    Prefetch ops are free on the demand path (SR never engages on a DRAM
    EP); advance ops carry ``dt`` ns in the nbytes slot and move the
    clocks (syncing ports first, as ``Topology.advance`` does). Raises
    ``ValueError`` for media with internal tasks on any lane (those need
    the event loop) and for fault-annotated kinds (retry/backoff prices
    off the recording run's FaultSchedule — replay those with
    ``replay_page_trace(..., faults=...)``).

    Args:
        ops: ``(kind, addr, nbytes)`` tuples, or port-tagged 4-tuples.
        media_name: one media spec, or a sequence of per-port specs for
            port-tagged ops.
        ds: deterministic store enabled (writes bill at GPU-memory speed).
        req_bytes: bytes per CXL.mem request within a page op.
        max_inflight: per-port async in-flight cap the trace was recorded
            under (``TierConfig.max_inflight``).

    Returns:
        Per-op latencies (ns), aligned with ``ops`` — completion latency
        for blocking ops, issue-stall wait for async ops, 0 for
        prefetch/advance (matching ``replay_page_trace``).
    """
    if max_inflight < 1:
        raise ValueError("max_inflight must be >= 1")
    if isinstance(media_name, (list, tuple)):
        medias = [resolve_media(m) for m in media_name]
        ops = list(ops)
        ports = np.asarray([p for p, _, _, _ in ops], np.int64)
        rest = [(k, a, n) for _, k, a, n in ops]
        tagged = True
    else:
        medias = [resolve_media(media_name)]
        rest = list(ops)
        ports = np.zeros(len(rest), np.int64)
        tagged = False
    for media in medias:
        # lockstep with Endpoint.is_dram: DRAM-class = no internal tasks
        # (scaled variants like "dram@2" stay valid — the stream never
        # queues in the controller regardless of the latency multiplier)
        if media.gc_every_bytes != 0:
            raise ValueError(f"{media.name}: closed form needs a "
                             "DRAM-class EP")
    kinds = np.asarray([k for k, _, _ in rest], np.int64)
    if np.any(np.isin(kinds, se.PAGE_FAULT_KINDS)):
        # fault-annotated ops price retry/backoff (and downed-port zero
        # charges) off the recording run's FaultSchedule — event-loop
        # state the per-op algebra cannot see
        raise ValueError("closed form cannot price fault-annotated page "
                         "ops; replay them with replay_page_trace(..., "
                         "faults=<the recording run's FaultSchedule>)")
    known = np.isin(kinds, (se.PAGE_ADVANCE, se.PAGE_READ, se.PAGE_WRITE,
                            se.PAGE_PREFETCH, se.PAGE_READ_ASYNC,
                            se.PAGE_WRITE_ASYNC))
    if not np.all(known):
        bad = sorted(set(kinds[~known].tolist()))
        raise ValueError(f"unknown page-op kind(s) {bad} in trace; known "
                         "kinds are PAGE_ADVANCE/PAGE_READ/PAGE_WRITE/"
                         "PAGE_PREFETCH/PAGE_READ_ASYNC/PAGE_WRITE_ASYNC "
                         "(fault-annotated kinds need replay_page_trace)")
    n = len(kinds)
    if n == 0:
        return np.zeros(0, np.float64)
    nbytes = np.asarray([nb for _, _, nb in rest], np.int64)
    n_reqs = -(-nbytes // req_bytes)
    line = 64                      # CXL.mem request granularity (MemRd)
    read_req = np.asarray(
        [CXL_RTT_NS + m.read_ns + m.xfer_ns(line) for m in medias])
    write_req = np.asarray(
        [GPU_MEM_NS if ds else CXL_RTT_NS + m.write_ns + m.xfer_ns(line)
         for m in medias])
    lane = np.clip(ports, 0, len(medias) - 1)   # advance records use -1
    is_read = (kinds == se.PAGE_READ) | (kinds == se.PAGE_READ_ASYNC)
    is_write = (kinds == se.PAGE_WRITE) | (kinds == se.PAGE_WRITE_ASYNC)
    dur = np.zeros(n, np.float64)              # service ns per op
    dur[is_read] = (n_reqs * read_req[lane])[is_read]
    dur[is_write] = (n_reqs * write_req[lane])[is_write]
    is_async = (kinds == se.PAGE_READ_ASYNC) | (kinds == se.PAGE_WRITE_ASYNC)
    # EP-channel residual coupling: a deterministic store's media write
    # can outlive the GPU-side completion only when its service time
    # exceeds the request cadence — then reads on the same lane can
    # queue behind it and the scan must track channel state
    chan_model = [ds and m.write_ns + m.xfer_ns(line) > GPU_MEM_NS
                  for m in medias]
    n_ports = len(medias)
    needs_chan = any(
        chan_model[p] and bool((is_write & (lane == p)).any())
        and bool((is_read & (lane == p)).any()) for p in range(n_ports))
    if not is_async.any() and not needs_chan:
        # blocking fast path: the stream clock always catches the service
        # cursor (t == u after every blocking op), so latency == service
        # time per op — no clock state needed at all
        return np.where((kinds == se.PAGE_READ) | (kinds == se.PAGE_WRITE),
                        dur, 0.0)

    # --- scan path: exact O(1)-state scan over precomputed costs ------
    # per-port async ordinals + cap-lag taps, vectorized: async op number
    # m on a port stalls until its (m - cap)-th predecessor completes
    # (completion times are monotone, so sorted(inflight)[len-cap] in
    # PageStream.issue is exactly d[m - cap])
    ordv = np.zeros(n, np.int64)
    n_async_p = [0] * n_ports
    for p in range(n_ports):
        mask = is_async & (lane == p)
        cnt = int(mask.sum())
        ordv[mask] = np.arange(cnt)
        n_async_p[p] = cnt
    tap = ordv - max_inflight       # < 0: cap slack, never stalls
    dur[kinds == se.PAGE_ADVANCE] = \
        nbytes[kinds == se.PAGE_ADVANCE].astype(np.float64)
    adv, rd, wr, pre = (se.PAGE_ADVANCE, se.PAGE_READ, se.PAGE_WRITE,
                        se.PAGE_PREFETCH)
    kl = kinds.tolist()
    ll = lane.tolist()
    dl = dur.tolist()
    ol = ordv.tolist()
    tl = tap.tolist()
    al = [a for _, a, _ in rest]    # request walks need base addresses
    wn = n_reqs.tolist()
    sw_l = [m.write_ns + m.xfer_ns(line) for m in medias]
    rr_l = read_req.tolist()
    chs = [[0.0] * m.channels if chan_model[p] else None
           for p, m in enumerate(medias)]
    t = [0.0] * n_ports             # stream clocks
    u = [0.0] * n_ports             # service cursors (busy_until)
    adone = [[0.0] * c for c in n_async_p]   # async completion times
    lat = [0.0] * n
    rda = se.PAGE_READ_ASYNC
    for e in range(n):
        k = kl[e]
        if k == adv:
            # Topology.advance: sync every stream clock to the global
            # max, then advance by dt (single-port traces degenerate to
            # t += dt); service cursors are untouched
            g = max(t) + dl[e]
            for p in range(n_ports):
                t[p] = g
        elif k == pre:
            continue                # free on a DRAM EP, no state change
        elif k == rd or k == wr:
            p = ll[e]
            tp, up = t[p], u[p]
            s = tp if tp > up else up
            d = dl[e]
            ch = chs[p]
            if ch is not None:
                if k == wr:
                    _chan_store(ch, len(ch), al[e], wn[e], s, sw_l[p],
                                req_bytes)
                else:
                    d += _chan_load_wait(ch, len(ch), al[e], wn[e], s,
                                         rr_l[p], req_bytes)
            done = s + d
            lat[e] = done - tp
            t[p] = u[p] = done
        else:                       # async issue
            p = ll[e]
            j = tl[e]
            tp = t[p]
            if j >= 0:
                dn = adone[p][j]
                if dn > tp:
                    lat[e] = dn - tp
                    tp = dn
                    t[p] = dn
            up = u[p]
            s = tp if tp > up else up
            d = dl[e]
            ch = chs[p]
            if ch is not None:
                if k == rda:
                    d += _chan_load_wait(ch, len(ch), al[e], wn[e], s,
                                         rr_l[p], req_bytes)
                else:
                    _chan_store(ch, len(ch), al[e], wn[e], s, sw_l[p],
                                req_bytes)
            done = s + d
            u[p] = done
            adone[p][ol[e]] = done
    return np.asarray(lat, np.float64)
