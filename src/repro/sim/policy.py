"""Learned page-placement policy: a small GMM over reuse features.

The ``hotness`` placement promotes an entry after a fixed number of
restores (``TierConfig.hot_promote_after``) — a threshold heuristic that
cannot tell a burst of restores from a sustained hot working set, and
never un-learns. ICGMM-style classifiers (GMM over reuse-distance /
recency features, PAPERS.md arXiv:2408.05614) beat such thresholds for
exactly this hot/cold decision, cheaply enough to sit on the restore
path. :class:`LearnedPlacement` is that classifier: it fits a
two-component diagonal-covariance Gaussian mixture (plain numpy EM, no
new dependencies) over per-entry features

    - reuse distance (simulated ns between consecutive restores)
    - restore recency (simulated ns since the previous restore)
    - restore frequency (decayed restore count)
    - entry bytes

and scores entries by the posterior probability of the short-reuse
component. ``CxlTier`` consumes it as ``placement="learned"`` (promotion
= ``is_hot``, demotion victims = lowest ``score``); ``ShardedTier``
reuses the same observation stream to re-home hot shared prefixes onto
the rank that restores them most (see ``core.sharded_tier``).

Everything is deterministic: fixed EM iteration count, deterministic
median-split initialisation, no RNG — two runs over the same trace fit
identical mixtures, which the differential replay gates rely on.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

# EM fit hyper-parameters. Fixed, not configurable knobs: the policy is
# judged end-to-end by the placement bench gates, and a deterministic
# fit schedule keeps replay bit-stable.
_EM_ITERS = 8                 # fixed EM iteration budget per refit
_VAR_FLOOR = 1e-3             # diagonal covariance floor (log-space feats)
_COMPONENTS = 2               # hot / cold


@dataclasses.dataclass
class _EntryState:
    """Incremental per-key reuse statistics feeding the feature vector."""

    last_ns: float = 0.0      # simulated time of the latest restore
    gap_ns: float = 0.0       # latest inter-restore gap (reuse distance)
    count: float = 0.0        # decayed restore count (frequency)
    count_t: float = 0.0      # timestamp the decayed count is valid at
    nbytes: int = 0           # latest observed entry payload


def _features(gap_ns: float, recency_ns: float, count: float,
              nbytes: int) -> List[float]:
    """Log-compressed feature vector — reuse distances span 1e2..1e9 ns,
    so the mixture is fit in log space where both scales are Gaussian-ish."""
    return [math.log1p(max(gap_ns, 0.0)),
            math.log1p(max(recency_ns, 0.0)),
            math.log1p(max(count, 0.0)),
            math.log1p(max(float(nbytes), 0.0))]


class LearnedPlacement:
    """Hot/cold classifier over restore-reuse features (numpy EM GMM).

    ``observe`` records one restore of ``key`` at simulated time
    ``now_ns``; every ``refit_every`` observations (once ``min_fit``
    samples exist) the mixture is refit over a sliding window of recent
    feature vectors. ``score`` returns the posterior probability that
    the key's *current* features (reuse estimate replaced by its live
    recency) belong to the short-reuse component; ``is_hot`` thresholds
    it. Below ``min_fit`` samples the policy falls back to the counter
    heuristic (``fallback_after`` decayed restores), so cold-start
    behaviour matches the ``hotness`` policy it replaces.

    ``half_life_ns > 0`` ages the per-key restore counts (satellite of
    the same aging applied to the counter policy): a once-hot entry's
    frequency feature decays toward zero while its recency feature
    grows, so the mixture stops classifying it hot without any explicit
    eviction rule.
    """

    def __init__(self, *, window: int = 512, refit_every: int = 32,
                 min_fit: int = 16, hot_threshold: float = 0.5,
                 fallback_after: int = 2, half_life_ns: float = 0.0):
        if window < min_fit:
            raise ValueError(f"window ({window}) must hold at least "
                             f"min_fit ({min_fit}) samples")
        self.window = int(window)
        self.refit_every = int(refit_every)
        self.min_fit = int(min_fit)
        self.hot_threshold = float(hot_threshold)
        self.fallback_after = int(fallback_after)
        self.half_life_ns = float(half_life_ns)
        self._state: Dict[object, _EntryState] = {}
        self._samples: List[List[float]] = []   # sliding feature window
        self._since_fit = 0
        self._obs = 0
        # fitted mixture (None until the first successful fit)
        self._means: Optional[np.ndarray] = None      # (K, F)
        self._vars: Optional[np.ndarray] = None       # (K, F)
        self._weights: Optional[np.ndarray] = None    # (K,)
        self._hot_comp = 0
        self.fits = 0                                  # telemetry

    # ------------------------------------------------------------- decay
    def _decayed_count(self, st: _EntryState, now_ns: float) -> float:
        """Restore count aged by the configured half-life (0 = frozen)."""
        if self.half_life_ns <= 0.0 or st.count <= 0.0:
            return st.count
        dt = max(0.0, now_ns - st.count_t)
        return st.count * 0.5 ** (dt / self.half_life_ns)

    # ----------------------------------------------------------- observe
    def observe(self, key, now_ns: float, nbytes: int) -> None:
        """Record one restore of ``key`` at simulated time ``now_ns``."""
        st = self._state.get(key)
        if st is None:
            st = self._state[key] = _EntryState()
            st.last_ns = float(now_ns)
            st.count = 1.0
            st.count_t = float(now_ns)
            st.nbytes = int(nbytes)
            return                    # first sighting: no reuse gap yet
        gap = max(0.0, float(now_ns) - st.last_ns)
        st.count = self._decayed_count(st, float(now_ns)) + 1.0
        st.count_t = float(now_ns)
        st.gap_ns = gap
        st.last_ns = float(now_ns)
        st.nbytes = int(nbytes)
        self._samples.append(_features(gap, gap, st.count, st.nbytes))
        if len(self._samples) > self.window:
            del self._samples[: len(self._samples) - self.window]
        self._obs += 1
        self._since_fit += 1
        if (self._since_fit >= self.refit_every
                and len(self._samples) >= self.min_fit):
            self._fit()
            self._since_fit = 0

    def forget(self, key) -> None:
        """Drop ``key``'s state (freed / lost entries)."""
        self._state.pop(key, None)

    # --------------------------------------------------------------- fit
    def _fit(self) -> None:
        """Deterministic EM over the sample window (diagonal Gaussians).

        Initialised by a median split on the reuse-distance feature —
        component 0 seeds on short-reuse samples — then a fixed
        ``_EM_ITERS`` rounds of EM with floored variances. The hot
        component is whichever ends with the smaller mean reuse
        distance."""
        x = np.asarray(self._samples, np.float64)        # (N, F)
        n, f = x.shape
        med = float(np.median(x[:, 0]))
        resp = np.zeros((n, _COMPONENTS), np.float64)
        lo = x[:, 0] <= med
        resp[lo, 0] = 1.0
        resp[~lo, 1] = 1.0
        if not lo.any() or lo.all():      # degenerate: one-point spread
            return                        # keep the previous fit (if any)
        means = np.zeros((_COMPONENTS, f))
        var = np.ones((_COMPONENTS, f))
        w = np.full(_COMPONENTS, 1.0 / _COMPONENTS)
        for _ in range(_EM_ITERS):
            # M step
            nk = resp.sum(axis=0) + 1e-12
            means = (resp.T @ x) / nk[:, None]
            diff = x[None, :, :] - means[:, None, :]     # (K, N, F)
            var = np.maximum(
                (resp.T[:, :, None] * diff ** 2).sum(axis=1) / nk[:, None],
                _VAR_FLOOR)
            w = nk / n
            # E step (log-domain, diagonal Gaussians)
            ll = (-0.5 * ((diff ** 2) / var[:, None, :]
                          + np.log(2.0 * np.pi * var[:, None, :]))
                  ).sum(axis=2).T + np.log(w)[None, :]   # (N, K)
            ll -= ll.max(axis=1, keepdims=True)
            resp = np.exp(ll)
            resp /= resp.sum(axis=1, keepdims=True)
        self._means, self._vars, self._weights = means, var, w
        self._hot_comp = int(np.argmin(means[:, 0]))    # short reuse = hot
        self.fits += 1

    # ------------------------------------------------------------- score
    def _posterior(self, feats: List[float]) -> float:
        x = np.asarray(feats, np.float64)
        diff = x[None, :] - self._means                  # (K, F)
        # Monotone extension on the reuse features (gap, recency): a key
        # reusing *faster* than the hot cluster's mean is at least as
        # hot, and one reusing *slower* than the cold cluster's mean is
        # at least as cold. Without the clamp a tightly-fit hot
        # component (variance at the floor) rejects gaps shorter than
        # its own mean, scoring the hottest keys cold.
        cold_comp = 1 - self._hot_comp
        diff[self._hot_comp, :2] = np.maximum(diff[self._hot_comp, :2], 0.0)
        diff[cold_comp, :2] = np.minimum(diff[cold_comp, :2], 0.0)
        ll = (-0.5 * (diff ** 2 / self._vars
                      + np.log(2.0 * np.pi * self._vars))).sum(axis=1) \
            + np.log(self._weights)
        ll -= ll.max()
        p = np.exp(ll)
        return float(p[self._hot_comp] / p.sum())

    def score(self, key, now_ns: float) -> float:
        """P(hot) for ``key`` at ``now_ns`` — 0.0 for unseen keys.

        The reuse-distance feature is the larger of the last observed
        gap and the live recency: an entry that has gone quiet scores as
        if its next gap were at least that long, so scores decay as
        simulated time passes (no restore required)."""
        st = self._state.get(key)
        if st is None:
            return 0.0
        recency = max(0.0, float(now_ns) - st.last_ns)
        count = self._decayed_count(st, float(now_ns))
        if self._means is None:
            # cold start: mirror the counter heuristic on decayed counts
            return 1.0 if count >= self.fallback_after else 0.0
        gap = max(st.gap_ns, recency)
        return self._posterior(_features(gap, recency, count, st.nbytes))

    def is_hot(self, key, now_ns: float) -> bool:
        """Promotion verdict: posterior P(hot) over ``hot_threshold``."""
        return self.score(key, now_ns) >= self.hot_threshold

    @property
    def fitted(self) -> bool:
        """True once a mixture has been fit (past cold-start fallback)."""
        return self._means is not None
