"""Backend media + endpoint models for the CXL-GPU simulator.

Latency/bandwidth constants follow Table 1a's parts (DDR5-5600 via a
DRAMSim3-style closed-page approximation; Optane P5800X; Samsung 983 ZET
Z-NAND; Samsung 980 Pro TLC NAND) at the 64B-4KB request sizes the
controller issues. NAND-family media carry a garbage-collection model
(periodic block reclaim that stalls the media — the paper's tail-latency
source); PRAM (Optane) models fine-grained wear-leveling as a smaller,
more frequent stall.

The endpoint (EP) couples a media model with the internal DRAM cache that
SSD-based expanders are expected to ship (paper §CXL with an SSD
integration). Fidelity points that matter for reproducing Fig. 9:

 * the cache tracks a per-block **ready time** — a read arriving while its
   block is still in flight merges with the fill (MSHR semantics) and
   waits out the remainder; it does not refetch. This is what makes the
   naive SR variant (64B MemSpecRd per request) a ~2x win, not a wash:
   the fetch starts at *issue* time instead of head-of-queue time.
 * SSD media have **channel parallelism** (multi-die): independent fetches
   overlap across channels; a single sequential demand stream without SR
   mostly serializes on one fetch at a time (the next miss is issued only
   after the GPU advances), while SR keeps all channels busy.
 * internal tasks (GC / wear-leveling) stall the whole device and are
   pre-announced via DevLoad (the paper's fine control for writes).
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Optional, Union

import numpy as np

from repro.core.qos import DevLoad

NS = 1.0
US = 1000.0


@dataclasses.dataclass(frozen=True)
class MediaModel:
    """One backend media part (Table 1a): service latencies in ns, per-
    channel bandwidth in GB/s (== bytes/ns), and the internal-task (GC /
    wear-leveling) cadence. ``gc_every_bytes == 0`` marks DRAM-class
    media with no internal tasks."""

    name: str
    read_ns: float            # base access latency, one internal granule
    write_ns: float
    bw_gbps: float            # per-channel transfer bandwidth (GB/s)
    channels: int = 1
    gc_every_bytes: int = 0   # 0 = no internal tasks
    gc_ns: float = 0.0        # stall per internal task

    def xfer_ns(self, nbytes: int) -> float:
        """Transfer time (ns) of ``nbytes`` on one channel."""
        return nbytes / self.bw_gbps  # GB/s == bytes/ns

    def scaled(self, latency: float = 1.0, bw: float = 1.0) -> "MediaModel":
        """Derived part with scaled service latencies / bandwidth — the
        sweep's media-latency-distribution axis (e.g. a 2x-slower Z-NAND
        bin, or a next-gen part at 0.5x)."""
        return dataclasses.replace(
            self, name=f"{self.name}@{latency:g}x",
            read_ns=self.read_ns * latency, write_ns=self.write_ns * latency,
            gc_ns=self.gc_ns * latency, bw_gbps=self.bw_gbps * bw)


# Table 1a media. DRAM numbers approximate DDR5-5600 closed-page access;
# SSD numbers are small-read/-write service times of the named parts.
DRAM = MediaModel("DRAM", read_ns=55.0, write_ns=55.0, bw_gbps=44.8,
                  channels=16)
# gc_every_bytes is calibrated to the simulated trace length (tens of
# thousands of requests, vs billions on real hardware) so each run sees
# several internal-task windows, as the paper's Fig. 9e does.
OPTANE = MediaModel("Optane", read_ns=1_600.0, write_ns=2_600.0,
                    bw_gbps=3.2, channels=8,
                    gc_every_bytes=256 << 10, gc_ns=60 * US)
ZNAND = MediaModel("Z-NAND", read_ns=9_000.0, write_ns=14_000.0,
                   bw_gbps=1.6, channels=8,
                   gc_every_bytes=128 << 10, gc_ns=500 * US)
NAND = MediaModel("NAND", read_ns=45_000.0, write_ns=90_000.0,
                  bw_gbps=0.8, channels=8,
                  gc_every_bytes=64 << 10, gc_ns=2_000 * US)

MEDIA = {"dram": DRAM, "optane": OPTANE, "znand": ZNAND, "nand": NAND}


def resolve_media(spec: Union[str, MediaModel]) -> MediaModel:
    """Resolve a media spec: a MediaModel, a name ("znand"), or a scaled
    variant "name@<latency-mult>" (e.g. "znand@2" = tail-bin Z-NAND with
    2x service latency)."""
    if isinstance(spec, MediaModel):
        return spec
    if "@" in spec:
        name, mult = spec.split("@", 1)
        return MEDIA[name].scaled(latency=float(mult))
    return MEDIA[spec]


def channel_timeline(arrivals: np.ndarray, channels: np.ndarray,
                     n_channels: int, service_ns: float) -> np.ndarray:
    """Vectorized FIFO service over parallel channels (constant service).

    For each channel the completion recurrence is
    ``done_i = max(a_i, done_{i-1}) + L``, whose closed form is
    ``done_i = (i+1)*L + cummax(a_j - j*L)`` — one cumulative-maximum pass
    per channel instead of a per-request Python loop. This is the
    miss-address-array form of ``Endpoint._media_fetch`` for media without
    internal tasks (DRAM expanders), used by the vectorized engine.
    """
    done = np.empty_like(arrivals)
    for c in range(n_channels):
        idx = np.nonzero(channels == c)[0]
        if idx.size == 0:
            continue
        a = arrivals[idx]
        i = np.arange(idx.size)
        done[idx] = (i + 1) * service_ns \
            + np.maximum.accumulate(a - i * service_ns)
    return done


class Endpoint:
    """A CXL EP: backend media + internal DRAM cache + ingress queue."""

    BLOCK = 256

    def __init__(self, media: MediaModel, dram_cache_bytes: int = 64 << 20,
                 ingress_depth: int = 64):
        self.media = media
        # DRAM-class = no internal tasks: scaled variants ("dram@2") stay
        # DRAM-class so the latency multiplier is charged on every access
        # instead of being silently dropped on internal-cache hits (the
        # cache path bills hits at the *unscaled* internal-DRAM speed).
        # repro.sim.vector mirrors this classification — keep in lockstep.
        self.is_dram = media.gc_every_bytes == 0
        self.cache_capacity = max(dram_cache_bytes // self.BLOCK, 1)
        self.cache: "OrderedDict[int, float]" = OrderedDict()  # ready time
        self.ingress_depth = ingress_depth
        self.chan_busy = [0.0] * media.channels
        # demand-fetch MSHRs: the EP's transaction tracker admits few
        # concurrent demand fills; the SR prefetch engine streams straight
        # to the media channels. This asymmetry is what lets MemSpecRd run
        # ahead of the demand stream (paper Fig. 6).
        self.demand_mshr = [0.0] * 1
        self.demand_pressure = 0.0     # EWMA of demand-fetch queue wait
        self._pressure_t = 0.0
        self._write_accum = 0          # write-back coalescing buffer
        self.inflight = 0
        self.written_since_gc = 0
        self.gc_until = 0.0
        self._gc_start = 0.0
        self.last_write_t = 0.0
        self.stats = {"reads": 0, "writes": 0, "hits": 0, "prefetches": 0,
                      "gc_events": 0, "evictions": 0, "media_fetches": 0}

    # ------------------------------------------------------------- cache
    def _lookup(self, block: int) -> Optional[float]:
        if block in self.cache:
            self.cache.move_to_end(block)
            return self.cache[block]
        return None

    def _fill(self, block: int, ready: float) -> None:
        if block in self.cache:
            self.cache.move_to_end(block)
            self.cache[block] = min(self.cache[block], ready)
            return
        if len(self.cache) >= self.cache_capacity:
            self.cache.popitem(last=False)
            self.stats["evictions"] += 1
        self.cache[block] = ready

    # --------------------------------------------------------------- media
    def _channel(self, addr: int) -> int:
        return (addr // self.BLOCK) % self.media.channels

    def _media_fetch(self, now: float, addr: int, nbytes: int,
                     write: bool = False) -> float:
        """Issue one media op on the owning channel; returns completion."""
        self.stats["media_fetches"] += 1
        c = self._channel(addr)
        base = self.media.write_ns if write else self.media.read_ns
        start = max(now, self.chan_busy[c], self.gc_until)
        done = start + base + self.media.xfer_ns(nbytes)
        self.chan_busy[c] = done
        return done

    # ----------------------------------------------------------------- gc
    def _maybe_gc(self, now: float) -> None:
        if self.media.gc_every_bytes and \
                self.written_since_gc >= self.media.gc_every_bytes:
            self.written_since_gc = 0
            self.stats["gc_events"] += 1
            start = max(now, max(self.chan_busy))
            self._gc_start = start
            self.gc_until = start + self.media.gc_ns

    def gc_pending(self) -> bool:
        """The media pre-announces an imminent internal task via DevLoad."""
        return bool(self.media.gc_every_bytes) and \
            self.written_since_gc >= 0.97 * self.media.gc_every_bytes

    # ------------------------------------------------------------ requests
    def read(self, now: float, addr: int, nbytes: int = 64) -> float:
        """Returns completion time of a demand read arriving at ``now``."""
        self.stats["reads"] += 1
        if self.is_dram:
            return self._media_fetch(now, addr, nbytes)
        block = addr // self.BLOCK
        ready = self._lookup(block)
        if ready is not None:
            # hit (or merge with an in-flight fill)
            if ready <= now:
                self.stats["hits"] += 1
            return max(now, ready) + DRAM.read_ns + DRAM.xfer_ns(nbytes)
        # single-slot demand MSHR: the heap degenerates to one scalar
        slot = self.demand_mshr[0]
        start = max(now, slot)
        done = self._media_fetch(start, addr, self.BLOCK)
        self.demand_mshr[0] = done
        self._fill(block, done)
        wait = (start - now) / (self.media.read_ns + 1.0)
        self._decay_pressure(now)
        self.demand_pressure = 0.75 * self.demand_pressure + 0.25 * wait
        return done + DRAM.read_ns

    def _decay_pressure(self, now: float) -> None:
        """Pressure relaxes over ~10 service times so a halted SR engine
        can observe recovery (the paper resumes SR when DevLoad returns
        to light load)."""
        dt = max(0.0, now - self._pressure_t)
        self._pressure_t = now
        tau = 10.0 * (self.media.read_ns + 1.0)
        self.demand_pressure *= math.exp(-dt / tau)

    def prefetch(self, now: float, addr: int, nbytes: int) -> float:
        """SR fill: media -> internal DRAM, off the critical path. Blocks
        already cached or in flight are skipped (the ring-buffer dedup
        upstream catches most of these; this is the EP-side guard)."""
        if self.is_dram:
            return now
        first = addr // self.BLOCK
        last = (addr + max(nbytes, 1) - 1) // self.BLOCK
        missing = [b for b in range(first, last + 1)
                   if self._lookup(b) is None]
        if not missing:
            return now
        self.stats["prefetches"] += 1
        # one media op per contiguous missing span (aggregated fetch)
        span_start = missing[0]
        prev = missing[0]
        spans = []
        for b in missing[1:]:
            if b != prev + 1:
                spans.append((span_start, prev))
                span_start = b
            prev = b
        spans.append((span_start, prev))
        done = now
        for s0, s1 in spans:
            n = (s1 - s0 + 1) * self.BLOCK
            d = self._media_fetch(now, s0 * self.BLOCK, n)
            for b in range(s0, s1 + 1):
                self._fill(b, d)
            done = max(done, d)
        return done

    def write(self, now: float, addr: int, nbytes: int = 64) -> float:
        """SSD EPs absorb writes in internal DRAM (write-back) and flush
        to media asynchronously; the request completes at DRAM speed
        unless the ingress/write backlog is saturated or an internal task
        (GC) holds the device — the paper's Fig. 8/9e behaviour."""
        self.stats["writes"] += 1
        if self.is_dram:
            return self._media_fetch(now, addr, nbytes, write=True)
        self.last_write_t = now
        self.written_since_gc += nbytes
        if now < self.gc_until:
            # writes landing mid-reclaim thrash the task: the paper's
            # "accumulated write requests flood back ... triggering the
            # next GC" feedback. DS's divert avoids exactly this. Capped
            # at 3x the base task so a storm cannot become unbounded.
            self.gc_until = min(self.gc_until + self.media.write_ns,
                                self._gc_start + 3 * self.media.gc_ns)
        self._maybe_gc(now)
        self._fill(addr // self.BLOCK, now)            # write-back cache
        # coalesced flush: internal DRAM merges small writes into 4 KiB
        # media programs (one program per accumulated 4 KiB)
        self._write_accum += nbytes
        flush_done = now
        if self._write_accum >= 4096:
            self._write_accum -= 4096
            flush_done = self._media_fetch(now, addr, 4096, write=True)
        backlog = max(0.0, sum(self.chan_busy) / len(self.chan_busy) - now)
        if now < self.gc_until or \
                backlog > self.ingress_depth * self.media.write_ns / 8:
            return max(flush_done, self.gc_until)      # back-pressure
        return max(now, self.gc_until) + DRAM.write_ns

    # ------------------------------------------------------------ devload
    def devload(self, now: float) -> DevLoad:
        """QoS telemetry: DEMAND-read pressure + pending internal tasks.

        Channels busy with prefetch are the SR mechanism working as
        intended, not congestion — the device reports overload only when
        demand fetches queue up (ingress pressure) or an internal task is
        running/imminent (the write-side fine control)."""
        # an announced internal task runs once the write stream pauses
        # (DS's divert gives the device exactly that window — Fig. 8)
        if self.gc_pending() and not self.is_dram and \
                now - self.last_write_t > 8 * self.media.write_ns:
            self.written_since_gc = 0
            self.stats["gc_events"] += 1
            self._gc_start = now
            self.gc_until = now + self.media.gc_ns
        if now < self.gc_until or (self.gc_pending() and not self.is_dram):
            return DevLoad.SEVERE
        self._decay_pressure(now)
        p = self.demand_pressure
        if p > 3.0:
            return DevLoad.SEVERE
        if p > 1.0:
            return DevLoad.MODERATE
        if p > 0.25:
            return DevLoad.OPTIMAL
        return DevLoad.LIGHT

    def hit_rate(self) -> float:
        """Fraction of demand reads served ready from internal DRAM."""
        r = self.stats["reads"]
        return self.stats["hits"] / r if r else 0.0
