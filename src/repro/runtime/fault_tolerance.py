"""Fault tolerance: heartbeats, straggler mitigation, restart policy.

At thousand-node scale the framework assumes failures are routine:

 * ``Heartbeat`` — every worker stamps a monotonic (step, time) record; a
   monitor flags nodes whose stamp lags (dead) or whose step durations
   drift above the fleet median (straggler). On TPU pods the stamps ride
   the coordination service; here they are a local table with the same
   interface.
 * ``StragglerMitigator`` — the paper's DevLoad discipline applied to the
   fleet: the fleet-relative slowdown of a worker maps to a DevLoad state
   and the same controller that throttles SR throttles the offending
   host's input prefetch depth / triggers its eviction, instead of letting
   one slow HBM or NIC gate every all-reduce. :meth:`~StragglerMitigator.
   assess_ports` applies the identical discipline to a CXL tier's root
   ports (``CxlTier.port_stats()``): a hot-removed port is evicted, a
   degraded or DevLoad-pressured port is throttled.
 * ``RestartPolicy`` — crash-consistent resume: (checkpoint step, data
   step, rng) define the restart point; elastic resize re-shards through
   Checkpointer.restore(shardings=new_mesh_shardings).

Every wall-clock read goes through an injectable ``now`` callable
(default ``time.time``): wiring ``lambda: engine.clock_ns / 1e9`` puts
heartbeat liveness on the serving engine's simulated clock, which is
what makes the fault-injection tests deterministic.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.qos import DevLoad, QoSController


@dataclasses.dataclass
class HeartbeatRecord:
    worker: int
    step: int
    t: float
    step_time: float


class Heartbeat:
    """Worker liveness + progress table.

    ``now`` injects the clock every default timestamp is read from
    (seconds; default wall ``time.time``). Pass the serving engine's
    simulated clock — ``lambda: engine.clock_ns / 1e9`` — and liveness
    becomes a pure function of simulated time. Explicit ``now=`` args on
    the methods still override per call.
    """

    def __init__(self, n_workers: int, *, dead_after_s: float = 60.0,
                 now: Optional[Callable[[], float]] = None):
        self.n_workers = n_workers
        self.dead_after_s = dead_after_s
        self.now = now if now is not None else time.time
        self.records: Dict[int, HeartbeatRecord] = {}

    def stamp(self, worker: int, step: int, step_time: float,
              now: Optional[float] = None) -> None:
        self.records[worker] = HeartbeatRecord(
            worker, step, now if now is not None else self.now(),
            step_time)

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else self.now()
        out = [w for w in range(self.n_workers)
               if w not in self.records
               or now - self.records[w].t > self.dead_after_s]
        return out

    def step_times(self) -> Dict[int, float]:
        return {w: r.step_time for w, r in self.records.items()}


class StragglerMitigator:
    """Fleet-relative slowdown -> DevLoad -> mitigation action."""

    def __init__(self, *, evict_threshold: float = 2.0):
        self.evict_threshold = evict_threshold
        self.controllers: Dict[int, QoSController] = {}

    def assess(self, step_times: Dict[int, float]) -> Dict[int, str]:
        """Returns worker -> action in {ok, throttle, evict}."""
        if not step_times:
            return {}
        med = statistics.median(step_times.values())
        actions: Dict[int, str] = {}
        for w, t in step_times.items():
            ratio = t / med if med > 0 else 1.0
            ctl = self.controllers.setdefault(w, QoSController())
            dl = ctl.classify(occupancy=0.0, service_ratio=ratio)
            ctl.update(dl)
            if ratio >= self.evict_threshold:
                actions[w] = "evict"
            elif dl >= DevLoad.MODERATE:
                actions[w] = "throttle"
            else:
                actions[w] = "ok"
        return actions

    def assess_ports(self, port_stats: List[Dict[str, object]]) \
            -> Dict[int, str]:
        """Map a CXL tier's per-port state onto the same action set.

        Takes ``CxlTier.port_stats()`` rows and returns port -> action:
        a hot-removed port is ``evict`` (its pages are already lost —
        placement must never target it again), a port whose media is
        degraded past ``evict_threshold`` or whose announced DevLoad is
        at/above MODERATE is ``throttle`` (hotness placement demotes
        away from it; the flusher narrows its admission window), and a
        healthy port is ``ok`` — the fleet straggler discipline and the
        endpoint fault discipline reduced to one policy.
        """
        actions: Dict[int, str] = {}
        for row in port_stats:
            port = int(row["port"])  # type: ignore[arg-type]
            if row.get("down"):
                actions[port] = "evict"
            elif (float(row.get("degrade_mult", 1.0))  # type: ignore
                  >= self.evict_threshold
                  or int(row.get("devload", 0))  # type: ignore
                  >= DevLoad.MODERATE):
                actions[port] = "throttle"
            else:
                actions[port] = "ok"
        return actions


@dataclasses.dataclass
class RestartPoint:
    checkpoint_step: int
    data_step: int
    seed: int


class RestartPolicy:
    """Decides resume point + mesh shape after failures."""

    def __init__(self, *, min_workers: int):
        self.min_workers = min_workers

    def plan(self, n_alive: int, latest_ckpt: Optional[int],
             data_step: int, seed: int) -> Tuple[str, RestartPoint]:
        """Returns (action, restart_point); action in {continue, resize,
        halt}."""
        point = RestartPoint(latest_ckpt if latest_ckpt is not None else -1,
                             data_step, seed)
        if n_alive < self.min_workers:
            return "halt", point
        if latest_ckpt is None:
            return "halt", point
        return "resize", point
