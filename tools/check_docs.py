"""Documentation checks: links, docstring coverage, bench-schema drift.

Three pure-stdlib-plus-numpy checks, run by the CI ``docs`` job and by
``tests/test_docs.py`` inside the tier-1 suite:

 1. **Markdown link check** — every relative link/anchor in README.md and
    docs/*.md must resolve to an existing file and (for ``#fragments``) a
    real heading of the target, GitHub-slugified. External (``http``,
    ``mailto``) and repo-escaping targets (badge/actions URLs) are
    skipped.
 2. **Docstring coverage** (pydocstyle-lite) — every module, public
    class, and public function/method in ``repro.sim``, ``repro.core``
    and ``repro.serving`` must carry a docstring, enforced on the AST so
    nothing needs importing.
 3. **BENCH_serve schema drift** — the schema table in
    docs/ARCHITECTURE.md (between the ``BENCH_SERVE_SCHEMA`` markers)
    must list exactly the keys ``benchmarks.serve_bench.SCHEMA_KEYS``
    declares; serve_bench itself fails at emit time if its output drifts
    from the same constant, closing the loop code <-> docs.

Run: ``python tools/check_docs.py`` (exit 1 + report on any failure).
"""
from __future__ import annotations

import ast
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md", "docs/ARCHITECTURE.md", "docs/SIM_MAPPING.md"]
DOCSTRING_PACKAGES = ["src/repro/sim", "src/repro/core",
                      "src/repro/serving"]

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    s = re.sub(r"[`*_]", "", heading.strip()).lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors(md_path: str) -> set:
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    return {_slugify(h) for h in _HEADING_RE.findall(text)}


def check_links() -> list:
    """Dead relative links / anchors in the documentation set."""
    errs = []
    for rel in DOC_FILES:
        path = os.path.join(REPO, rel)
        if not os.path.exists(path):
            errs.append(f"{rel}: file missing")
            continue
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, frag = target.partition("#")
            if base:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(path), base))
                if not dest.startswith(REPO):
                    continue            # escapes the repo (badge links)
                if not os.path.exists(dest):
                    errs.append(f"{rel}: dead link -> {target}")
                    continue
            else:
                dest = path             # same-file fragment
            if frag and dest.endswith(".md") and \
                    frag not in _anchors(dest):
                errs.append(f"{rel}: dead anchor -> {target}")
    return errs


def _public_defs(tree: ast.Module):
    """Yield (lineno, kind, name) for undocumented public defs."""
    if ast.get_docstring(tree) is None:
        yield 1, "module", "<module>"
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_") and \
                    ast.get_docstring(node) is None:
                yield node.lineno, "function", node.name
        elif isinstance(node, ast.ClassDef) and \
                not node.name.startswith("_"):
            if ast.get_docstring(node) is None:
                yield node.lineno, "class", node.name
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)) and \
                        not sub.name.startswith("_") and \
                        ast.get_docstring(sub) is None:
                    yield sub.lineno, "method", f"{node.name}.{sub.name}"


def check_docstrings() -> list:
    """Public API without docstrings in the covered packages."""
    errs = []
    for pkg in DOCSTRING_PACKAGES:
        root = os.path.join(REPO, pkg)
        for dirpath, _, files in os.walk(root):
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, REPO)
                with open(path, encoding="utf-8") as f:
                    tree = ast.parse(f.read(), filename=rel)
                for lineno, kind, name in _public_defs(tree):
                    errs.append(f"{rel}:{lineno}: undocumented {kind} "
                                f"{name}")
    return errs


def check_bench_schema() -> list:
    """Drift between the documented BENCH_serve schema and SCHEMA_KEYS."""
    bench_dir = os.path.join(REPO, "benchmarks")
    sys.path.insert(0, bench_dir)
    try:
        from serve_bench import SCHEMA_KEYS
    finally:
        # remove the exact entry we added: importing serve_bench runs its
        # own sys.path.insert(0, src/), so pop(0) would strip that instead
        # and leave benchmarks/ shadowing imports for the whole process
        sys.path.remove(bench_dir)
    declared = {k for keys in SCHEMA_KEYS.values() for k in keys}
    path = os.path.join(REPO, "docs", "ARCHITECTURE.md")
    if not os.path.exists(path):
        return ["docs/ARCHITECTURE.md missing (bench schema table)"]
    with open(path, encoding="utf-8") as f:
        text = f.read()
    m = re.search(r"<!-- BENCH_SERVE_SCHEMA -->(.*?)"
                  r"<!-- /BENCH_SERVE_SCHEMA -->", text, re.DOTALL)
    if not m:
        return ["docs/ARCHITECTURE.md: BENCH_SERVE_SCHEMA markers missing"]
    documented = set(re.findall(r"`([A-Za-z0-9_]+)`", m.group(1)))
    errs = []
    if declared - documented:
        errs.append("BENCH_serve keys emitted but not documented: "
                    f"{sorted(declared - documented)}")
    if documented - declared:
        errs.append("BENCH_serve keys documented but not emitted: "
                    f"{sorted(documented - declared)}")
    return errs


def main() -> int:
    """Run all checks; print a report and return a shell exit code."""
    failures = []
    for name, check in [("links", check_links),
                        ("docstrings", check_docstrings),
                        ("bench-schema", check_bench_schema)]:
        errs = check()
        status = "ok" if not errs else f"{len(errs)} problem(s)"
        print(f"[check_docs] {name}: {status}")
        for e in errs:
            print(f"  {e}")
        failures.extend(errs)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
