"""Multi-rank (sharded) serving end to end: token identity against the
single-rank engine on the same seed/trace, per-rank and peer-lane page
traces replaying against the scalar oracle, cross-rank restores beating
N independent cold restores on shared prefixes, placement invariants
under churn (hypothesis), and the ``make_production_mesh(shape=...)``
override tests/benches rely on to build small meshes.

The sharded cases need forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the tier-1 CI
job sets it); on a single-device interpreter they skip, never fail.
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sharded_tier import PEER_LINK_MEDIA, ShardedTier
from repro.core.tier import CxlTier, TierConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sim.engine import replay_page_trace

ENTRY = 32 << 10
N_DEVICES = len(jax.devices())

needs2 = pytest.mark.skipif(
    N_DEVICES < 2, reason="needs >= 2 devices (XLA_FLAGS="
    "--xla_force_host_platform_device_count=4)")
needs4 = pytest.mark.skipif(
    N_DEVICES < 4, reason="needs >= 4 devices (XLA_FLAGS="
    "--xla_force_host_platform_device_count=4)")


def _needs(n_ranks):
    if N_DEVICES < n_ranks:
        pytest.skip(f"needs >= {n_ranks} devices, have {N_DEVICES}")


# ------------------------------------------- mesh shape override (fix)

def test_production_mesh_shape_override_validation():
    """Bad explicit shapes fail fast with a ValueError, not deep in
    ``jax.make_mesh``."""
    for bad in ((0, 2), (2,), (1, 2, 3, 4), (1, -1)):
        with pytest.raises(ValueError, match="2- or 3-tuple"):
            make_production_mesh(shape=bad)


def test_production_mesh_shape_insufficient_devices():
    """Asking for more devices than the process has names the fix
    (the XLA_FLAGS device-count escape hatch) in the error."""
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_production_mesh(shape=(1, N_DEVICES + 1))


@needs2
def test_production_mesh_small_shapes_build():
    """The ``shape=`` override builds small meshes with the production
    axis names — no XLA_FLAGS=...=512 dry-run env needed."""
    mesh = make_production_mesh(shape=(1, 2))
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, 2)
    mesh3 = make_production_mesh(shape=(1, 1, 2))
    assert mesh3.axis_names == ("pod", "data", "model")
    if N_DEVICES >= 4:
        assert make_production_mesh(shape=(2, 2)).devices.shape == (2, 2)


# ---------------------------------------------------- config plumbing

def test_serve_config_shard_knobs():
    from repro.serving.config import ServeConfig
    assert ServeConfig().resolved_mesh_shape == ()
    assert ServeConfig().n_ranks == 1
    assert ServeConfig(tp=2).resolved_mesh_shape == (1, 2)
    assert ServeConfig(tp=2).n_ranks == 2
    assert ServeConfig(mesh_shape=(2, 4)).n_ranks == 4
    assert ServeConfig(mesh_shape=(2, 4), tp=4).resolved_mesh_shape == \
        (2, 4)
    with pytest.raises(ValueError, match="conflicts with tp"):
        ServeConfig(mesh_shape=(1, 2), tp=4)
    with pytest.raises(ValueError, match="2- or 3-tuple"):
        ServeConfig(mesh_shape=(0, 2))
    with pytest.raises(ValueError, match="tp must be >= 1"):
        ServeConfig(tp=0)
    with pytest.raises(ValueError, match="legacy host path"):
        ServeConfig(tp=2, legacy_host_path=True)


def test_serve_config_builds_sharded_tier():
    from repro.serving.config import ServeConfig
    sc = ServeConfig(tp=2, tier_topology=("dram", "ssd-fast"))
    tier = sc.make_tier()
    assert isinstance(tier, ShardedTier) and tier.n_ranks == 2
    assert len(tier.ranks) == 2 and len(tier.peer) == 2
    assert isinstance(ServeConfig(tier_media="ssd-fast").make_tier(),
                      CxlTier)
    # fault schedule lands on rank 0's ports only
    sc = ServeConfig(tp=2, tier_topology=("dram", "ssd-fast"),
                     tier_faults=(("hot_remove", 1e6, 1),))
    tier = sc.make_tier()
    assert tier.ranks[0].cfg.faults is not None
    assert tier.ranks[1].cfg.faults is None


@needs2
def test_engine_rejects_indivisible_page_axis():
    """n_pages % n_ranks != 0 is a construction-time error that names
    the knob to turn, not a silent fall-back to unsharded attention."""
    eng = _build_engine(tp=2)           # kv_page_size=16 divides fine
    assert eng.stats["mesh_ranks"] == 2
    with pytest.raises(ValueError, match="divisible by the model axis"):
        _build_engine(tp=2, kv_page_size=256)   # 1 page, 2 ranks


# --------------------------------------------- sharded decode identity

def _build_engine(*, tp=1, kv_quant="none", kv_page_size=16, n_slots=2,
                  tier=False, faults=(), seed=0):
    from repro.configs import registry
    from repro.configs.base import MeshConfig, RunConfig, SHAPES
    from repro.models import model as M
    from repro.serving.config import ServeConfig
    from repro.serving.engine import ServingEngine

    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                   mesh=MeshConfig())
    rc = dataclasses.replace(rc, kv_page_size=kv_page_size)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    kw = dict(n_slots=n_slots, max_seq=64, prefill_chunk=8, tp=tp,
              kv_quant=kv_quant, seed=seed)
    if tier or faults:
        kw.update(tier_topology=("dram", "ssd-fast"), cxl_async=True,
                  preempt_policy="recompute", tier_faults=tuple(faults))
    return ServingEngine(params, cfg, rc, config=ServeConfig(**kw))


def _greedy_tokens(eng, n_requests=3, max_new=8):
    from repro.serving.engine import Request
    handles = [eng.submit(Request(rid=i, prompt=[1 + i, 2, 3, 4 + i],
                                  max_new_tokens=max_new))
               for i in range(n_requests)]
    eng.run(max_ticks=600)
    return [h.result() for h in handles]


@pytest.fixture(scope="module")
def single_rank_tokens():
    """Greedy token streams from the 1-rank engine (host mesh), per
    kv_quant mode — the oracle every sharded run must reproduce."""
    out = {}
    with jax.set_mesh(make_host_mesh()):
        for kv_quant in ("none", "int8"):
            out[kv_quant] = _greedy_tokens(_build_engine(
                kv_quant=kv_quant))
    return out


@pytest.mark.parametrize("tp", [2, 4])
@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_sharded_decode_token_identity(tp, kv_quant,
                                       single_rank_tokens):
    """N-way tensor-parallel decode is bit-identical to the single-rank
    engine on the same seed and request trace — greedy, both the bf16
    and the int8-quantized KV cache (scales sharded alongside pages)."""
    _needs(tp)
    eng = _build_engine(tp=tp, kv_quant=kv_quant)
    assert eng.stats["mesh_ranks"] == tp
    # the paged KV cache really is sharded over the model axis
    leaf = jax.tree_util.tree_leaves(eng.cache["kv"])[0]
    assert "model" in str(leaf.sharding.spec)
    toks = _greedy_tokens(eng)
    assert toks == single_rank_tokens[kv_quant]


@needs2
def test_sharded_engine_with_tier_token_identity():
    """Attaching the ShardedTier (flush/restore on the simulated clock)
    must not perturb the generated tokens."""
    eng = _build_engine(tp=2, tier=True)
    toks = _greedy_tokens(eng)
    with jax.set_mesh(make_host_mesh()):
        ref = _greedy_tokens(_build_engine(tier=True))
    assert toks == ref
    assert isinstance(eng.tier, ShardedTier)


# ------------------------------------------------ rank-trace replay

def _replay_rank(t: CxlTier) -> np.ndarray:
    return replay_page_trace(
        t.ops, media=t.cfg.media_name,
        topology=t.cfg.port_medias if t.cfg.tagged else None,
        sr=t.cfg.sr_enabled, ds=t.cfg.ds_enabled,
        req_bytes=t.cfg.req_bytes,
        dram_cache_bytes=t.cfg.dram_cache_bytes,
        max_inflight=t.cfg.max_inflight, faults=t.cfg.faults)


def _replay_peer(tier: ShardedTier, rank: int) -> np.ndarray:
    return replay_page_trace(
        tier.peer_ops[rank], media=tier.peer_media, sr=False, ds=False,
        req_bytes=tier.cfg.req_bytes,
        dram_cache_bytes=tier.cfg.dram_cache_bytes,
        max_inflight=tier.cfg.max_inflight)


def _assert_sharded_replay(tier: ShardedTier) -> None:
    """Every rank's port-tagged trace AND every peer-link lane's
    single-stream trace replay within 1% of the scalar oracle."""
    for r, t in enumerate(tier.ranks):
        if t.ops:
            np.testing.assert_allclose(np.asarray(t.op_ns),
                                       _replay_rank(t), rtol=0.01)
        if tier.peer_ops[r]:
            np.testing.assert_allclose(np.asarray(tier.peer_op_ns[r]),
                                       _replay_peer(tier, r), rtol=0.01)


def test_rank_tagged_traces_replay_against_oracle():
    """Direct tier-level churn: writes stripe to home ranks, restores
    cross the peer link, and all 2N traces (N rank topologies + N peer
    lanes) replay within 1%."""
    tier = ShardedTier(2, TierConfig(topology=("dram", "ssd-fast")))
    for i in range(8):
        tier.write_entry(i, ENTRY)
    owners = {tier._owner[i] for i in range(8)}
    assert owners == {0, 1}                  # hash striping uses both
    tier.advance(5e5)
    for i in range(8):
        tier.read_entry(i, ENTRY)
    tier.advance(5e5)
    for i in range(0, 8, 2):
        tier.free_entry(i)
    c = tier.counters
    assert c["peer_fetches"] == 8 and c["peer_bytes"] > 0
    assert c["mirror_writes"] == 8           # first share mirrors once
    _assert_sharded_replay(tier)


def test_async_rank_traces_replay_against_oracle():
    """The async path (handles spanning rank media + peer link) keeps
    every trace independently replayable too."""
    tier = ShardedTier(2, TierConfig(topology=("dram", "ssd-fast")))
    handles = [tier.write_entry_async(i, ENTRY) for i in range(6)]
    while not all(tier.poll(h) for h in handles):
        tier.advance(1e4)
    handles = [tier.read_entry_async(i, ENTRY) for i in range(6)]
    while not all(tier.poll(h) for h in handles):
        tier.advance(1e4)
    assert all(getattr(h, "rank", None) in (0, 1) for h in handles)
    assert tier.counters["peer_fetches"] == 6
    assert tier.inflight_ops() == 0
    _assert_sharded_replay(tier)


@needs2
def test_serving_rank_traces_replay_under_load(mesh_ctx):
    """End to end: a 2-rank engine with the ShardedTier under an
    open-loop trace completes everything, surfaces the shard telemetry,
    and every rank + peer-lane trace replays within 1%."""
    from repro.serving import loadgen
    from repro.serving.loadgen import LoadConfig
    eng = _build_engine(tp=2, n_slots=4, tier=True)
    lc = LoadConfig(n_arrivals=16, rate_rps=8000.0, arrival="bursty",
                    n_prompts=8, prompt_len_choices=(8, 16),
                    max_new_choices=(4, 8), seed=0)
    handles, depths = loadgen.drive_open_loop(eng, loadgen.make_trace(lc),
                                              max_ticks=4000)
    metrics = loadgen.summarize(eng, handles, depths, lc)
    assert metrics.completed == 16 and metrics.lost_requests == 0
    assert eng.stats["mesh_ranks"] == 2
    assert eng.stats["flushes"] > 0
    _assert_sharded_replay(eng.tier)


# ------------------------------------- shared-prefix restore economics

def test_cross_rank_restore_cheaper_than_n_cold_restores():
    """The tentpole placement claim: restoring a zipf-shared prefix on
    an N-rank tier (one home-rank media fetch + one peer-link hop)
    is strictly cheaper than N independent cold restores of the same
    pages — for both 2 and 4 ranks, and the advantage grows with N."""
    advantages = {}
    for n in (2, 4):
        sharded = ShardedTier(n, TierConfig(topology=("ssd-fast",),
                                            sr_enabled=False))
        sharded.write_entry("prefix", ENTRY)     # flushed ONCE
        assert sum(t.counters["writes"] for t in sharded.ranks) == 1
        sharded.advance(1e6)
        shared_ns = sharded.read_entry("prefix", ENTRY)
        assert not sharded.last_entry_failed
        # the baseline: every rank keeps its own copy on its own ports
        # and cold-restores it independently
        cold_ns = 0.0
        for _ in range(n):
            solo = CxlTier(TierConfig(topology=("ssd-fast",),
                                      sr_enabled=False))
            solo.write_entry("prefix", ENTRY)
            solo.advance(1e6)
            cold_ns += solo.read_entry("prefix", ENTRY)
        assert shared_ns < cold_ns
        advantages[n] = cold_ns / shared_ns
        # the restore's mirror is the only extra copy: home + 1 mirror,
        # never one duplicate per rank
        writes = sum(t.counters["writes"] for t in sharded.ranks)
        assert writes == 2
    assert advantages[4] > advantages[2]


def test_peer_link_charges_partial_bytes():
    """The link hop carries only the other ranks' shards:
    nbytes * (N-1)/N, not a full duplicate of the entry."""
    tier = ShardedTier(4, TierConfig(topology=("ssd-fast",),
                                     sr_enabled=False))
    tier.write_entry("k", ENTRY)
    tier.read_entry("k", ENTRY)
    assert tier.counters["peer_bytes"] == (ENTRY * 3) // 4


# ------------------------------- placement invariants (hypothesis)

def _check_never_stranded(tier: ShardedTier, live, freed) -> None:
    """Every live key is resolvable to a rank that actually holds it;
    every freed key is gone from every rank; recorded owners are
    consistent with the holder sets."""
    for key in live:
        assert tier.has_entry(key)
        owner = tier._resolve_owner(key)
        assert owner is not None
        assert tier.ranks[owner].has_entry(key)
        held = tier._holders.get(key)
        assert held and owner in held
        for r in held:
            assert tier.ranks[r].has_entry(key)
    for key in freed:
        assert not tier.has_entry(key)
        assert key not in tier._owner and key not in tier._holders


_CHURN = st.one_of(
    st.tuples(st.just("write"), st.integers(0, 5),
              st.sampled_from((100, 5_000, ENTRY))),
    st.tuples(st.just("read"), st.integers(0, 5), st.just(ENTRY)),
    st.tuples(st.just("free"), st.integers(0, 5), st.just(0)),
    st.tuples(st.just("advance"), st.just(0), st.just(0)),
)


@given(st.lists(_CHURN, min_size=1, max_size=40),
       st.sampled_from((2, 3, 4)))
@settings(max_examples=25, deadline=None)
def test_rank_striped_placement_never_strands_entry(actions, n_ranks):
    """Random admit/flush/free/advance churn never strands an entry: a
    key some rank holds is always resolvable (and readable) through the
    facade, re-flushes collapse stale mirrors, frees reach every copy —
    and all the traces still replay at the end."""
    tier = ShardedTier(n_ranks, TierConfig(topology=("dram", "ssd-fast")))
    live, freed = set(), set()
    for op, key, nbytes in actions:
        if op == "write":
            tier.write_entry(key, nbytes)
            assert not tier.last_entry_failed
            live.add(key)
            freed.discard(key)
        elif op == "read":
            tier.read_entry(key, nbytes)   # cold-read allocates (CxlTier
            assert not tier.last_entry_failed   # parity), so key is live
            live.add(key)
            freed.discard(key)
        elif op == "free":
            tier.free_entry(key)
            live.discard(key)
            freed.add(key)
        else:
            tier.advance(1e5)
        _check_never_stranded(tier, live, freed)
    _assert_sharded_replay(tier)


def test_sharded_tier_validation_and_snapshot():
    with pytest.raises(ValueError, match="n_ranks >= 2"):
        ShardedTier(1, TierConfig())
    with pytest.raises(ValueError, match="fault_rank"):
        ShardedTier(2, TierConfig(), fault_rank=5)
    tier = ShardedTier(2, TierConfig(topology=("dram", "ssd-fast")))
    tier.write_entry("a", ENTRY)
    tier.read_entry("a", ENTRY)
    snap = tier.snapshot()
    assert snap["n_ranks"] == 2 and snap["peer_fetches"] == 1
    # CxlTier-shaped: the serving stats line reads these unconditionally
    for key in ("media", "writes", "async_writes", "write_ns", "reads",
                "async_reads", "sr_hit_rate", "gc_events", "frees",
                "segment_reuses", "placement", "ports"):
        assert key in snap
    rows = tier.port_stats()
    assert [r["rank"] for r in rows] == [0, 0, 1, 1]
    assert PEER_LINK_MEDIA == "dram"
