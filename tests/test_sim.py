"""Simulator validation: the paper's orderings must hold structurally.

Exact magnitudes are calibration (see EXPERIMENTS.md §Paper-validation);
these tests pin the DIRECTIONS the paper's Figure 9 reports, so a
regression in the controller/media models fails loudly.
"""
import pytest

from repro.sim import run, workloads

N = 6000  # small traces keep the suite fast; directions are stable


@pytest.fixture(scope="module")
def cache():
    return {}


def _run(cache, *a, **kw):
    key = (a, tuple(sorted(kw.items())))
    if key not in cache:
        cache[key] = run(*a, n_ops=N, **kw)
    return cache[key]


def test_uvm_much_slower_than_ideal(cache):
    base = _run(cache, "gpu-dram", "vadd", "dram").exec_ns
    uvm = _run(cache, "uvm", "vadd", "dram").exec_ns
    assert uvm > 10 * base


def test_cxl_close_to_ideal_on_dram(cache):
    """Fig 9a: CXL within tens of percent of GPU-DRAM."""
    for w in ("rsum", "vadd", "bfs"):
        base = _run(cache, "gpu-dram", w, "dram").exec_ns
        cxl = _run(cache, "cxl", w, "dram").exec_ns
        assert cxl < 2.0 * base, w
        assert cxl > 0.95 * base, w


def test_cxl_beats_uvm_everywhere(cache):
    for w in workloads.TABLE_1B:
        uvm = _run(cache, "uvm", w, "dram").exec_ns
        cxl = _run(cache, "cxl", w, "dram").exec_ns
        assert cxl < uvm, w


def test_sr_improves_ssd_reads(cache):
    """Fig 9b: SR a multiple faster than plain CXL on Z-NAND."""
    for w in ("vadd", "gemm", "sort"):
        cxl = _run(cache, "cxl", w, "znand").exec_ns
        sr = _run(cache, "cxl-sr", w, "znand").exec_ns
        assert sr < 0.7 * cxl, w


def test_sr_ablation_ladder(cache):
    """Fig 9d: hit rate rises NAIVE -> DYN on sequential workloads."""
    base = _run(cache, "cxl", "vadd", "znand")
    naive = _run(cache, "cxl-naive", "vadd", "znand")
    dyn = _run(cache, "cxl-dyn", "vadd", "znand")
    assert naive.ep_hit_rate > base.ep_hit_rate
    assert dyn.exec_ns <= naive.exec_ns * 1.05
    assert dyn.sr["bytes"] > naive.sr["bytes"]   # bigger MemSpecRd windows


def test_ds_helps_store_intensive(cache):
    """Fig 9b/9e: DS hides write/GC tails on store-heavy workloads."""
    for w in ("bfs", "gauss"):
        sr = _run(cache, "cxl-sr", w, "znand").exec_ns
        dsr = _run(cache, "cxl-ds", w, "znand").exec_ns
        assert dsr < 1.05 * sr, w
    bfs_sr = _run(cache, "cxl-sr", "bfs", "nand").exec_ns
    bfs_ds = _run(cache, "cxl-ds", "bfs", "nand").exec_ns
    assert bfs_ds < bfs_sr


def test_media_ordering(cache):
    """Slower media -> slower CXL baseline (Optane < Z-NAND < NAND)."""
    times = [
        _run(cache, "cxl", "vadd", m).exec_ns
        for m in ("dram", "optane", "znand", "nand")]
    assert times == sorted(times)


def test_ds_never_blocks_stores_under_gc(cache):
    r = _run(cache, "cxl-ds", "bfs", "znand")
    assert r.ds["fire_and_forget"] + r.ds["diverted"] > 0
    # diverted stores eventually flush (none lost)
    assert r.ds["flushed"] <= r.ds["diverted"]


def test_trace_determinism():
    t1 = workloads.generate("gnn", 2000, seed=3)
    t2 = workloads.generate("gnn", 2000, seed=3)
    assert (t1 == t2).all()
    t3 = workloads.generate("gnn", 2000, seed=4)
    assert not (t1["addr"] == t3["addr"]).all()


def test_table_1b_ratios():
    """Trace generator honours Table 1b's compute/load ratios."""
    import numpy as np
    for name in ("gemm", "bfs", "rsum"):
        spec = workloads.TABLE_1B[name]
        tr = workloads.generate(name, 50_000)
        kinds = tr["kind"]
        comp = float((kinds == 0).mean())
        loads = float((kinds == 1).sum()) / max((kinds > 0).sum(), 1)
        assert abs(comp - spec.compute_ratio) < 0.02, name
        assert abs(loads - spec.load_ratio) < 0.02, name
