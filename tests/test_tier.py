"""Differential tests for the CXL-timed memory tier.

The tier charges the serving engine's page traffic incrementally against
one simulated root port + EP while recording every op; the same trace
replayed from scratch through ``sim.engine.replay_page_trace`` (the
scalar oracle) must reproduce the charged latencies within 1%, and on
DRAM-class media the ``sim.vector`` closed form must agree too. On top
of the cross-validation: SR must strictly reduce restore stall on the
SSD bins, and the EP's announced state must gate the QoS flusher without
breaking reads (the staging read-through path).
"""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.core.tier import CxlTier, MEDIA_BINS, TierConfig
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.sim import vector
from repro.sim.engine import (PAGE_PREFETCH, PAGE_READ, PAGE_WRITE,
                              replay_page_trace)

ENTRY = 32 << 10          # synthetic page-entry size (bytes)


def _replay(tier: CxlTier) -> np.ndarray:
    return replay_page_trace(tier.ops, media=tier.cfg.media_name,
                             sr=tier.cfg.sr_enabled, ds=tier.cfg.ds_enabled,
                             req_bytes=tier.cfg.req_bytes,
                             dram_cache_bytes=tier.cfg.dram_cache_bytes,
                             faults=tier.cfg.faults)


def _settle(eng, max_windows: int = 300) -> None:
    """Advance simulated time until staging drains into the cold tier."""
    for _ in range(max_windows):
        if not eng.flusher.pending:
            return
        eng.tier.advance(eng.tier_step_ns)
        eng.flusher.maybe_flush()
    raise AssertionError("staging did not drain into the cold tier")


# ------------------------------------------------- tier vs scalar oracle

def test_serving_page_trace_matches_scalar_oracle(mesh_ctx):
    """The tentpole cross-validation: per-page latencies charged online
    during a real serving run (flush -> SR prefetch -> restore, engine
    ticks interleaved) must match the scalar-oracle replay of the
    recorded trace within 1%."""
    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    tier = CxlTier(TierConfig(media="ssd-fast", sr_enabled=True))
    eng = ServingEngine(params, cfg, rc, n_slots=2, max_seq=32,
                        prefill_chunk=4, cxl_tier=tier)
    prompts = [[i + 1, 2, 3, 4, 5] for i in range(4)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.run(max_ticks=200)
    _settle(eng)
    for i, p in enumerate(prompts):          # restores: the charged reads
        eng.submit(Request(rid=100 + i, prompt=p, max_new_tokens=3))
    eng.run(max_ticks=200)

    assert eng.stats["prefix_hits"] == len(prompts)
    kinds = [k for k, _, _ in tier.ops]
    assert kinds.count(PAGE_WRITE) >= len(prompts)     # flushes charged
    assert kinds.count(PAGE_READ) == len(prompts)      # restores charged
    assert kinds.count(PAGE_PREFETCH) == len(prompts)  # SR at enqueue
    assert eng.stats["restore_stall_ns"] > 0
    restored = [r for r in eng.finished if r.restored]
    assert all(r.restore_stall_ns > 0 for r in restored)

    oracle = _replay(tier)
    np.testing.assert_allclose(np.asarray(tier.op_ns), oracle, rtol=0.01)


def test_quantized_page_trace_matches_scalar_oracle(mesh_ctx):
    """The kv_quant differential: the same serve -> settle -> restore
    scenario with int8 KV pages records a trace whose per-page charges
    replay through the scalar oracle within 1% — AND the quantized run's
    tier byte counters shrink by ~the cache dtype's itemsize (per-page
    scales add back well under 1%)."""
    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    prompts = [[i + 1, 2, 3, 4, 5] for i in range(4)]
    traffic, itemsize = {}, None
    for mode in ("none", "int8"):
        tier = CxlTier(TierConfig(media="ssd-fast", sr_enabled=True))
        eng = ServingEngine(params, cfg, rc, n_slots=2, max_seq=32,
                            prefill_chunk=4, cxl_tier=tier, kv_quant=mode)
        if mode == "none":
            itemsize = np.dtype(eng.cache["kv"]["k"].dtype).itemsize
        else:
            assert eng.cache["kv"]["k"].dtype == "int8"
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        eng.run(max_ticks=200)
        _settle(eng)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=100 + i, prompt=p, max_new_tokens=3))
        eng.run(max_ticks=200)
        assert eng.stats["prefix_hits"] == len(prompts)
        traffic[mode] = (tier.counters["read_bytes"]
                         + tier.counters["write_bytes"])
        assert traffic[mode] > 0
        np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                                   rtol=0.01)
    ratio = traffic["int8"] / traffic["none"]
    assert ratio < 1.0 / itemsize + 0.05


@pytest.mark.parametrize("media,sr", [("ssd-fast", False), ("ssd-slow", True),
                                      ("dram", True)])
def test_synthetic_page_trace_matches_scalar_oracle(media, sr):
    """Oracle agreement across media bins / SR modes on a pure page-op
    stream (no engine in the loop, so every bin stays cheap to cover)."""
    tier = CxlTier(TierConfig(media=media, sr_enabled=sr))
    for i in range(6):
        tier.write_entry(i, ENTRY)
        tier.advance(50_000.0)
    for i in range(6):
        tier.speculative_read(i, ENTRY)
        tier.read_entry(i, ENTRY)
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)


def test_dram_bin_matches_vector_closed_form():
    """On the DRAM bin the blocking stream never queues, so the vectorized
    closed form is exact — an implementation-independent cross-check."""
    tier = CxlTier(TierConfig(media="dram"))
    for i in range(4):
        tier.write_entry(i, ENTRY)
        tier.speculative_read(i, ENTRY)
        tier.read_entry(i, ENTRY)
        tier.advance(10_000.0)
    cf = vector.page_trace_closed_form(tier.ops, "dram", ds=True,
                                       req_bytes=tier.cfg.req_bytes)
    np.testing.assert_allclose(np.asarray(tier.op_ns), cf, rtol=1e-9)
    with pytest.raises(ValueError):
        vector.page_trace_closed_form(tier.ops, "znand")


# --------------------------------------------------------- SR mechanism

@pytest.mark.parametrize("media", ["ssd-fast", "ssd-slow"])
def test_sr_strictly_reduces_restore_stall(media):
    """The paper's headline mechanism at page granularity: MemSpecRd ahead
    of the demand fetch strictly beats cold demand reads on SSD media."""
    stall = {}
    for sr in (False, True):
        tier = CxlTier(TierConfig(media=media, sr_enabled=sr))
        for i in range(8):      # working set > EP cache: entries age out
            tier.write_entry(i, ENTRY)
        stall[sr] = 0.0
        for i in range(8):
            tier.speculative_read(i, ENTRY)
            stall[sr] += tier.read_entry(i, ENTRY)
    assert stall[True] < stall[False]
    tier_dram = CxlTier(TierConfig(media="dram", sr_enabled=True))
    tier_dram.write_entry(0, ENTRY)
    tier_dram.speculative_read(0, ENTRY)
    assert tier_dram.counters["prefetches"] == 1
    assert tier_dram.stream.ep.stats["prefetches"] == 0  # no-op on DRAM


def test_sr_hit_rate_surfaced():
    tier = CxlTier(TierConfig(media="ssd-fast", sr_enabled=True))
    tier.write_entry(0, ENTRY)
    for i in range(1, 6):       # push entry 0 out of the EP cache
        tier.write_entry(i, ENTRY)
    tier.speculative_read(0, ENTRY)
    tier.read_entry(0, ENTRY)
    assert tier.sr_hit_rate() > 0.5


# ------------------------------------------------- DS admission gating

def test_admit_store_gates_flusher_and_reads_stay_correct():
    """A congested EP closes the flush window (admission deferral); staged
    pages keep serving restores through the staging index meanwhile."""
    from repro.core.deterministic_store import StagingFlusher
    from repro.core.qos import DevLoad

    tier = CxlTier(TierConfig(media="ssd-slow", sr_enabled=True))
    # drive the EP to announce an internal task: writes until GC is pending
    i = 0
    while not tier.stream.ep.gc_pending() and i < 64:
        tier.write_entry(("warm", i), ENTRY)
        i += 1
    assert tier.stream.ep.gc_pending()
    assert not tier.admit_store()
    assert tier.counters["deferred_admits"] >= 1

    sunk = []
    fl = StagingFlusher(sink=lambda k, v: sunk.append(k),
                        admit=tier.admit_store)
    fl.stage(1, {"prompt": (1,)})
    assert fl.maybe_flush() == 0 and fl.deferred == 1
    assert fl.pending and not sunk              # pages parked, not lost
    # the EP recovers once the write stream pauses (the divert gives it
    # exactly that window): idle simulated time, then the flush drains
    for _ in range(200):
        tier.advance(100_000.0)
        if fl.maybe_flush():
            break
    assert sunk == [1] and not fl.pending


def test_flusher_without_admit_hook_unchanged():
    from repro.core.deterministic_store import StagingFlusher

    sunk = []
    fl = StagingFlusher(sink=lambda k, v: sunk.append(k))
    fl.stage(1, "a")
    assert fl.maybe_flush() == 1 and sunk == [1] and fl.deferred == 0


# ----------------------------------------------------------- allocator

def test_allocator_ranges_stable_and_page_aligned():
    tier = CxlTier(TierConfig(media="ssd-fast"))
    tier.write_entry("a", 5000)
    tier.write_entry("b", 100)
    tier.write_entry("a", 5000)                  # re-flush: same range
    (k0, a0, n0), (k1, a1, _), (k2, a2, n2) = tier.ops
    assert a0 == a2 and n0 == n2 == 5000
    assert a1 % tier.cfg.page_bytes == 0 and a1 >= 8192  # a got 2 pages
    tier.write_entry("a", 9000)                  # grown: relocates
    assert tier.ops[-1][1] != a0


def test_fault_trace_replay_requires_schedule():
    """A fault-annotated tier trace must not replay without the recording
    run's FaultSchedule (the oracle would silently misprice retries);
    with it, the replay is exact."""
    from repro.sim.engine import FaultSchedule, transient

    fs = FaultSchedule((transient(0.0, 0, 1.0),), seed=1)
    tier = CxlTier(TierConfig(media="ssd-fast", sr_enabled=False,
                              faults=fs))
    tier.write_entry("a", ENTRY)
    tier.read_entry("a", ENTRY)
    assert tier.last_entry_failed
    with pytest.raises(ValueError, match="FaultSchedule"):
        replay_page_trace(tier.ops, media=tier.cfg.media_name,
                          sr=False, ds=tier.cfg.ds_enabled,
                          req_bytes=tier.cfg.req_bytes,
                          dram_cache_bytes=tier.cfg.dram_cache_bytes)
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)
