"""Fault injection through the stack: deterministic ``FaultSchedule``
semantics in the simulator, page-loss/invalidation behavior in the tier,
allocator invariants under fault churn (hypothesis), and the serving
engine's RECOVERING lifecycle under an open-loop trace — ending every
scenario with the same differential gate the benches use: the recorded
(fault-annotated) page trace must replay against the scalar oracle.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tier import CxlTier, TierConfig
from repro.runtime.fault_tolerance import Heartbeat, StragglerMitigator
from repro.sim.engine import (MAX_OP_RETRIES, PAGE_FAULT_KINDS,
                              FaultSchedule, degrade, hot_remove,
                              replay_page_trace, transient)

ENTRY = 32 << 10


def _replay(tier: CxlTier) -> np.ndarray:
    return replay_page_trace(
        tier.ops, media=tier.cfg.media_name,
        topology=tier.cfg.port_medias if tier.cfg.tagged else None,
        sr=tier.cfg.sr_enabled, ds=tier.cfg.ds_enabled,
        req_bytes=tier.cfg.req_bytes,
        dram_cache_bytes=tier.cfg.dram_cache_bytes,
        max_inflight=tier.cfg.max_inflight, faults=tier.cfg.faults)


# ------------------------------------------------------- FaultSchedule

def test_fault_schedule_state_is_pure_and_windowed():
    """state(port, t) is a pure fold over the event list: repeated
    queries agree, windows open/close at their boundaries, hot-remove
    latches forever."""
    fs = FaultSchedule((degrade(1_000.0, 0, 4.0, 5_000.0),
                        transient(2_000.0, 0, 0.5, 6_000.0),
                        hot_remove(7_000.0, 1)))
    for _ in range(2):                 # idempotent re-query
        assert fs.state(0, 0.0).mult == 1.0
        assert fs.state(0, 1_000.0).mult == 4.0
        assert fs.state(0, 4_999.0).mult == 4.0
        assert fs.state(0, 5_000.0).mult == 1.0
        assert fs.state(0, 2_500.0).p_err == 0.5
        assert fs.state(0, 6_000.0).p_err == 0.0
        assert not fs.state(1, 6_999.0).down
        assert fs.state(1, 7_000.0).down
        assert fs.state(1, 1e12).down          # latched for good
        assert not fs.state(0, 1e12).down      # other port unaffected
    assert list(fs.ports_down(8_000.0)) == [1]
    assert list(fs.ports_down(0.0)) == []


def test_fault_schedule_op_fails_deterministic():
    """Failure draws hash (seed, port, attempt) — identical across runs
    (the property the replay oracle rests on), extreme probabilities are
    exact, and the seed actually matters."""
    fs = FaultSchedule((), seed=3)
    draws = [fs.op_fails(0, a, 0.5) for a in range(64)]
    assert draws == [fs.op_fails(0, a, 0.5) for a in range(64)]
    assert all(not fs.op_fails(0, a, 0.0) for a in range(64))
    assert all(fs.op_fails(0, a, 1.0) for a in range(64))
    other = FaultSchedule((), seed=4)
    assert draws != [other.op_fails(0, a, 0.5) for a in range(64)]


def test_fault_event_validation():
    with pytest.raises(ValueError):
        degrade(0.0, 0, 0.0)               # mult must be positive
    with pytest.raises(ValueError):
        transient(0.0, 0, 1.5)             # p_err is a probability
    with pytest.raises(ValueError):
        FaultSchedule((degrade(5.0, 0, 2.0, 1.0),))  # empty window


# ------------------------------------------------- simulator semantics

def test_degrade_window_scales_service_and_recovers():
    """Inside the window reads cost ~mult x the healthy latency; after
    it closes the port serves at base speed again — and the recorded
    trace replays exactly."""
    fs = FaultSchedule((degrade(1e6, 0, 8.0, 2e6),))
    tier = CxlTier(TierConfig(topology=("dram",), sr_enabled=False,
                              faults=fs))
    tier.write_entry("a", ENTRY)
    healthy = tier.read_entry("a", ENTRY)
    tier.advance(1e6 - tier.topo.now + 10.0)   # into the window
    slow = tier.read_entry("a", ENTRY)
    tier.advance(2e6 - tier.topo.now + 10.0)   # past it
    recovered = tier.read_entry("a", ENTRY)
    # only the media component scales (link/controller costs don't), so
    # the end-to-end factor is below mult but still well above healthy
    assert slow > 2.0 * healthy
    assert recovered == pytest.approx(healthy, rel=0.01)
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)


def test_transient_retries_bounded_and_replay_exact():
    """p_err=1.0 exhausts the retry budget on every op: retries stay at
    MAX_OP_RETRIES per op (no livelock), failures are counted, backoff
    is charged — and the fault-annotated trace still replays exactly."""
    fs = FaultSchedule((transient(0.0, 0, 1.0),), seed=1)
    tier = CxlTier(TierConfig(topology=("ssd-fast",), sr_enabled=False,
                              faults=fs))
    for i in range(3):
        tier.write_entry(i, ENTRY)
        tier.read_entry(i, ENTRY)
        assert tier.last_entry_failed
    ps = tier.topo.ports[0]
    n_ops = tier.counters["fault_ops"]
    assert n_ops == 6
    assert ps.fault_failures == 6
    # a failed op charges MAX_OP_RETRIES backoff retries plus the final
    # budget-exhausting attempt
    assert ps.fault_retries == 6 * (MAX_OP_RETRIES + 1)
    assert ps.fault_backoff_ns > 0
    assert any(op[1] in PAGE_FAULT_KINDS for op in tier.ops)  # tagged ops
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)


def test_hot_removed_port_ops_cost_nothing():
    """After removal every op on the port fails instantly at zero cost:
    the clock and the media cursor stop moving."""
    fs = FaultSchedule((hot_remove(1e6, 0),))
    tier = CxlTier(TierConfig(topology=("ssd-fast",), sr_enabled=False,
                              faults=fs))
    tier.write_entry("a", ENTRY)
    tier.advance(2e6)
    assert tier.poll_faults() == []            # already swept by advance
    assert tier.topo.ports_down() == [0]
    before = tier.topo.ports[0].now
    with pytest.raises(RuntimeError, match="hot-removed"):
        tier.write_entry("b", ENTRY)           # nowhere left to place
    assert tier.topo.ports[0].now == before


# ------------------------------------------------------ tier page loss

def test_hot_remove_invalidates_and_restripes():
    """Removal tears every entry with a segment on the dead port, the
    tier reports the lost keys once, and new placements stripe around
    the dead port — with the whole trace replaying exactly."""
    fs = FaultSchedule((hot_remove(1e6, 1),))
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast", "ssd-slow"),
                              placement="striped", faults=fs))
    for i in range(6):
        tier.write_entry(i, ENTRY)
    assert any(p == 1 for segs in tier._segments.values()
               for p, _, _ in segs)
    tier.advance(2e6)
    lost = tier.take_lost_keys()
    assert lost and set(lost) <= set(range(6))
    assert tier.counters["lost_entries"] == len(lost)
    assert tier.counters["lost_bytes"] > 0
    assert tier.take_lost_keys() == []          # reported exactly once
    for key in lost:
        assert not tier.has_entry(key)
        assert tier.free_entry(key) == 0        # counted no-op, no raise
    assert tier.counters["noop_frees"] >= len(lost)
    for i in range(6, 12):                      # re-stripe around port 1
        tier.write_entry(i, ENTRY)
        assert all(p != 1 for p, _, _ in tier._segments[i])
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)


def test_hotness_demotes_away_from_degraded_port():
    """When the fast port's effective read latency (base x degrade mult)
    falls behind another port, hotness placement demotes its residents
    (DRAM needs > ~164x to lose to znand-backed ssd-fast)."""
    fs = FaultSchedule((degrade(1e6, 0, 400.0),))
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast"),
                              placement="hotness", faults=fs))
    for i in range(3):
        tier.write_entry(i, ENTRY)
        for _ in range(3):                      # heat them onto the DRAM
            tier.read_entry(i, ENTRY)
    assert tier._fast_port == 0
    promoted = tier.counters["promotions"]
    tier.advance(2e6)
    assert tier._fast_port == 1
    assert tier.counters["demotions"] >= min(promoted, 1)
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)


# ------------------------------------ allocator invariants (hypothesis)

def _check_alloc_invariants(tier: CxlTier) -> None:
    """No leak, no double-free, byte conservation, free/live disjoint."""
    pg = tier.cfg.page_bytes
    for p in range(len(tier.topo.ports)):
        if p in tier._down_ports:
            assert not tier._free[p] and tier._live_bytes[p] == 0
            continue
        free_bases = [b for bases in tier._free[p].values()
                      for b in bases]
        assert len(free_bases) == len(set(free_bases))
        live = [(base, length) for segs in tier._segments.values()
                for (pp, base, length) in segs if pp == p]
        assert sum(length for _, length in live) == tier._live_bytes[p]
        assert not ({b for b, _ in live} & set(free_bases))
        for npg, bases in tier._free[p].items():
            assert npg >= 1 and all(b % pg == 0 for b in bases)


_ACTION = st.one_of(
    st.tuples(st.just("write"), st.integers(0, 7),
              st.sampled_from((100, 5_000, ENTRY))),
    st.tuples(st.just("free"), st.integers(0, 7)),
    st.tuples(st.just("free_unknown"), st.integers(100, 107)),
    st.tuples(st.just("advance"), st.just(0)),
)


@given(st.lists(_ACTION, min_size=1, max_size=40))
@settings(max_examples=25, deadline=None)
def test_allocator_invariants_under_fault_churn(actions):
    """Random alloc/free/re-flush/hot-remove interleavings never leak a
    segment, never double-free a base, and keep per-port byte accounting
    conserved — the ``free_entry`` hardening under fault churn."""
    fs = FaultSchedule((hot_remove(5e5, 1),))
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast"),
                              placement="striped", faults=fs))
    for op, key, *rest in actions:
        if op == "write":
            tier.write_entry(key, rest[0])
        elif op in ("free", "free_unknown"):
            tier.free_entry(key)
            tier.free_entry(key)        # double free: counted no-op
        else:
            tier.advance(3e5)           # eventually fires the hot-remove
        _check_alloc_invariants(tier)
    tier.advance(1e6)                   # force the removal if not yet
    tier.take_lost_keys()
    _check_alloc_invariants(tier)
    before = tier.counters["noop_frees"]
    assert tier.free_entry("never-written") == 0
    assert tier.counters["noop_frees"] == before + 1


# --------------------------------------------------- runtime satellites

def test_heartbeat_injectable_clock_deterministic():
    """Liveness on an injected clock is a pure function of simulated
    time — the serving engine's clock_ns slots straight in."""
    t = {"now": 0.0}
    hb = Heartbeat(2, dead_after_s=10.0, now=lambda: t["now"])
    hb.stamp(0, step=1, step_time=0.1)
    hb.stamp(1, step=1, step_time=0.1)
    assert hb.dead_workers() == []
    t["now"] = 5.0
    hb.stamp(0, step=2, step_time=0.1)
    t["now"] = 12.0
    assert hb.dead_workers() == [1]     # stamped at 0, now 12 > 10
    t["now"] = 100.0
    assert hb.dead_workers() == [0, 1]


def test_straggler_mitigator_maps_port_states():
    """Per-port tier DevLoad states fold into the same action set the
    fleet straggler policy uses: down -> evict, degraded or pressured ->
    throttle, healthy -> ok."""
    sm = StragglerMitigator(evict_threshold=2.0)
    rows = [
        {"port": 0, "down": False, "degrade_mult": 1.0, "devload": 0},
        {"port": 1, "down": True, "degrade_mult": 1.0, "devload": 0},
        {"port": 2, "down": False, "degrade_mult": 300.0, "devload": 0},
        {"port": 3, "down": False, "degrade_mult": 1.0, "devload": 2},
    ]
    assert sm.assess_ports(rows) == {0: "ok", 1: "evict", 2: "throttle",
                                     3: "throttle"}


def test_straggler_mitigator_on_live_tier_stats():
    """assess_ports consumes real ``CxlTier.port_stats()`` rows."""
    fs = FaultSchedule((hot_remove(1e6, 1),))
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast"), faults=fs))
    tier.write_entry("a", ENTRY)
    tier.advance(2e6)
    actions = StragglerMitigator().assess_ports(tier.port_stats())
    assert actions[1] == "evict" and actions[0] in ("ok", "throttle")


# ------------------------------------------- serving engine recovery

def _serve_engine(faults, *, n_slots=4, seed=0):
    import jax

    from repro.configs import registry
    from repro.configs.base import MeshConfig, RunConfig, SHAPES
    from repro.models import model as M
    from repro.serving.config import ServeConfig
    from repro.serving.engine import ServingEngine

    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                   mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(n_slots=n_slots, max_seq=64, prefill_chunk=8,
                     cxl_async=True, preempt_policy="recompute",
                     tier_topology=("dram", "ssd-fast"),
                     tier_faults=faults, fault_seed=seed)
    return ServingEngine(params, cfg, rc, config=sc)


def _drive(engine, n_arrivals=16):
    from repro.serving import loadgen
    from repro.serving.loadgen import LoadConfig

    lc = LoadConfig(n_arrivals=n_arrivals, rate_rps=8000.0,
                    arrival="bursty", n_prompts=8,
                    prompt_len_choices=(8, 16), max_new_choices=(4, 8),
                    seed=0)
    trace = loadgen.make_trace(lc)
    handles, depths = loadgen.drive_open_loop(engine, trace,
                                              max_ticks=4000)
    return loadgen.summarize(engine, handles, depths, lc), handles


def test_serve_config_fault_validation():
    from repro.serving.config import ServeConfig
    with pytest.raises(ValueError, match="without a tier"):
        ServeConfig(tier_faults=(("hot_remove", 1e6, 0),))
    with pytest.raises(ValueError, match="unknown fault event"):
        ServeConfig(tier_media="ssd-fast",
                    tier_faults=(("meteor_strike", 1e6, 0),))
    sc = ServeConfig(tier_topology=("dram", "ssd-fast"),
                     tier_faults=(("degrade", 1e6, 0, 4.0),
                                  ("transient", 0.0, 1, 0.5, 2e6),
                                  ("hot_remove", 3e6, 1)), fault_seed=7)
    fs = sc.make_fault_schedule()
    assert fs is not None and fs.seed == 7 and len(fs.events) == 3
    assert sc.make_tier().cfg.faults is fs.__class__(
        fs.events, seed=7).__class__ or True  # tier carries a schedule
    assert sc.make_tier().cfg.faults.events == fs.events


def test_recovering_lifecycle_under_flaky_ports(mesh_ctx):
    """A high-p_err transient window on both ports forces failed tier
    reads mid-flight: requests pass through RECOVERING at least once and
    every one of them still completes (bounded retries, no livelock)."""
    eng = _serve_engine((("transient", 0.0, 0, 0.97, 4.0e6),
                         ("transient", 0.0, 1, 0.97, 4.0e6)), seed=1)
    metrics, handles = _drive(eng, n_arrivals=16)
    assert metrics.completed == 16
    assert metrics.lost_requests == 0
    assert eng.stats["tier_fault_ops"] > 0
    assert eng.stats["recoveries"] >= 1
    assert metrics.recoveries == eng.stats["recoveries"]
    # bounded: every fault op retries at most MAX_OP_RETRIES+1 times
    assert eng.stats["tier_fault_retries"] <= \
        eng.stats["tier_fault_ops"] * (MAX_OP_RETRIES + 1)
    assert all(h.done for h in handles)


def test_hot_remove_mid_decode_loses_no_requests(mesh_ctx):
    """Hot-removing a port mid-trace tears tier entries but the engine
    recovers every affected request: zero lost, and the fault-annotated
    page trace still replays against the scalar oracle."""
    from repro.sim.engine import replay_page_trace
    eng = _serve_engine((("hot_remove", 1.5e6, 1),), seed=0)
    metrics, handles = _drive(eng, n_arrivals=16)
    assert metrics.completed == 16
    assert metrics.lost_requests == 0
    assert eng.stats["tier_ports_down"] == 1
    tier = eng.tier
    np.testing.assert_allclose(
        np.asarray(tier.op_ns),
        replay_page_trace(tier.ops, media=tier.cfg.media_name,
                          topology=tier.cfg.port_medias,
                          sr=tier.cfg.sr_enabled, ds=tier.cfg.ds_enabled,
                          req_bytes=tier.cfg.req_bytes,
                          dram_cache_bytes=tier.cfg.dram_cache_bytes,
                          max_inflight=tier.cfg.max_inflight,
                          faults=tier.cfg.faults),
        rtol=0.01)


# ------------------------------------- sharded serving x fault recovery

def test_sharded_hot_remove_recovers_via_peer_rank():
    """Hot-removing one rank's entire port set mid-decode in a 2-rank
    engine loses zero requests: keys with a peer-rank mirror remap to
    the survivor (the engine never sees the fault), the rest re-queue
    through RECOVERING, new flushes fall over to the live rank — and
    every rank's fault-annotated trace plus every peer-link lane trace
    still replays against the scalar oracle."""
    import dataclasses

    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")

    from repro.configs import registry
    from repro.configs.base import MeshConfig, RunConfig, SHAPES
    from repro.core.sharded_tier import ShardedTier
    from repro.models import model as M
    from repro.serving.config import ServeConfig
    from repro.serving.engine import ServingEngine

    cfg = registry.smoke("qwen3-1.7b")
    rc = dataclasses.replace(
        RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                  mesh=MeshConfig()),
        kv_page_size=16)           # page axis divisible by 2 ranks
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(n_slots=4, max_seq=64, prefill_chunk=8, tp=2,
                     cxl_async=True, preempt_policy="recompute",
                     tier_topology=("dram", "ssd-fast"),
                     tier_faults=(("hot_remove", 3.0e6, 0),
                                  ("hot_remove", 3.0e6, 1)), fault_seed=0)
    eng = ServingEngine(params, cfg, rc, config=sc)
    metrics, handles = _drive(eng, n_arrivals=16)

    assert metrics.completed == 16
    assert metrics.lost_requests == 0
    assert all(h.done for h in handles)
    tier = eng.tier
    assert isinstance(tier, ShardedTier)
    # the whole of rank 0's topology is gone; rank 1 carries on
    assert eng.stats["tier_ports_down"] == 2
    assert tier.ranks[0].topo.ports_down() == [0, 1]
    assert tier.ranks[1].topo.ports_down() == []
    # recovery came through the peer rank's mirror copy, and the engine
    # surfaces it in the shard telemetry
    assert tier.shard_counters["peer_recoveries"] >= 1
    assert eng.stats["tier_peer_recoveries"] == \
        tier.shard_counters["peer_recoveries"]
    assert eng.stats["tier_rank_remaps"] == \
        tier.shard_counters["rank_remaps"]
    assert eng.stats["tier_peer_fetches"] > 0
    # post-removal flushes land on the surviving rank only
    assert all(r == 1 for r in tier._owner.values())
    # every trace replays: rank 0 against its fault schedule, rank 1
    # clean, and both peer-link lanes as single DRAM-class streams
    for r, t in enumerate(tier.ranks):
        np.testing.assert_allclose(np.asarray(t.op_ns), _replay(t),
                                   rtol=0.01, err_msg=f"rank {r}")
        if tier.peer_ops[r]:
            np.testing.assert_allclose(
                np.asarray(tier.peer_op_ns[r]),
                replay_page_trace(tier.peer_ops[r], media=tier.peer_media,
                                  sr=False, ds=False,
                                  req_bytes=tier.cfg.req_bytes,
                                  dram_cache_bytes=tier.cfg.dram_cache_bytes,
                                  max_inflight=tier.cfg.max_inflight),
                rtol=0.01, err_msg=f"peer lane {r}")
