"""Serving hot-path tests: staging ring semantics, chunked prefill,
on-device sampling determinism, tiered-store LRU, and the
retire -> QoS-gated flush -> prefix-restore round trip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.core import deterministic_store as ds
from repro.core.qos import DevLoad
from repro.models import model as M
from repro.serving.engine import HostPageStore, Request, ServingEngine

PROMPT = [1, 2, 3, 7, 9, 4, 2, 8, 1, 5, 6]


def _make(arch="qwen3-1.7b", **kw):
    cfg = registry.smoke(arch)
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return ServingEngine(params, cfg, rc, **kw)


# ------------------------------------------------------------- StagingRing

def test_ring_wraparound_overwrites_oldest():
    item = jax.eval_shape(lambda: jnp.zeros((2,), jnp.float32))
    state = ds.ring_init(4, item)
    for i in range(6):                    # 6 writes into 4 slots: wraps
        state = ds.ring_write(state, jnp.int32(i),
                              jnp.full((2,), float(i)))
    hit, _ = ds.ring_lookup(state, jnp.int32(0))
    assert not bool(hit)                  # overwritten by write 4
    hit, _ = ds.ring_lookup(state, jnp.int32(1))
    assert not bool(hit)                  # overwritten by write 5
    for i in range(2, 6):
        hit, slot = ds.ring_lookup(state, jnp.int32(i))
        assert bool(hit)
        got = ds.read_through(state, jnp.int32(i), jnp.zeros((2,)))
        np.testing.assert_allclose(np.asarray(got), float(i))
    assert float(ds.ring_occupancy(state)) == 1.0


def test_ring_duplicate_key_latest_write_wins_after_wrap():
    item = jax.eval_shape(lambda: jnp.zeros((), jnp.float32))
    state = ds.ring_init(4, item)
    # writes: keys [1, 2, 3, 1, 9, 1] -> ring keys are [9, 1, 3, 1] with
    # head at 2; key 1 appears at slots 1 (newest) and 3 (older)
    for key, val in [(1, 10.0), (2, 20.0), (3, 30.0), (1, 40.0),
                     (9, 90.0), (1, 50.0)]:
        state = ds.ring_write(state, jnp.int32(key), jnp.float32(val))
    hit, slot = ds.ring_lookup(state, jnp.int32(1))
    assert bool(hit) and int(slot) == 1   # recency rank picks the newest
    got = ds.read_through(state, jnp.int32(1), jnp.float32(-1.0))
    assert float(got) == 50.0
    got = ds.read_through(state, jnp.int32(3), jnp.float32(-1.0))
    assert float(got) == 30.0
    got = ds.read_through(state, jnp.int32(2), jnp.float32(-1.0))
    assert float(got) == -1.0             # evicted -> backing value


# ----------------------------------------------------------- HostPageStore

def test_host_page_store_put_reports_admission():
    """put() returns whether the entry survived: budget pressure can evict
    an entry during its own insert (stage -> re-stage growing past the
    budget), and the caller must not index what already left."""
    kv = {"k": np.zeros((4, 64), np.float32)}   # 1 KiB per entry
    store = HostPageStore(budget_bytes=2 * kv["k"].nbytes)
    assert store.put(1, {"kv": kv, "pos": 5, "prompt": (1,)})
    big = {"k": np.zeros((16, 64), np.float32)}  # 4 KiB > budget
    assert not store.put(2, {"kv": big, "pos": 5, "prompt": (2,)})
    assert 2 not in store.pages and 1 not in store.pages  # LRU went first
    assert store.bytes == 0


def test_store_restage_evict_keeps_alias_index_bounded(mesh_ctx):
    """Regression for the stage -> re-stage -> evict ordering: when a
    flushed entry is evicted during its own put (or a re-staged rid
    replaces and then ages out), the prompt->rid alias map must not keep
    a dangling entry — on_evict fires before the sink used to re-add the
    alias, leaking one index entry per evicted prompt."""
    # budget below one smoke entry: every flush self-evicts on insert
    eng = _make(n_slots=1, max_seq=32, store_budget_bytes=1024)
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=[rid + 1, 2, 3],
                           max_new_tokens=2))
    eng.run(max_ticks=200)
    assert eng.store.evictions >= 3 and not eng.store.pages
    assert eng._prompt_index == {}              # the leak
    assert eng.store.bytes == 0

    # stage -> RE-stage (same rid+prompt retired twice) -> evict by a
    # later, larger working set: alias entries always point at live pages
    eng = _make(n_slots=1, max_seq=32, store_budget_bytes=60_000)
    for _ in range(2):                          # second pass re-stages rid 0
        eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2))
        eng.run(max_ticks=100)
    assert 0 in eng.store.pages
    for rid in range(1, 5):                     # push rid 0 out via LRU
        eng.submit(Request(rid=rid, prompt=[rid + 1, 2, 3],
                           max_new_tokens=2))
        eng.run(max_ticks=100)
    assert eng.store.evictions >= 1
    live = set(eng.store.pages)
    assert set(eng._prompt_index.values()) <= live
    assert eng.store.bytes == sum(
        eng.store._entry_bytes(e) for e in eng.store.pages.values())
    assert eng.store.bytes <= 60_000


def test_submit_probe_refreshes_store_recency(mesh_ctx):
    """Regression: the enqueue-time SR probe read ``store.pages`` without
    touching recency, so a hot prefix — one a queued request was about to
    restore — could be evicted behind entries nobody was waiting for,
    wasting the MemSpecRd and forcing a full re-prefill. A confirmed
    probe must refresh LRU order so the prefix survives until admission.
    """
    from repro.core.tier import CxlTier, TierConfig

    # a tier makes submit() issue the enqueue-time SR probe; budget sized
    # to exactly the working set so any insertion evicts the LRU entry
    eng = _make(n_slots=1, max_seq=32,
                cxl_tier=CxlTier(TierConfig(media="dram")))
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=[rid + 1, 2, 3],
                           max_new_tokens=2))
    eng.run(max_ticks=200)
    assert set(eng.store.pages) == {0, 1, 2}
    per_entry = eng.store.bytes // 3
    eng.store.budget_bytes = 3 * per_entry

    # rid 0 is the LRU entry; a queued resubmit probes (and now touches)
    # it at submit time...
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2))
    assert next(iter(eng.store.pages)) != 0    # probe refreshed recency
    # ...so a competing retirement evicts a genuinely cold entry instead
    eng.submit(Request(rid=7, prompt=[7, 7, 7], max_new_tokens=2))
    eng.run(max_ticks=200)
    assert 0 in eng.store.pages                # the hot prefix survived
    restored = [r for r in eng.finished if r.rid == 0 and r.restored]
    assert restored, "resubmit was not served via restore"


def test_host_page_store_lru_eviction_and_bytes():
    kv = {"k": np.zeros((4, 64), np.float32)}   # 1 KiB per entry
    store = HostPageStore(budget_bytes=3 * kv["k"].nbytes)
    for rid in range(3):
        store.put(rid, {"kv": kv, "pos": 5, "prompt": (rid,)})
    assert store.bytes == 3 * kv["k"].nbytes and not store.evictions
    store.get(0)                                # refresh rid 0's recency
    store.put(3, {"kv": kv, "pos": 5, "prompt": (3,)})
    assert store.evictions == 1
    assert 1 not in store.pages                 # LRU (not rid 0) evicted
    assert 0 in store.pages and 3 in store.pages
    assert store.bytes <= store.budget_bytes
    # re-put of an existing rid replaces, not duplicates
    store.put(3, {"kv": kv, "pos": 6, "prompt": (3,)})
    assert store.bytes == 3 * kv["k"].nbytes and store.evictions == 1


# ------------------------------------------------- chunked prefill parity

def test_prefill_step_cached_matches_sequential_decode(mesh_ctx):
    """The chunked cache-writing prefill must reproduce the per-token
    decode_step path: same KV cache, same final logits."""
    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(1), cfg)
    import repro.parallel.sharding as shlib
    pspecs = shlib.param_specs(jax.eval_shape(lambda: params))
    toks = [5, 17, 3, 250, 9, 11, 41]

    seq = M.cache_init(cfg, rc, 1, max_seq=32)
    logits_seq = None
    for t in toks:
        logits_seq, seq = M.decode_step(params, cfg, rc,
                                        jnp.full((1, 1), t, jnp.int32),
                                        seq, pspecs)

    chunk = M.cache_init(cfg, rc, 1, max_seq=32)
    logits_chunk, chunk = M.prefill_step_cached(
        params, cfg, rc, jnp.asarray([toks], jnp.int32), chunk, pspecs)

    np.testing.assert_allclose(
        np.asarray(logits_seq.astype(jnp.float32))[0, -1],
        np.asarray(logits_chunk.astype(jnp.float32))[0, -1],
        atol=2e-2, rtol=2e-2)
    assert int(chunk["pos"][0]) == len(toks) == int(seq["pos"][0])
    np.testing.assert_allclose(
        np.asarray(chunk["kv"]["k"].astype(jnp.float32)),
        np.asarray(seq["kv"]["k"].astype(jnp.float32)),
        atol=2e-2, rtol=2e-2)


def test_engine_chunked_prefill_matches_legacy_greedy(mesh_ctx):
    """Multi-chunk prefill + fused on-device sampling must emit the same
    greedy tokens as the pre-rewrite per-token host path."""
    legacy = _make(n_slots=2, max_seq=32, legacy_host_path=True)
    new = _make(n_slots=2, max_seq=32, prefill_chunk=4)
    for eng in (legacy, new):
        for rid in range(3):
            eng.submit(Request(rid=rid, prompt=PROMPT[: 11 - rid],
                               max_new_tokens=5))
        eng.run(max_ticks=200)
    legacy_out = {r.rid: r.generated for r in legacy.finished}
    new_out = {r.rid: r.generated for r in new.finished}
    assert legacy_out == new_out
    # the whole point: a handful of chunk dispatches, not one per token
    assert new.stats["prefill_dispatches"] < new.stats["prefill_tokens"]


def test_slot_reuse_prefill_isolated(mesh_ctx):
    """A request admitted into a reused slot must decode exactly as if it
    had the engine to itself (regression: the first prefill chunk used the
    slot's stale device pos left by the previous occupant)."""
    solo = _make(n_slots=1, max_seq=32)
    solo.submit(Request(rid=1, prompt=[9, 8, 7, 6, 5], max_new_tokens=4))
    ref = solo.run(max_ticks=60)[0].generated

    eng = _make(n_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5, 6, 7],
                       max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=[9, 8, 7, 6, 5], max_new_tokens=4))
    outs = {r.rid: r.generated for r in eng.run(max_ticks=100)}
    assert outs[1] == ref


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "musicgen-large",
                                  "zamba2-2.7b"])
def test_engine_families_complete(mesh_ctx, arch):
    """Chunked (moe/audio) and scan-fallback (hybrid) families serve
    requests through the device-resident path."""
    eng = _make(arch, n_slots=2, max_seq=16, prefill_chunk=3)
    for rid in range(2):
        eng.submit(Request(rid=rid, prompt=[1 + rid, 2, 3, 4],
                           max_new_tokens=3))
    done = eng.run(max_ticks=60)
    assert len(done) == 2
    assert all(len(r.generated) == 3 for r in done)


# --------------------------------------------------- int8 KV token quality

def test_greedy_int8_kv_matches_baseline_token_for_token(mesh_ctx):
    """The serving-level accuracy gate: greedy decode with the int8 KV
    cache must reproduce the full-precision engine's tokens exactly on
    the smoke configs — quantization noise (0.5 ulp of a 127-step page
    grid) stays far below the greedy argmax margins."""
    outs = {}
    for mode in ("none", "int8"):
        eng = _make(n_slots=2, max_seq=32, prefill_chunk=4, kv_quant=mode)
        for rid in range(3):
            eng.submit(Request(rid=rid, prompt=PROMPT[: 11 - rid],
                               max_new_tokens=6))
        eng.run(max_ticks=200)
        outs[mode] = {r.rid: r.generated for r in eng.finished}
        assert len(outs[mode]) == 3
    assert outs["int8"] == outs["none"]


def test_temperature_int8_bounded_divergence(mesh_ctx):
    """Sampled decode under int8 KV: one borderline sample flipped by
    quantization noise legitimately forks the sequence from that point
    on, so exact identity is NOT the contract — the documented bound is
    the positional match fraction (serve_bench's kv_quant axis pins the
    same bound end-to-end; see docs/ARCHITECTURE.md "KV page format").
    Determinism still holds: same engine seed + mode => same tokens."""
    outs = {}
    for mode in ("none", "int8"):
        runs = []
        for _ in range(2):
            eng = _make(n_slots=2, max_seq=32, temperature=0.8, seed=7,
                        kv_quant=mode)
            for rid in range(3):
                eng.submit(Request(rid=rid, prompt=[5, 6, 7],
                                   max_new_tokens=6))
            eng.run(max_ticks=100)
            runs.append({r.rid: r.generated for r in eng.finished})
        assert runs[0] == runs[1]          # seeded sampling deterministic
        outs[mode] = runs[0]
    total = matched = 0
    for rid, a in outs["none"].items():
        b = outs["int8"][rid]
        total += max(len(a), len(b))
        matched += sum(x == y for x, y in zip(a, b))
    assert matched / total >= 0.5          # bounded, not exact (see above)


# ------------------------------------------------- sampling determinism

def test_temperature_sampling_deterministic_across_host_rng(mesh_ctx):
    """Same engine seed => same tokens, independent of host numpy RNG
    state/version (sampling runs on device via the jax PRNG)."""
    outs = []
    for np_seed in (123, 987654):
        np.random.seed(np_seed)           # must not influence anything
        eng = _make(n_slots=2, max_seq=32, temperature=0.8, seed=7)
        for rid in range(3):
            eng.submit(Request(rid=rid, prompt=[5, 6, 7],
                               max_new_tokens=6))
        eng.run(max_ticks=100)
        outs.append({r.rid: r.generated for r in eng.finished})
    assert outs[0] == outs[1]


# ------------------- retire -> QoS-gated flush -> prefix-restore round trip

def test_retire_flush_restore_round_trip(mesh_ctx):
    eng = _make(n_slots=2, max_seq=32, prefill_chunk=4)
    # congest the QoS controller: flushes stay suppressed every tick
    eng.qos.classify = lambda **kw: DevLoad.MODERATE
    eng.submit(Request(rid=42, prompt=PROMPT, max_new_tokens=4))
    done = eng.run(max_ticks=100)
    assert done[0].done
    original = done[0].generated
    assert not eng.store.pages              # flush was QoS-gated
    assert len(eng.flusher.pending) == 1    # pages parked in staging
    assert eng.flusher.suppressed > 0

    # a resubmit while the pages sit in staging is served from the
    # staging index (latest-write-wins read path), no prefill dispatches,
    # and reproduces the original greedy continuation
    pf = eng.stats["prefill_dispatches"]
    eng.submit(Request(rid=42, prompt=PROMPT, max_new_tokens=3))
    done = eng.run(max_ticks=100)
    assert done[-1].restored
    assert done[-1].generated == original[:3]
    assert eng.stats["prefill_dispatches"] == pf
    assert eng.stats["prefix_hits"] == 1

    # load clears -> the background flush drains staging into the store
    del eng.qos.classify                    # restore the real classifier
    eng.qos.update(DevLoad.LIGHT)
    assert eng.flusher.maybe_flush() >= 1
    assert 42 in eng.store.pages

    # ...and a later resubmit restores from the cold tier as well
    pf = eng.stats["prefill_dispatches"]
    eng.submit(Request(rid=42, prompt=PROMPT, max_new_tokens=2))
    done = eng.run(max_ticks=100)
    assert done[-1].restored
    assert eng.stats["prefill_dispatches"] == pf


def test_prefix_restore_requires_matching_prompt(mesh_ctx):
    """A rid collision with a different prompt must NOT restore pages."""
    eng = _make(n_slots=1, max_seq=32)
    eng.submit(Request(rid=7, prompt=[1, 2, 3], max_new_tokens=3))
    eng.run(max_ticks=60)
    pf = eng.stats["prefill_dispatches"]
    eng.submit(Request(rid=7, prompt=[9, 9, 9], max_new_tokens=3))
    done = eng.run(max_ticks=60)
    assert not done[-1].restored
    assert eng.stats["prefill_dispatches"] > pf


def test_engine_store_stats_surface(mesh_ctx):
    budget = 60_000       # smoke qwen3 slot pages are ~16-32 KiB: the
    eng = _make(n_slots=1, max_seq=32, store_budget_bytes=budget)
    # budget holds 1-3 entries, so 4 retirements must evict
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[rid + 1, 2, 3],
                           max_new_tokens=2))
    eng.run(max_ticks=200)
    assert eng.stats["store_bytes"] == eng.store.bytes
    assert eng.stats["store_evictions"] == eng.store.evictions
    assert eng.store.bytes <= budget
    assert eng.store.evictions >= 1
