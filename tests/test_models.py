"""Per-architecture smoke tests + decode/forward parity.

Each assigned architecture instantiates a REDUCED config of the same
family and runs a real forward/train/decode step on CPU, asserting output
shapes and finite values (assignment requirement). The parity test checks
that stepwise decode reproduces the teacher-forced forward logits — the
strongest end-to-end correctness property of the paged decode path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.models import model as M
from repro.parallel import sharding as shlib

ARCHS = sorted(registry.ARCHS)


def _setup(arch, shape="train_4k"):
    cfg = registry.smoke(arch)
    rc = RunConfig(model=cfg, shape=SHAPES[shape], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    specs = shlib.param_specs(jax.eval_shape(lambda: params))
    return cfg, rc, params, specs


def _batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    shape = (B, cfg.n_codebooks, S) if cfg.family == "audio" else (B, S)
    toks = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(mesh_ctx, arch):
    cfg, rc, params, specs = _setup(arch)
    loss = M.loss_fn(params, cfg, rc, _batch(cfg), specs)
    assert jnp.isfinite(loss), arch
    # random-init loss is near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5, arch


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-moe-1b-a400m",
                                  "zamba2-2.7b", "xlstm-125m"])
def test_train_step_decreases_loss(mesh_ctx, arch):
    from repro.launch import steps as steps_lib
    from repro.optim import adamw
    cfg, rc, params, specs = _setup(arch)
    opt_cfg = adamw.AdamWConfig(learning_rate=1e-2, warmup_steps=0)
    step = jax.jit(steps_lib.build_train_step(cfg, rc, opt_cfg))
    state = steps_lib.TrainState(params, adamw.init(params, opt_cfg), None)
    batch = _batch(cfg)
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(mesh_ctx, arch):
    cfg, rc, params, specs = _setup(arch, "decode_32k")
    B = 2
    cache = M.cache_init(cfg, rc, B, max_seq=64)
    toks = (jnp.zeros((B, cfg.n_codebooks, 1), jnp.int32)
            if cfg.family == "audio" else jnp.zeros((B, 1), jnp.int32))
    logits, cache2 = M.decode_step(params, cfg, rc, toks, cache, specs)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), arch
    assert (cache2["pos"] == 1).all()


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma-2b",
                                  "granite-moe-1b-a400m", "zamba2-2.7b",
                                  "xlstm-125m", "musicgen-large"])
def test_decode_matches_forward(mesh_ctx, arch):
    """Stepwise decode logits == teacher-forced forward logits."""
    cfg, rc, params, specs = _setup(arch, "decode_32k")
    B, S = 1, 8
    batch = _batch(cfg, B=B, S=S, key=7)
    toks = batch["tokens"]

    # teacher-forced forward logits at the last position
    fwd = M.prefill_step(params, cfg, rc, {"tokens": toks}, specs)

    # stepwise decode through the same tokens
    cache = M.cache_init(cfg, rc, B, max_seq=16)
    logits = None
    for t in range(S):
        tok = toks[..., t:t + 1]
        logits, cache = M.decode_step(params, cfg, rc, tok, cache, specs)
    np.testing.assert_allclose(
        np.asarray(logits.astype(jnp.float32)).reshape(-1),
        np.asarray(fwd.astype(jnp.float32)).reshape(-1),
        atol=6e-2, rtol=6e-2)


def test_per_slot_positions_isolated(mesh_ctx):
    """A slot's logits must not depend on other slots' positions — the
    continuous-batching isolation property."""
    cfg, rc, params, specs = _setup("qwen3-1.7b", "decode_32k")
    toks = jnp.array([[5], [9]], jnp.int32)
    cache = M.cache_init(cfg, rc, 2, max_seq=16)
    cache["pos"] = jnp.array([3, 0], jnp.int32)
    l_mixed, _ = M.decode_step(params, cfg, rc, toks, cache, specs)

    cache1 = M.cache_init(cfg, rc, 2, max_seq=16)
    cache1["pos"] = jnp.array([3, 7], jnp.int32)   # other slot elsewhere
    l_mixed2, _ = M.decode_step(params, cfg, rc, toks, cache1, specs)
    np.testing.assert_allclose(
        np.asarray(l_mixed[0].astype(jnp.float32)),
        np.asarray(l_mixed2[0].astype(jnp.float32)), atol=1e-5)


def test_param_count_sane():
    """Full-size analytic parameter counts are in the advertised range."""
    expect = {"qwen3-1.7b": (1.4e9, 2.4e9),
              "gemma-2b": (2.0e9, 3.2e9),
              "glm4-9b": (8e9, 10.5e9),
              "starcoder2-15b": (13e9, 17e9),
              "qwen3-moe-235b-a22b": (2.1e11, 2.6e11),
              "xlstm-125m": (0.8e8, 2.2e8)}
    for arch, (lo, hi) in expect.items():
        n = registry.get(arch).n_params()
        assert lo < n < hi, f"{arch}: {n:.2e} not in ({lo:.1e},{hi:.1e})"
    # MoE active params well below total
    moe = registry.get("qwen3-moe-235b-a22b")
    assert moe.n_active_params() < 0.15 * moe.n_params()


def test_pallas_attention_path_parity(mesh_ctx):
    """use_pallas=True (kernel path, interpret on CPU) matches the jnp
    chunked-attention path end to end through the model loss."""
    cfg = registry.smoke("qwen3-1.7b")
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    specs = shlib.param_specs(jax.eval_shape(lambda: params))
    batch = _batch(cfg, B=2, S=64)
    losses = {}
    for flag in (False, True):
        rc = dataclasses.replace(
            RunConfig(model=cfg, shape=SHAPES["train_4k"],
                      mesh=MeshConfig()), use_pallas=flag)
        losses[flag] = float(M.loss_fn(params, cfg, rc, batch, specs))
    np.testing.assert_allclose(losses[False], losses[True],
                               atol=2e-3, rtol=2e-3)
