"""Learned-placement tests: the GMM policy, tier heat aging, and
cross-rank re-homing.

Covers the ``repro.sim.policy.LearnedPlacement`` classifier in
isolation (determinism, cold-start fallback, hot/cold separation on
bimodal reuse), its integration as ``placement="learned"`` in
``CxlTier`` (promotion + strict stall win over the ``hotness`` counter
on churn traffic, with exact replay), the heat-aging knob (a cooled
fast-port resident must eventually demote, under both policies), and
the ``ShardedTier`` learned homing paths (re-home to the dominant
requester rank, multi-source restores, fault consistency).
"""
import random

import numpy as np
import pytest

from repro.core.sharded_tier import ShardedTier
from repro.core.tier import CxlTier, TierConfig
from repro.sim.engine import replay_page_trace
from repro.sim.policy import LearnedPlacement

ENTRY = 32 << 10


# --------------------------------------------------------------- policy

def _feed_bimodal(pol, reps=60):
    """Two key populations: hot keys restore every 1us, cold every 1ms."""
    t = 0.0
    for i in range(reps):
        t += 1_000.0
        pol.observe("hot-a", t, ENTRY)
        pol.observe("hot-b", t + 250.0, ENTRY)
        if i % 20 == 0:
            pol.observe(f"cold-{i}", t, ENTRY)
            pol.observe(f"cold-{i}", t + 1_000_000.0, ENTRY)
    return t


def test_policy_fits_and_separates_bimodal_reuse():
    pol = LearnedPlacement()
    t = _feed_bimodal(pol)
    assert pol.fitted
    assert pol.is_hot("hot-a", t)
    assert not pol.is_hot("cold-40", t + 1_000_000.0)
    assert pol.score("never-seen", t) == 0.0


def test_policy_is_deterministic():
    scores = []
    for _ in range(2):
        pol = LearnedPlacement()
        t = _feed_bimodal(pol)
        scores.append([pol.score(k, t) for k in ("hot-a", "hot-b",
                                                 "cold-0", "cold-20")])
    assert scores[0] == scores[1]


def test_policy_cold_start_mirrors_counter_heuristic():
    pol = LearnedPlacement(fallback_after=2)
    pol.observe("k", 100.0, ENTRY)
    assert not pol.is_hot("k", 200.0)       # one sighting: count 1 < 2
    pol.observe("k", 300.0, ENTRY)
    assert not pol.fitted
    assert pol.is_hot("k", 400.0)           # counter fallback fires at 2


def test_policy_scores_decay_with_simulated_time():
    pol = LearnedPlacement()
    t = _feed_bimodal(pol)
    fresh = pol.score("hot-a", t)
    stale = pol.score("hot-a", t + 100_000_000.0)
    assert stale < fresh


def test_policy_forget_drops_state():
    pol = LearnedPlacement()
    t = _feed_bimodal(pol)
    pol.forget("hot-a")
    assert pol.score("hot-a", t) == 0.0


def test_policy_validates_window():
    with pytest.raises(ValueError, match="window"):
        LearnedPlacement(window=4, min_fit=16)


# ------------------------------------------------------ learned CxlTier

def _churn_trace(seed, n_keys=24, steps=600, phases=3, alpha=1.4):
    rng = random.Random(seed)
    trace, w = [], [1.0 / (r + 1) ** alpha for r in range(n_keys)]
    for ph in range(phases):
        shift = ph * (n_keys // phases)
        ids = [(i + shift) % n_keys for i in range(n_keys)]
        for _ in range(steps // phases):
            k = ids[rng.choices(range(n_keys), weights=w)[0]]
            trace.append(("read", f"k{k}"))
            if rng.random() < 0.06:
                trace.append(("write", f"k{k}"))
    return trace


def _run_churn(placement, trace, **cfg_kw):
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast", "ssd-slow"),
                              placement=placement, **cfg_kw))
    for k in sorted({k for _, k in trace}):
        tier.write_entry(k, ENTRY)
    stall = 0.0
    for op, k in trace:
        if op == "read":
            stall += tier.read_entry(k, ENTRY)
        else:
            tier.write_entry(k, ENTRY)
        tier.advance(2000.0)
    return tier, stall


def test_learned_beats_hotness_on_churn_with_exact_replay():
    trace = _churn_trace(11)
    hot_tier, hot_stall = _run_churn("hotness", trace)
    lrn_tier, lrn_stall = _run_churn("learned", trace)
    assert lrn_stall < hot_stall
    assert lrn_tier.counters["promotions"] >= 1
    for tier in (hot_tier, lrn_tier):
        oracle = replay_page_trace(
            tier.ops, media=tier.cfg.media_name,
            topology=tier.cfg.port_medias, sr=tier.cfg.sr_enabled,
            ds=tier.cfg.ds_enabled, req_bytes=tier.cfg.req_bytes,
            dram_cache_bytes=tier.cfg.dram_cache_bytes,
            max_inflight=tier.cfg.max_inflight)
        np.testing.assert_allclose(np.asarray(tier.op_ns), oracle,
                                   rtol=0.01, atol=1e-6)


@pytest.mark.parametrize("placement", ("hotness", "learned"))
def test_cooled_entry_eventually_demotes(placement):
    """Heat aging: a once-hot entry must not pin the DRAM port forever —
    once its decayed heat falls below one restore, the next placement
    sweep demotes it even without budget pressure."""
    tier = CxlTier(TierConfig(topology=("dram", "ssd-slow"),
                              placement=placement,
                              heat_half_life_ns=1_000_000.0))
    tier.write_entry("hot", ENTRY)
    tier.write_entry("other", ENTRY)
    for _ in range(4):                       # heat "hot" past promotion
        tier.read_entry("hot", ENTRY)
        tier.advance(2000.0)
    assert "hot" in tier._fast_resident
    tier.advance(50_000_000.0)               # 50 half-lives of silence
    tier.read_entry("other", ENTRY)          # any restore runs the sweep
    assert "hot" not in tier._fast_resident
    assert tier.counters["demotions"] >= 1


@pytest.mark.parametrize("placement", ("hotness", "learned"))
def test_no_aging_by_default(placement):
    """half_life=0 keeps the pre-aging behaviour: heat never decays and
    a quiet fast-port resident stays put."""
    tier = CxlTier(TierConfig(topology=("dram", "ssd-slow"),
                              placement=placement))
    tier.write_entry("hot", ENTRY)
    tier.write_entry("other", ENTRY)
    for _ in range(4):
        tier.read_entry("hot", ENTRY)
        tier.advance(2000.0)
    assert "hot" in tier._fast_resident
    tier.advance(50_000_000.0)
    tier.read_entry("other", ENTRY)
    assert "hot" in tier._fast_resident


def test_serve_config_accepts_learned_placement():
    from repro.serving.config import ServeConfig
    sc = ServeConfig(tier_topology=("dram", "ssd-fast"),
                     tier_placement="learned",
                     tier_heat_half_life_ns=1e6)
    tier = sc.make_tier()
    assert tier.cfg.placement == "learned"
    assert tier.cfg.heat_half_life_ns == 1e6
    with pytest.raises(ValueError, match="tier_heat_half_life_ns"):
        ServeConfig(tier_heat_half_life_ns=-1.0)


# -------------------------------------------------- ShardedTier homing

def _shared_tier(placement):
    return ShardedTier(2, TierConfig(topology=("dram", "ssd-slow"),
                                     placement=placement))


def _train_hot(tier, key, req_rank, rounds=40):
    """Drive enough tagged restores that the policy classifies ``key``
    hot (interleaving a cold key so the EM split is non-degenerate)."""
    tier.write_entry(key, ENTRY)
    tier.write_entry("cold", ENTRY)
    for i in range(rounds):
        tier.read_entry(key, ENTRY, req_rank=req_rank)
        if i % 10 == 0:
            tier.read_entry("cold", ENTRY)
            tier.advance(500_000.0)
        tier.advance(2000.0)


def test_sharded_rehomes_to_dominant_requester():
    tier = _shared_tier("learned")
    # pick a key hashed onto rank 0 so re-homing to rank 1 is observable
    key = next(f"k{i}" for i in range(64) if tier.home_rank(f"k{i}") == 0)
    _train_hot(tier, key, req_rank=1)
    assert tier._policy.is_hot(key, tier.topo.now)
    tier.write_entry(key, ENTRY)             # flush migrates the entry
    assert tier._owner[key] == 1
    assert tier.shard_counters["rehomes"] >= 1
    assert tier.ranks[1].has_entry(key)
    assert not tier.ranks[0].has_entry(key)  # stale copy freed


def test_sharded_multi_source_reads_drop_peer_bytes():
    tier = _shared_tier("learned")
    key = "prefix"
    _train_hot(tier, key, req_rank=1)
    assert tier.shard_counters["multi_source_reads"] >= 1
    # once mirrored on both of 2 ranks, a hot restore ships zero link
    # bytes: every requester reads its shard from a local copy
    before = tier.shard_counters["peer_bytes"]
    stall = tier.read_entry(key, ENTRY, req_rank=0)
    assert stall > 0.0
    assert tier.shard_counters["peer_bytes"] == before
    assert "multi_source_reads" in tier.snapshot()
    assert "rehomes" in tier.snapshot()


def test_sharded_hash_home_ignores_req_rank():
    """The hash-home baseline must be bit-identical with and without
    request tags — the placement bench replays one trace against both."""
    stalls = []
    for tag in (None, 1):
        tier = _shared_tier("hashed")
        tier.write_entry("k", ENTRY)
        stalls.append([tier.read_entry("k", ENTRY, req_rank=tag)
                       for _ in range(5)])
    assert stalls[0] == stalls[1]


def test_sharded_req_rank_validated():
    tier = _shared_tier("learned")
    tier.write_entry("k", ENTRY)
    with pytest.raises(ValueError, match="req_rank"):
        tier.read_entry("k", ENTRY, req_rank=7)


def test_sharded_learned_survives_holder_loss():
    """Dead ranks drop out of the multi-source holder set: after rank
    1's ports hot-remove, reads of a formerly-mirrored hot entry still
    succeed from rank 0 alone."""
    from repro.sim.engine import FaultSchedule, hot_remove

    cfg = TierConfig(topology=("dram", "ssd-slow"), placement="learned")
    faults = FaultSchedule((hot_remove(10e9, 0), hot_remove(10e9, 1)))
    tier = ShardedTier(2, cfg, faults=faults, fault_rank=1)
    key = "prefix"
    _train_hot(tier, key, req_rank=0)
    assert tier.shard_counters["multi_source_reads"] >= 1
    tier.advance(11e9)                       # fires both hot-removes
    tier.poll_faults()
    ns = tier.read_entry(key, ENTRY, req_rank=0)
    assert not tier.last_entry_failed
    assert ns > 0.0


def test_sharded_learned_replay_parity():
    tier = _shared_tier("learned")
    rng = random.Random(3)
    keys = [f"p{i}" for i in range(8)]
    for k in keys:
        tier.write_entry(k, ENTRY)
    for _ in range(200):
        k = rng.choice(keys)
        tier.read_entry(k, ENTRY, req_rank=rng.randrange(2))
        if rng.random() < 0.1:
            tier.write_entry(k, ENTRY)
        tier.advance(2000.0)
    for t in tier.ranks:
        oracle = replay_page_trace(
            t.ops, media=t.cfg.media_name, topology=t.cfg.port_medias,
            sr=t.cfg.sr_enabled, ds=t.cfg.ds_enabled,
            req_bytes=t.cfg.req_bytes,
            dram_cache_bytes=t.cfg.dram_cache_bytes,
            max_inflight=t.cfg.max_inflight)
        np.testing.assert_allclose(np.asarray(t.op_ns), oracle,
                                   rtol=0.01, atol=1e-6)
    for r in range(tier.n_ranks):
        oracle = replay_page_trace(
            tier.peer_ops[r], media=tier.peer_media, sr=False, ds=False,
            req_bytes=tier.cfg.req_bytes,
            dram_cache_bytes=tier.cfg.dram_cache_bytes,
            max_inflight=tier.cfg.max_inflight)
        np.testing.assert_allclose(np.asarray(tier.peer_op_ns[r]), oracle,
                                   rtol=0.01, atol=1e-6)


# ------------------------------------------------------- sweep section

def test_sweep_page_trace_bench_gates():
    from repro.sim import sweep as sw
    pt = sw.page_trace_bench(n_ops=800)
    assert pt["pass"]
    assert any(s["async"] for s in pt["scenarios"].values())
    for s in pt["scenarios"].values():
        assert s["max_rel_err"] <= 0.01
