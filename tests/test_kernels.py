"""Per-kernel allclose vs the pure-jnp oracles, sweeping shapes/dtypes.

All kernels run under interpret=True on CPU (the kernel body is executed
in Python) — the same code path that compiles to Mosaic on the TPU target.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.ops import decode
from repro.kernels.decode_attention.ref import paged_flash_decode_ref
from repro.kernels.mamba2_scan.ops import ssd
from repro.kernels.mamba2_scan.ref import ssd_scan_ref
from repro.kernels.hdm_stream.ops import stream_matmul
from repro.kernels.hdm_stream.ref import paged_matmul_ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D,qb,kb,causal", [
    (1, 64, 4, 4, 16, 32, 32, True),      # MHA
    (2, 128, 8, 2, 32, 64, 32, True),     # GQA, uneven blocks
    (1, 96, 4, 1, 16, 32, 32, False),     # MQA, full attention
    (2, 64, 8, 4, 64, 64, 64, True),      # single q block
])
def test_flash_attention(dtype, B, S, H, Hkv, D, qb, kb, causal):
    q = jax.random.normal(KEY, (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, D), dtype)
    out = attention(q, k, v, causal=causal, q_block=qb, kv_block=kb)
    g = H // Hkv
    qr = jnp.moveaxis(q.reshape(B, S, Hkv, g, D), 1, 3)
    ref = flash_attention_ref(qr, jnp.moveaxis(k, 1, 2),
                              jnp.moveaxis(v, 1, 2), causal=causal)
    ref = jnp.moveaxis(ref, 3, 1).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,D,P,page,kv_len", [
    (1, 4, 4, 16, 2, 8, 5),
    (2, 8, 2, 32, 4, 16, 33),
    (2, 4, 1, 64, 3, 8, 24),              # full cache
])
def test_paged_flash_decode(dtype, B, H, Hkv, D, P, page, kv_len):
    q = jax.random.normal(KEY, (B, 1, H, D), dtype)
    kp = jax.random.normal(jax.random.fold_in(KEY, 1),
                           (B, P, page, Hkv, D), dtype)
    vp = jax.random.normal(jax.random.fold_in(KEY, 2),
                           (B, P, page, Hkv, D), dtype)
    out = decode(q, kp, vp, jnp.int32(kv_len))
    g = H // Hkv
    ref = paged_flash_decode_ref(
        q.reshape(B, Hkv, g, D), jnp.moveaxis(kp, 3, 1),
        jnp.moveaxis(vp, 3, 1), kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32).reshape(B, Hkv, g, D),
        np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 32, 2, 8, 16, 16),
    (2, 64, 3, 8, 16, 32),
    (1, 64, 1, 16, 8, 64),                # single chunk
])
def test_ssd_scan(B, S, H, P, N, chunk):
    xdt = jax.random.normal(KEY, (B, S, H, P))
    bm = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, N)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, N)) * 0.5
    la = -jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 3),
                                    (B, S, H))) * 0.1
    y = ssd(xdt, bm, cm, la, chunk=chunk)
    c = S // chunk
    lac = jnp.moveaxis(jnp.cumsum(la.reshape(B, c, chunk, H), axis=2), 3, 1)
    ref = ssd_scan_ref(jnp.moveaxis(xdt.reshape(B, c, chunk, H, P), 3, 1),
                       bm.reshape(B, c, chunk, N),
                       cm.reshape(B, c, chunk, N), lac)
    ref = jnp.moveaxis(ref, 1, 3).reshape(B, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N,page_k,n_pages,bm,bn", [
    (32, 64, 64, 16, 8, 32, 32),
    (64, 128, 96, 32, 4, 32, 48),
])
def test_hdm_stream_matmul(dtype, M, K, N, page_k, n_pages, bm, bn):
    x = jax.random.normal(KEY, (M, K), dtype)
    wp = jax.random.normal(jax.random.fold_in(KEY, 1),
                           (n_pages, page_k, N), dtype)
    rng = np.random.default_rng(0)
    pids = jnp.asarray(rng.permutation(n_pages)[:K // page_k], jnp.int32)
    y = stream_matmul(x, wp, pids, block_m=bm, block_n=bn)
    ref = paged_matmul_ref(x, wp, pids)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
