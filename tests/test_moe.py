"""MoE dispatch: EP paths vs the dense oracle + capacity properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.models import moe


def _cfg(**kw):
    base = registry.smoke("granite-moe-1b-a400m")
    return dataclasses.replace(base, **kw)


def _params(cfg, key=0):
    return moe.moe_init(jax.random.PRNGKey(key), cfg)


def test_ep_equals_oracle_on_single_rank(mesh_ctx):
    """On a 1x1 mesh the EP path must reproduce moe_apply exactly (same
    capacity discipline and slot-major priority)."""
    cfg = _cfg()
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_ref, aux_ref = moe.moe_apply(params, cfg, x)
    y_ep, aux_ep = moe.moe_apply_ep(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux_ep), float(aux_ref), atol=1e-6)


def test_ep_decode_equals_oracle(mesh_ctx):
    cfg = _cfg()
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 1, cfg.d_model),
                          jnp.float32)
    y_ref, _ = moe.moe_apply(params, cfg, x)
    y_ep = moe.moe_apply_ep_decode(params, cfg, x)
    # decode path has no drops; oracle may drop under capacity — compare
    # only when capacity admits everything (cf large here: t=4, k=2, e=8)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_drops_bounded(seed):
    """With capacity_factor >= 1, the kept fraction is at least 1/k (the
    top-1 slot of a balanced router) and never exceeds 1."""
    cfg = _cfg(capacity_factor=1.0)
    params = _params(cfg, key=seed % 7)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 32, cfg.d_model))
    y, aux = moe.moe_apply(params, cfg, x)
    assert jnp.isfinite(y).all()
    assert float(aux) >= 0.0


def test_router_aux_penalizes_imbalance():
    """The Switch aux loss is minimized by a uniform routing distribution."""
    cfg = _cfg(router_aux_coef=1.0)
    e = cfg.n_experts
    # balanced: me = ce = uniform -> aux = coef * e * sum(1/e * 1/e) = 1
    me = jnp.full((e,), 1.0 / e)
    aux_uniform = float(e * jnp.sum(me * me))
    # imbalanced: all mass on one expert -> aux = e
    one = jnp.zeros((e,)).at[0].set(1.0)
    aux_skewed = float(e * jnp.sum(one * one))
    assert aux_skewed > aux_uniform


def test_gate_applied_at_combine(mesh_ctx):
    """Doubling the router temperature changes gates but expert inputs are
    unscaled: outputs must be a gate-weighted combination, i.e. scaling
    all gates uniformly scales the output linearly."""
    cfg = _cfg(top_k=1, capacity_factor=8.0)   # no drops
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
    y, _ = moe.moe_apply(params, cfg, x)
    # top-1 gates normalize to 1.0, so output equals the selected expert's
    # raw output; check linearity: expert(2x) != 2*expert(x) for the glu,
    # but gate*out IS linear in gate. Verify by recomputing by hand:
    t = x.reshape(-1, cfg.d_model)
    logits = t.astype(jnp.float32) @ params["router"]
    top = jnp.argmax(logits, axis=-1)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", t, params["e_gate"])) \
        * jnp.einsum("td,edf->tef", t, params["e_up"])
    y_all = jnp.einsum("tef,efd->ted", h, params["e_down"])
    y_hand = y_all[jnp.arange(t.shape[0]), top]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(y_hand), atol=1e-5, rtol=1e-5)
