"""Property tests for the int8 KV page format (``repro.models.kv_quant``).

Pins the three contracts the quantized tier path rests on:

 * per-page roundtrip error is bounded by half a quantization step
   (0.5 * scale) for every drawn shape/magnitude, including pages of
   zeros and subnormals (the amax floor keeps scales normal fp32);
 * monotone scale growth makes dequantize -> requantize of an untouched
   page *bit*-stable — the property the tier flush -> restore -> decode
   round trip relies on;
 * the serving engine's flush -> restore -> decode path preserves the
   int8 payload byte-exactly and charges quantized (roughly halved)
   byte counts end-to-end.

Runs under real hypothesis when installed (CI) and under the seeded
fallback shim otherwise (``repro._compat.hypothesis_fallback``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.models import kv_quant as kvq
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine

# (..., n_pages, page, Hkv, D) page layouts, tiny so draws stay fast
PAGE_SHAPES = [
    (2, 8, 2, 16),
    (1, 3, 4, 16, 4),
    (2, 2, 2, 4, 2, 8),
]
# page magnitudes spanning tiny to huge (scale must track amax per page)
MAGNITUDES = [1e-12, 1e-3, 1.0, 1e4, 1e12]


def _key(seed, i=0):
    return jax.random.fold_in(jax.random.PRNGKey(seed), i)


def _draw(shape, seed, magnitude):
    return jax.random.normal(_key(seed), shape, jnp.float32) * magnitude


# ----------------------------------------------------- roundtrip bound

@settings(max_examples=25, deadline=None)
@given(shape=st.sampled_from(PAGE_SHAPES),
       magnitude=st.sampled_from(MAGNITUDES),
       seed=st.integers(0, 2 ** 16))
def test_roundtrip_error_bounded_per_page(shape, magnitude, seed):
    """|x - dequantize(quantize(x))| <= 0.5 * scale elementwise: codes are
    round-to-nearest on a symmetric grid whose step is the page's scale,
    and scale = amax/127 means no value is ever out of clip range."""
    x = _draw(shape, seed, magnitude)
    s = kvq.page_scales(x)
    q = kvq.quantize_pages(x, s)
    dq = kvq.dequantize_pages(q, s)
    err = np.abs(np.asarray(x, np.float64) - np.asarray(dq, np.float64))
    bound = 0.5 * np.asarray(s, np.float64)[..., :, None, :, None]
    assert (err <= bound * (1 + 1e-5)).all()
    assert np.asarray(q).dtype == np.int8
    assert np.abs(np.asarray(q)).max() <= kvq.QMAX


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_mixed_magnitude_pages_scale_independently(seed):
    """A huge page must not inflate a tiny page's quantization step: the
    error on each page is bounded by that page's own scale."""
    tiny = _draw((1, 8, 2, 16), seed, 1e-6)
    huge = _draw((1, 8, 2, 16), seed + 1, 1e6)
    x = jnp.concatenate([tiny, huge], axis=0)        # pages axis
    s = kvq.page_scales(x)
    dq = kvq.dequantize_pages(kvq.quantize_pages(x, s), s)
    err_tiny = np.abs(np.asarray(tiny) - np.asarray(dq[:1]))
    assert err_tiny.max() <= 0.5 * float(np.asarray(s)[0].max()) * (1 + 1e-5)
    assert float(np.asarray(s)[0].max()) < 1e-5      # not polluted by huge


# ----------------------------------------- zero / subnormal edge cases

def test_zero_page_scale_is_normal_and_codes_zero():
    x = jnp.zeros((2, 8, 2, 16), jnp.float32)
    s = kvq.page_scales(x)
    tiny_normal = np.finfo(np.float32).tiny          # smallest NORMAL f32
    assert (np.asarray(s) >= tiny_normal).all()      # never zero/subnormal
    q = kvq.quantize_pages(x, s)
    assert not np.asarray(q).any()
    assert not np.asarray(kvq.dequantize_pages(q, s)).any()


def test_subnormal_page_quantizes_to_zero_with_normal_scale():
    """A page of subnormals sits far below the amax floor: the scale
    stays a normal fp32 (no division blow-ups) and every code rounds
    to 0 — the reconstruction error is the (subnormal) input itself."""
    x = jnp.full((1, 8, 2, 16), 1e-40, jnp.float32)
    s = kvq.page_scales(x)
    assert (np.asarray(s) >= np.finfo(np.float32).tiny).all()
    assert np.isfinite(np.asarray(1.0 / s)).all()
    q = kvq.quantize_pages(x, s)
    assert not np.asarray(q).any()


def test_init_scale_is_positive_and_normal():
    assert kvq.INIT_SCALE > 0
    assert np.float32(kvq.INIT_SCALE) >= np.finfo(np.float32).tiny


# ------------------------------------------- monotone-scale bit stability

@settings(max_examples=25, deadline=None)
@given(shape=st.sampled_from(PAGE_SHAPES),
       magnitude=st.sampled_from(MAGNITUDES),
       seed=st.integers(0, 2 ** 16))
def test_requantize_untouched_page_bit_stable(shape, magnitude, seed):
    """dequantize -> requantize(prev_scale) of an unchanged page must
    reproduce the identical codes AND scales: this is what keeps tier
    flush -> restore -> decode round trips byte-exact."""
    x = _draw(shape, seed, magnitude)
    s = kvq.page_scales(x)
    q = kvq.quantize_pages(x, s)
    dq = kvq.dequantize_pages(q, s)
    q2, s2 = kvq.requantize_pages(dq, s)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_scale_growth_is_monotone(seed):
    """Scales only grow: shrinking page contents keeps the old scale
    (bit-stability dominates), growing contents raises it to the new
    amax/127 so nothing clips."""
    x = _draw((2, 8, 2, 16), seed, 1.0)
    s0 = kvq.page_scales(x)
    _, s_small = kvq.requantize_pages(x * 0.01, s0)
    np.testing.assert_array_equal(np.asarray(s_small), np.asarray(s0))
    q_big, s_big = kvq.requantize_pages(x * 100.0, s0)
    assert (np.asarray(s_big) >= np.asarray(s0)).all()
    assert np.abs(np.asarray(q_big)).max() <= kvq.QMAX   # no clip overflow


# --------------------------------------------------------- mode validation

def test_validate_mode_spellings():
    assert kvq.validate_mode("none") == "none"
    assert kvq.validate_mode("int8") == "int8"
    with pytest.raises(ValueError, match="unknown"):
        kvq.validate_mode("int4")
    with pytest.raises(ValueError, match="reserved"):
        kvq.validate_mode("fp8")


# ------------------------- engine flush -> restore -> decode byte-exactness

PROMPT = [1, 2, 3, 7, 9, 4, 2, 8, 1, 5, 6]


def _make(kv_quant, page_size=8):
    """Smoke engine with small KV pages so the cache spans several pages
    (page geometry: page=8, n_pages=4 at max_seq=32)."""
    cfg = registry.smoke("qwen3-1.7b")
    rc = dataclasses.replace(
        RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig()),
        kv_page_size=page_size)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return ServingEngine(params, cfg, rc, n_slots=1, max_seq=32,
                         prefill_chunk=4, kv_quant=kv_quant)


def test_tier_flush_restore_decode_byte_exact(mesh_ctx):
    """Serve -> retire -> flush -> resubmit -> restore -> decode with the
    int8 cache: the restored continuation reproduces the original greedy
    tokens, and the int8 codes + scales of every fully-prefix page come
    back byte-identical after further decode steps (monotone scales)."""
    eng = _make("int8")
    assert eng.cache["kv"]["k"].dtype == jnp.int8
    assert "k_scale" in eng.cache["kv"]
    eng.submit(Request(rid=42, prompt=PROMPT, max_new_tokens=4))
    done = eng.run(max_ticks=100)
    original = done[0].generated
    for _ in range(10):
        if 42 in eng.store.pages:
            break
        eng.flusher.maybe_flush()
    assert 42 in eng.store.pages
    entry = eng.store.pages[42]
    assert entry["kv"]["k"].dtype == np.int8
    assert entry["kv"]["k_scale"].dtype == np.float32

    pf = eng.stats["prefill_dispatches"]
    eng.submit(Request(rid=42, prompt=PROMPT, max_new_tokens=2))
    done = eng.run(max_ticks=100)
    assert done[-1].restored
    assert done[-1].generated == original[:2]
    assert eng.stats["prefill_dispatches"] == pf   # no re-prefill

    # stored entry covered pos=len(PROMPT)=11 -> page 0 (tokens 0..7) is
    # full and untouched by the 2 extra decode steps (tokens 11, 12 land
    # on page 1); its codes and scales must round-trip byte-exactly
    page = 8
    full = len(PROMPT) // page                     # fully-written pages
    assert full >= 1
    cache_k = np.asarray(eng.cache["kv"]["k"])[:, 0, :full]
    np.testing.assert_array_equal(cache_k, entry["kv"]["k"][:, :full])
    cache_ks = np.asarray(eng.cache["kv"]["k_scale"])[:, 0, :full]
    np.testing.assert_array_equal(cache_ks, entry["kv"]["k_scale"][:, :full])


def test_quantized_store_entry_bytes_roughly_halved(mesh_ctx):
    """The host store (and therefore every tier charge, which uses the
    same leaf nbytes) sees the quantized payload: entry bytes shrink by
    ~the dtype itemsize ratio, plus the small per-page scale overhead."""
    sizes = {}
    for mode in ("none", "int8"):
        eng = _make(mode)
        eng.submit(Request(rid=1, prompt=PROMPT, max_new_tokens=2))
        eng.run(max_ticks=100)
        for _ in range(10):
            if 1 in eng.store.pages:
                break
            eng.flusher.maybe_flush()
        sizes[mode] = eng.store._entry_bytes(eng.store.pages[1])
        if mode == "none":
            itemsize = np.asarray(eng.cache["kv"]["k"]).dtype.itemsize
    ratio = sizes["int8"] / sizes["none"]
    assert ratio < 1.0 / itemsize + 0.05
