"""Completion-based async tier I/O: op handles, caps, oracle replay.

Pins the non-blocking half of the page-timing API: ``issue()`` never
moves the caller's clock (except for in-flight-cap stalls, which are the
only latency charged), ``poll()`` flips exactly when simulated time
passes the completion timestamp, blocking ops queue behind outstanding
async work on the shared service cursor, and — the satellite property —
an async-issued page trace replayed through ``replay_page_trace`` (the
blocking-oracle machinery extended with async kinds) reproduces the
online accounting within 1% across random op interleavings, port counts
and media bins.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.tier import CxlTier, TierConfig
from repro.sim.engine import (MAX_INFLIGHT_OPS, PAGE_READ, PAGE_READ_ASYNC,
                              PAGE_WRITE_ASYNC, PageStream, Topology,
                              replay_page_trace)
from repro.sim import vector

ENTRY = 32 << 10


def _tier_replay(tier: CxlTier) -> np.ndarray:
    return replay_page_trace(
        tier.ops, media=tier.cfg.media_name,
        topology=tier.cfg.port_medias if tier.cfg.tagged else None,
        sr=tier.cfg.sr_enabled, ds=tier.cfg.ds_enabled,
        req_bytes=tier.cfg.req_bytes,
        dram_cache_bytes=tier.cfg.dram_cache_bytes,
        max_inflight=tier.cfg.max_inflight)


# ----------------------------------------------------- PageStream handles

def test_issue_does_not_advance_clock_poll_flips_on_completion():
    s = PageStream("znand")
    h = s.issue(PAGE_READ_ASYNC, 0, ENTRY)
    assert s.now == 0.0                      # caller clock untouched
    assert h.wait_ns == 0.0
    assert h.done_ns > 0.0 and h.in_flight_ns == h.done_ns
    assert not s.poll(h)
    s.advance(h.done_ns / 2)
    assert not s.poll(h)
    s.advance(h.done_ns)                     # clock passes the completion
    assert s.poll(h)
    assert s.inflight_depth() == 0


def test_issue_matches_blocking_read_when_stream_idle():
    """On an idle stream the async op's service span is exactly the
    blocking read's stall (same controller walk, same arithmetic)."""
    b = PageStream("znand")
    a = PageStream("znand")
    stall = b.read(0, ENTRY)
    h = a.issue(PAGE_READ_ASYNC, 0, ENTRY)
    assert h.done_ns - h.start_ns == pytest.approx(stall)


def test_inflight_cap_charges_issue_wait():
    s = PageStream("znand", max_inflight=2)
    h1 = s.issue(PAGE_READ_ASYNC, 0, ENTRY)
    h2 = s.issue(PAGE_READ_ASYNC, ENTRY, ENTRY)
    assert h1.wait_ns == h2.wait_ns == 0.0
    h3 = s.issue(PAGE_READ_ASYNC, 2 * ENTRY, ENTRY)   # cap hit: stalls
    assert h3.wait_ns > 0.0
    assert s.now == pytest.approx(h1.done_ns)  # waited for the oldest
    assert s.inflight_depth() == 2


def test_blocking_op_queues_behind_async_backlog():
    """Shared service cursor: a blocking read issued while a cold async
    fetch is in flight starts after it, and the stall bills the queueing
    — the two do not magically parallelize on one port."""
    solo = PageStream("znand", sr=False)
    solo_stall = solo.read(4 << 20, ENTRY)
    s = PageStream("znand", sr=False)
    h = s.issue(PAGE_READ_ASYNC, 0, ENTRY)    # cold fetch holds the cursor
    stall = s.read(4 << 20, ENTRY)            # disjoint span: no cache help
    assert s.now >= h.done_ns                 # read completed after it
    assert stall > solo_stall                 # queueing actually billed


def test_topology_issue_routes_and_overlaps():
    topo = Topology(["znand", "znand"])
    h0 = topo.issue(0, PAGE_READ_ASYNC, 0, ENTRY)
    h1 = topo.issue(1, PAGE_READ_ASYNC, 0, ENTRY)
    assert h0.port == 0 and h1.port == 1
    assert topo.inflight_depth() == 2
    # distinct ports: neither queued behind the other
    assert h0.start_ns == h1.start_ns == 0.0
    topo.advance(max(h0.done_ns, h1.done_ns))
    assert topo.poll(h0) and topo.poll(h1)
    assert topo.inflight_depth() == 0


def test_closed_form_accepts_async_kinds():
    """The vectorized closed form now covers async kinds on DRAM-class
    EPs (it rejected them before the issue-stall recurrence landed) —
    pin exact agreement with the online accounting on a mixed trace."""
    tier = CxlTier(TierConfig(media="dram", max_inflight=2))
    for i in range(6):
        tier.write_entry_async(i, ENTRY)      # cap 2: charges real waits
    tier.advance(50_000.0)
    for i in range(6):
        tier.read_entry_async(i, ENTRY)
    tier.read_entry(0, ENTRY)                 # blocking queues behind async
    got = vector.page_trace_closed_form(
        tier.ops, tier.cfg.media_name, ds=tier.cfg.ds_enabled,
        req_bytes=tier.cfg.req_bytes, max_inflight=tier.cfg.max_inflight)
    np.testing.assert_allclose(np.asarray(tier.op_ns), got,
                               rtol=1e-9, atol=1e-6)


def test_closed_form_async_respects_inflight_cap():
    """Pricing a cap-stalled async trace with a looser cap must diverge,
    exactly like replay_page_trace does (the cap is part of the timing
    contract, not a free parameter)."""
    tier = CxlTier(TierConfig(media="dram", max_inflight=1))
    tier.read_entry_async(0, ENTRY)
    tier.read_entry_async(1, ENTRY)
    assert any(ns > 0 for ns in tier.op_ns)
    strict = vector.page_trace_closed_form(tier.ops, "dram", max_inflight=1)
    np.testing.assert_allclose(np.asarray(tier.op_ns), strict, rtol=1e-9)
    loose = vector.page_trace_closed_form(tier.ops, "dram",
                                          max_inflight=MAX_INFLIGHT_OPS)
    assert not np.allclose(np.asarray(tier.op_ns), loose, rtol=0.01)


# --------------------------------------------------------- tier handles

def test_tier_async_entry_ops_retire_via_advance():
    tier = CxlTier(TierConfig(media="ssd-fast"))
    wh = tier.write_entry_async("a", ENTRY)
    rh = tier.read_entry_async("a", ENTRY)
    assert not tier.poll(rh)
    assert tier.inflight_ops() == 2
    for _ in range(200):
        if tier.poll(wh) and tier.poll(rh):
            break
        tier.advance(100_000.0)
    assert tier.poll(wh) and tier.poll(rh)
    assert tier.inflight_ops() == 0
    assert tier.counters["async_reads"] == 1
    assert tier.counters["async_writes"] == 1
    np.testing.assert_allclose(np.asarray(tier.op_ns), _tier_replay(tier),
                               rtol=0.01, atol=1e-6)


def test_tier_async_trace_replays_on_multi_port_topology():
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast"),
                              placement="striped"))
    handles = []
    for i in range(6):
        handles.append(tier.write_entry_async(i, ENTRY))
        tier.advance(50_000.0)
    for i in range(6):
        tier.speculative_read(i, ENTRY)
        handles.append(tier.read_entry_async(i, ENTRY))
        tier.advance(50_000.0)
    for _ in range(300):
        if all(tier.poll(h) for h in handles):
            break
        tier.advance(100_000.0)
    assert all(h.retired for h in handles)
    np.testing.assert_allclose(np.asarray(tier.op_ns), _tier_replay(tier),
                               rtol=0.01, atol=1e-6)


# ------------------------------------------- satellite: property replay

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3),
       st.integers(0, 2), st.booleans())
def test_random_async_interleaving_replays_within_1pct(seed, n_ports,
                                                       media_i, sr):
    """Any interleaving of sync/async entry ops, prefetches and advances,
    on any port count and media bin, must replay within 1% of the scalar
    oracle — per-op and in aggregate."""
    rng = np.random.default_rng(seed)
    bins = ("dram", "ssd-fast", "ssd-slow")
    medias = tuple(bins[(media_i + j) % 3] for j in range(n_ports))
    cfg = TierConfig(topology=medias, sr_enabled=sr) if n_ports > 1 \
        else TierConfig(media=medias[0], sr_enabled=sr)
    tier = CxlTier(cfg)
    keys = list(range(6))
    for _ in range(30):
        k = keys[int(rng.integers(len(keys)))]
        nbytes = int(rng.integers(1 << 10, 48 << 10))
        op = rng.random()
        if op < 0.25:
            tier.write_entry(k, nbytes)
        elif op < 0.45:
            tier.write_entry_async(k, nbytes)
        elif op < 0.60:
            tier.read_entry(k, nbytes)
        elif op < 0.80:
            tier.read_entry_async(k, nbytes)
        elif op < 0.90:
            tier.speculative_read(k, nbytes)
        else:
            tier.advance(float(rng.integers(10_000, 500_000)))
    oracle = _tier_replay(tier)
    got = np.asarray(tier.op_ns)
    np.testing.assert_allclose(got, oracle, rtol=0.01, atol=1e-6)
    assert got.sum() == pytest.approx(oracle.sum(), rel=0.01, abs=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 3),
       st.integers(0, 2), st.sampled_from((1, 2, MAX_INFLIGHT_OPS)))
def test_random_async_interleaving_closed_form_within_1pct(seed, n_ports,
                                                           media_i, cap):
    """The async-capable vectorized closed form must match the scalar
    oracle within 1% per-op and in aggregate on random sync/async/
    prefetch/advance interleavings across 1-3 ports x DRAM-class media
    bins x max_inflight values (same generator as the replay property
    above; DRAM-class bins because the closed form refuses media with
    internal tasks)."""
    rng = np.random.default_rng(seed)
    bins = ("dram", "dram@2", "dram@4")
    medias = tuple(bins[(media_i + j) % 3] for j in range(n_ports))
    cfg = TierConfig(topology=medias, max_inflight=cap) if n_ports > 1 \
        else TierConfig(media=medias[0], max_inflight=cap)
    tier = CxlTier(cfg)
    keys = list(range(6))
    for _ in range(30):
        k = keys[int(rng.integers(len(keys)))]
        nbytes = int(rng.integers(1 << 10, 48 << 10))
        op = rng.random()
        if op < 0.25:
            tier.write_entry(k, nbytes)
        elif op < 0.45:
            tier.write_entry_async(k, nbytes)
        elif op < 0.60:
            tier.read_entry(k, nbytes)
        elif op < 0.80:
            tier.read_entry_async(k, nbytes)
        elif op < 0.90:
            tier.speculative_read(k, nbytes)
        else:
            tier.advance(float(rng.integers(10_000, 500_000)))
    oracle = _tier_replay(tier)
    got = vector.page_trace_closed_form(
        tier.ops,
        tier.cfg.port_medias if tier.cfg.tagged else tier.cfg.media_name,
        ds=tier.cfg.ds_enabled, req_bytes=tier.cfg.req_bytes,
        max_inflight=tier.cfg.max_inflight)
    np.testing.assert_allclose(got, oracle, rtol=0.01, atol=1e-6)
    assert got.sum() == pytest.approx(oracle.sum(), rel=0.01, abs=1e-6)
    np.testing.assert_allclose(np.asarray(tier.op_ns), got,
                               rtol=0.01, atol=1e-6)


def test_replay_with_wrong_cap_diverges_detectably():
    """The cap is part of the timing contract: replaying a cap-stalled
    trace with a larger cap must not reproduce the charged waits (guards
    against the replay silently ignoring max_inflight)."""
    tier = CxlTier(TierConfig(media="ssd-slow", max_inflight=1))
    tier.read_entry_async(0, ENTRY)
    tier.read_entry_async(1, ENTRY)          # cap 1: charged a real wait
    assert any(ns > 0 for ns in tier.op_ns)
    loose = replay_page_trace(tier.ops, media=tier.cfg.media_name,
                              sr=True, ds=True,
                              req_bytes=tier.cfg.req_bytes,
                              dram_cache_bytes=tier.cfg.dram_cache_bytes,
                              max_inflight=MAX_INFLIGHT_OPS)
    assert not np.allclose(np.asarray(tier.op_ns), loose, rtol=0.01)
    strict = _tier_replay(tier)
    np.testing.assert_allclose(np.asarray(tier.op_ns), strict, rtol=0.01)
