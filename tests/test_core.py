"""The paper's mechanisms as JAX modules: SR, DS, QoS invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import deterministic_store as ds
from repro.core import speculative_read as sr
from repro.core.qos import (DevLoad, QoSController, SR_GRANULARITIES,
                            address_window, SR_OFFSET_UNIT)
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# speculative read
# ---------------------------------------------------------------------------


def _stacked_linear(key, n_layers, d):
    w = jax.random.normal(key, (n_layers, d, d)) * (0.5 / np.sqrt(d))
    return {"w": w}


@pytest.mark.parametrize("depth,granularity,mode", [
    (0, 1, "train"), (1, 1, "train"), (2, 1, "train"), (1, 2, "train"),
    (0, 1, "infer"), (1, 1, "infer"), (2, 1, "infer"), (2, 2, "infer"),
])
def test_stream_layers_matches_direct_loop(mesh_ctx, depth, granularity,
                                           mode):
    """SR pipelining must be a pure schedule change: same numerics as the
    direct layer loop at every depth/granularity."""
    n_layers, d = 5, 8
    params = _stacked_linear(jax.random.PRNGKey(0), n_layers, d)
    specs = {"w": P(None, None, None)}
    x0 = jax.random.normal(jax.random.PRNGKey(1), (3, d))

    def body(x, layer, extra):
        del extra
        return jnp.tanh(x @ layer["w"]), None

    out, _ = sr.stream_layers(body, x0, params, specs, n_layers=n_layers,
                              prefetch_depth=depth, granularity=granularity,
                              mode=mode, remat=False)
    ref = x0
    for i in range(n_layers):
        ref = jnp.tanh(ref @ params["w"][i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)


def test_stream_layers_grad_matches(mesh_ctx):
    """Remat'd SR training path: gradients equal the direct loop's."""
    n_layers, d = 4, 6
    params = _stacked_linear(jax.random.PRNGKey(0), n_layers, d)
    specs = {"w": P(None, None, None)}
    x0 = jax.random.normal(jax.random.PRNGKey(1), (2, d))

    def body(x, layer, extra):
        return jnp.tanh(x @ layer["w"]), None

    def loss_stream(p):
        out, _ = sr.stream_layers(body, x0, p, specs, n_layers=n_layers,
                                  prefetch_depth=1, mode="train", remat=True)
        return jnp.sum(out ** 2)

    def loss_direct(p):
        x = x0
        for i in range(n_layers):
            x = jnp.tanh(x @ p["w"][i])
        return jnp.sum(x ** 2)

    g1 = jax.grad(loss_stream)(params)
    g2 = jax.grad(loss_direct)(params)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# deterministic store: staging ring
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.integers(0, 7), st.floats(-10, 10)),
                min_size=1, max_size=40))
@settings(max_examples=30, deadline=None)
def test_ring_latest_write_wins(writes):
    """read_through returns the MOST RECENT staged value for a key, else
    the backing value — the paper's staging-index read path."""
    item = jnp.zeros((2,))
    state = ds.ring_init(8, {"x": item})
    last = {}
    for key, val in writes:
        state = ds.ring_write(state, jnp.int32(key),
                              {"x": jnp.full((2,), val)})
        last[key] = val
    n_slots = 8
    recent = {}
    for key, val in writes[-n_slots:]:
        recent[key] = val
    for key in range(8):
        backing = {"x": jnp.full((2,), -99.0)}
        got = ds.read_through(state, jnp.int32(key), backing)
        # a key overwritten within the ring window returns its latest value
        if key in recent and last[key] == recent[key]:
            np.testing.assert_allclose(np.asarray(got["x"]),
                                       recent[key], atol=1e-6)


@given(st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_ring_occupancy_bounded(n_writes):
    state = ds.ring_init(8, {"x": jnp.zeros(())})
    for i in range(n_writes):
        state = ds.ring_write(state, jnp.int32(i), {"x": jnp.float32(i)})
    occ = float(ds.ring_occupancy(state))
    assert 0.0 < occ <= 1.0
    assert occ == min(n_writes, 8) / 8


def test_flusher_respects_qos():
    qos = QoSController()
    sunk = []
    fl = ds.StagingFlusher(sink=lambda k, v: sunk.append(k), qos=qos)
    fl.stage(1, "a")
    qos.update(DevLoad.MODERATE)        # congestion: divert, no flush
    assert fl.maybe_flush() == 0 and not sunk
    qos.update(DevLoad.LIGHT)           # recovered: drain
    assert fl.maybe_flush() == 1 and sunk == [1]


def test_ds_grad_specs_toggle():
    specs = {"w": P("data", "model")}
    assert ds.ds_grad_specs(specs, True) == specs          # reduce-scatter
    gathered = ds.ds_grad_specs(specs, False)
    assert gathered["w"] == P(None, "model")               # all-reduce


# ---------------------------------------------------------------------------
# QoS / DevLoad state machine (paper's control table)
# ---------------------------------------------------------------------------


def test_qos_granularity_ladder():
    q = QoSController(granularity=512)
    q.update(DevLoad.LIGHT)
    assert q.granularity == 768 and q.sr_enabled and q.flush_enabled
    q.update(DevLoad.LIGHT)
    assert q.granularity == 1024
    q.update(DevLoad.LIGHT)
    assert q.granularity == 1024          # clamped at the top
    q.update(DevLoad.MODERATE)
    assert q.granularity == 768 and not q.flush_enabled
    q.update(DevLoad.SEVERE)
    assert q.sr_halted and q.granularity == SR_GRANULARITIES[0]
    q.update(DevLoad.LIGHT)               # paper: resume on light load
    assert q.sr_enabled and q.flush_enabled


@given(st.lists(st.sampled_from(list(DevLoad)), min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_qos_invariants(seq):
    q = QoSController()
    for dl in seq:
        q.update(dl)
        assert q.granularity in SR_GRANULARITIES
        assert 0 <= q.prefetch_depth <= q.max_prefetch_depth
        if dl == DevLoad.SEVERE:
            assert q.sr_halted and not q.flush_enabled
        if dl == DevLoad.LIGHT:
            assert not q.sr_halted and q.flush_enabled


# ---------------------------------------------------------------------------
# address window (paper Fig. 7)
# ---------------------------------------------------------------------------


@given(st.integers(0, 1 << 20), st.sampled_from(SR_GRANULARITIES),
       st.lists(st.integers(0, 1 << 20), max_size=32),
       st.lists(st.integers(0, 1 << 20), max_size=32))
@settings(max_examples=100, deadline=None)
def test_address_window_properties(addr, g, mem_q, sr_q):
    start, end = address_window(addr, g, mem_q, sr_q)
    assert start >= 0
    assert end > start
    assert start % SR_OFFSET_UNIT == 0
    assert end - start <= max(g, SR_OFFSET_UNIT)


def test_address_window_shifts():
    # past requests (memory queue) push the start forward; future SRs
    # (SR queue) pull the end back — the paper's queue-derived window
    a, g = 4096, 1024
    s0, e0 = address_window(a, g, [], [])
    s1, e1 = address_window(a, g, [0] * 8, [])
    s2, e2 = address_window(a, g, [], [0] * 8)
    assert s1 >= s0
    assert e2 <= e1
