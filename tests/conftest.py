import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro  # noqa: E402,F401  (installs the jax < 0.5 compat shims)

try:
    import hypothesis  # noqa: F401  (preferred when installed — CI does)
except ImportError:
    from repro._compat import hypothesis_fallback

    hypothesis_fallback.register()

import random  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def seed_all():
    """Reseed the global host RNGs before every test.

    Test order must never change outcomes: anything that (even
    accidentally) reads ``np.random`` or ``random`` global state gets the
    same stream regardless of which tests ran before it. Audit note:
    the suite's tests draw through explicit ``np.random.default_rng`` /
    ``jax.random.PRNGKey`` generators (test_loadgen / test_scheduler use
    seeded LoadConfig streams); the one deliberate global reseed —
    test_serving's determinism-across-host-RNG test — overrides this
    per-test baseline, which is exactly its point.
    """
    np.random.seed(0)
    random.seed(0)


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh()


@pytest.fixture()
def mesh_ctx(host_mesh):
    with jax.set_mesh(host_mesh):
        yield host_mesh
