import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro  # noqa: E402,F401  (installs the jax < 0.5 compat shims)

try:
    import hypothesis  # noqa: F401  (preferred when installed — CI does)
except ImportError:
    from repro._compat import hypothesis_fallback

    hypothesis_fallback.register()

import jax  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh()


@pytest.fixture()
def mesh_ctx(host_mesh):
    with jax.set_mesh(host_mesh):
        yield host_mesh
