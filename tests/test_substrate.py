"""Substrate: optimizer, compression, data pipeline, checkpoint, runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Pipeline, SyntheticLM
from repro.optim import adamw, compression
from repro.runtime.fault_tolerance import (Heartbeat, RestartPolicy,
                                           StragglerMitigator)


# ---------------------------------------------------------------- optimizer

def test_adamw_converges_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                            warmup_steps=0, total_steps=200)
    state = adamw.init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 100


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=10,
                            total_steps=100, min_lr_ratio=0.1)
    lr0 = float(adamw.schedule(jnp.int32(1), cfg))
    lr_peak = float(adamw.schedule(jnp.int32(10), cfg))
    lr_end = float(adamw.schedule(jnp.int32(100), cfg))
    assert lr0 < lr_peak
    assert abs(lr_peak - 1.0) < 1e-5
    assert abs(lr_end - 0.1) < 1e-3


# -------------------------------------------------------------- compression

@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_int8_ef_error_feedback_residual(seed):
    """deq + new_residual == g + old_residual exactly (error feedback
    conserves mass)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (300,)) * 0.1
    r = jax.random.normal(jax.random.fold_in(key, 1), (300,)) * 0.01
    deq, r2 = compression.compress_leaf(g, r)
    np.testing.assert_allclose(np.asarray(deq + r2), np.asarray(g + r),
                               atol=1e-6, rtol=1e-5)


def test_int8_ef_converges_over_steps():
    """Repeated compression of a constant gradient transmits the full
    value on average (EF unbiasedness over steps)."""
    g = jnp.linspace(-0.3, 0.4, 128)
    r = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(50):
        deq, r = compression.compress_leaf(g, r)
        sent += deq
    np.testing.assert_allclose(np.asarray(sent / 50), np.asarray(g),
                               atol=5e-3)


def test_compressed_bytes_much_smaller():
    params = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    wire = compression.compressed_bytes(params)
    raw = 1024 * 1024 * 4
    assert wire < 0.3 * raw


# --------------------------------------------------------------------- data

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, global_batch=4, seq_len=16, seed=1)
    src = SyntheticLM(cfg)
    b0 = src.batch(0)
    assert (src.batch(0)["tokens"] == b0["tokens"]).all()
    p1 = Pipeline(cfg, start_step=0)
    steps1 = [next(p1) for _ in range(4)]
    p1.close()
    # resume from step 2: identical stream
    p2 = Pipeline(cfg, start_step=2)
    s2, b2 = next(p2)
    p2.close()
    assert s2 == 2
    np.testing.assert_array_equal(np.asarray(steps1[2][1]["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_labels_shifted():
    cfg = DataConfig(vocab_size=50, global_batch=2, seq_len=8, seed=0)
    b = SyntheticLM(cfg).batch(5)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"w": jnp.arange(6.0).reshape(2, 3), "n": jnp.int32(7),
             "none": None}
    ck.save(10, state, extra={"data_step": 11}, blocking=True)
    step, restored, extra = ck.restore()
    assert step == 10 and extra["data_step"] == 11
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["none"] is None


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.float32(s)}, blocking=True)
    assert ck.steps() == [3, 4]


def test_checkpoint_crash_consistency(tmp_path):
    """A half-written temp dir is never visible as a checkpoint."""
    ck = Checkpointer(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_9"))
    assert ck.latest_step() is None
    ck.save(1, {"x": jnp.float32(1)}, blocking=True)
    assert ck.latest_step() == 1


# ------------------------------------------------------------------ runtime

def test_heartbeat_detects_dead():
    hb = Heartbeat(n_workers=3, dead_after_s=10)
    hb.stamp(0, 5, 0.1, now=100.0)
    hb.stamp(1, 5, 0.1, now=105.0)
    # worker 2 never stamped; worker 0 stale
    dead = hb.dead_workers(now=112.0)
    assert dead == [0, 2]


def test_straggler_actions():
    sm = StragglerMitigator(evict_threshold=2.0)
    times = {0: 1.0, 1: 1.0, 2: 1.05, 3: 5.0}
    actions = sm.assess(times)
    assert actions[3] == "evict"
    assert actions[0] == "ok"


def test_restart_policy():
    rp = RestartPolicy(min_workers=2)
    act, point = rp.plan(n_alive=4, latest_ckpt=100, data_step=101, seed=0)
    assert act == "resize" and point.checkpoint_step == 100
    act, _ = rp.plan(n_alive=1, latest_ckpt=100, data_step=101, seed=0)
    assert act == "halt"
