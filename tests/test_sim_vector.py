"""Vectorized-engine equivalence: repro.sim.vector vs the scalar oracle.

The vectorized engine must reproduce the scalar reference engine's
per-config cycle totals within 1% on every config (in practice the two
agree to machine epsilon — the tolerance leaves room for the closed-form
paths' float reassociation). Property-style coverage replays randomly
generated traces through both engines across all eight configurations.
"""
import numpy as np
import pytest

from repro.sim import engine as scalar_engine
from repro.sim import sweep as sweep_lib
from repro.sim import vector
from repro.sim.media import MEDIA, channel_timeline, resolve_media

N = 4000
TOL = 0.01
ALL_CONFIGS = vector.ALL_CONFIGS


def _pair(config, workload, media, **kw):
    r1 = scalar_engine.run(config, workload, media, n_ops=N, **kw)
    r2 = vector.run(config, workload, media, n_ops=N, **kw)
    return r1, r2


def _assert_close(r1, r2, ctx):
    rel = abs(r2.exec_ns - r1.exec_ns) / max(abs(r1.exec_ns), 1e-12)
    assert rel <= TOL, f"{ctx}: {r1.exec_ns} vs {r2.exec_ns} (rel {rel:.2e})"


@pytest.mark.parametrize("config", ALL_CONFIGS)
def test_equivalence_dram(config):
    for w in ("vadd", "bfs"):
        r1, r2 = _pair(config, w, "dram")
        _assert_close(r1, r2, f"{config}/{w}/dram")


@pytest.mark.parametrize("config",
                         [c for c in ALL_CONFIGS if c.startswith("cxl")])
def test_equivalence_ssd_exact(config):
    """The SSD path replays the identical controller state machine, so
    cycle totals and SR/DS statistics must match the oracle exactly."""
    for w, m in (("vadd", "znand"), ("bfs", "znand"), ("rsum", "optane")):
        r1, r2 = _pair(config, w, m)
        assert r1.exec_ns == pytest.approx(r2.exec_ns, rel=1e-12), \
            (config, w, m)
        assert r1.sr == r2.sr and r1.ds == r2.ds, (config, w, m)
        assert r1.ep_hit_rate == pytest.approx(r2.ep_hit_rate, abs=1e-12)


def _random_trace(rng, n):
    """Random op trace spanning compute/load/store mixes and address
    patterns the bundled workloads don't cover."""
    p_comp = rng.uniform(0.1, 0.5)
    p_load = rng.uniform(0.2, 0.5)
    kind = rng.choice(np.array([0, 1, 2], np.uint8), size=n,
                      p=[p_comp, p_load, 1.0 - p_comp - p_load])
    ws = int(rng.integers(8, 64)) << 20
    style = rng.integers(0, 3)
    if style == 0:       # streaming
        addr = (np.arange(n, dtype=np.int64) * 64) % ws
    elif style == 1:     # hot-set
        addr = (rng.integers(0, ws // 4096, n) * 64) % ws
    else:                # uniform random
        addr = rng.integers(0, ws // 64, n) * 64
    out = np.zeros(n, dtype=[("kind", "u1"), ("addr", "i8")])
    out["kind"] = kind
    out["addr"] = addr
    return out


@pytest.mark.parametrize("seed", range(4))
def test_equivalence_random_traces(seed):
    """Property-style: random traces through both engines, all eight
    configs (DRAM media for host configs, mixed media for CXL)."""
    rng = np.random.default_rng(1000 + seed)
    trace = _random_trace(rng, 2500)
    media_pick = ("dram", "optane", "znand", "nand")[seed % 4]
    for config in ALL_CONFIGS:
        media = "dram" if config in ("gpu-dram", "uvm") else media_pick
        r1 = scalar_engine.run(config, "vadd", media, n_ops=len(trace),
                               trace=trace)
        r2 = vector.run(config, "vadd", media, n_ops=len(trace),
                        trace=trace)
        _assert_close(r1, r2, f"random[{seed}]/{config}/{media}")


def test_equivalence_queue_shape():
    """MLP / store-queue depth are sweep axes; equivalence must hold away
    from the defaults (narrow queues exercise the blocking paths)."""
    for config, media in (("gpu-dram", "dram"), ("cxl", "dram"),
                          ("cxl-sr", "znand"), ("cxl-ds", "znand")):
        r1, r2 = _pair(config, "vadd", media, mlp=8, store_q=2)
        _assert_close(r1, r2, f"{config}/{media}/mlp8/sq2")


def test_media_variants_resolve_and_order():
    m2 = resolve_media("znand@2")
    assert m2.read_ns == 2 * MEDIA["znand"].read_ns
    assert m2.gc_ns == 2 * MEDIA["znand"].gc_ns
    r1, r2 = _pair("cxl-sr", "vadd", "znand@2")
    _assert_close(r1, r2, "cxl-sr/vadd/znand@2")
    base = vector.run("cxl-sr", "vadd", "znand", n_ops=N).exec_ns
    assert r2.exec_ns > base       # slower media bin -> slower run


def test_record_samples_parity():
    r1, r2 = _pair("cxl-ds", "bfs", "znand", record_samples=True)
    assert len(r1.samples) == len(r2.samples)
    s1 = np.asarray(r1.samples)
    s2 = np.asarray(r2.samples)
    np.testing.assert_allclose(s1, s2, rtol=1e-9, atol=1e-6)


def test_channel_timeline_matches_naive():
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.uniform(0, 30, 500))
    chans = rng.integers(0, 4, 500)
    got = channel_timeline(arrivals, chans, 4, 17.5)
    busy = [0.0] * 4
    want = np.empty_like(arrivals)
    for i, (a, c) in enumerate(zip(arrivals, chans)):
        busy[c] = max(a, busy[c]) + 17.5
        want[i] = busy[c]
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_running_kth_largest_matches_sort():
    rng = np.random.default_rng(11)
    vals = rng.uniform(0, 1e6, 300)
    for m in (1, 4, 32):
        got = vector._running_kth_largest(vals, m)
        for k in range(len(vals)):
            want = -np.inf if k < m else np.sort(vals[:k])[-m]
            assert got[k] == pytest.approx(want), (m, k)


def test_event_loop_bridge_oracle():
    """The object-driven compressed event loop is the bridge between the
    scalar engine and the inlined SSD loop — all three must agree."""
    from repro.sim.media import resolve_media as rm
    from repro.sim.vector import _run_cxl_events, bundle_for

    for config, w, m in (("cxl-sr", "vadd", "znand"),
                         ("cxl-ds", "bfs", "znand"),
                         ("cxl", "rsum", "optane")):
        bundle = bundle_for(w, N, 640 << 20, 0)
        gpu_mem = int((640 << 20) * 0.1)
        r_ev = _run_cxl_events(bundle, config, rm(m), gpu_mem, 64, 16,
                               False, m)
        r_sc = scalar_engine.run(config, w, m, n_ops=N)
        r_ve = vector.run(config, w, m, n_ops=N)
        assert r_ev.exec_ns == pytest.approx(r_sc.exec_ns, rel=1e-12)
        assert r_ev.exec_ns == pytest.approx(r_ve.exec_ns, rel=1e-12)
        assert r_ev.sr == r_sc.sr and r_ev.ds == r_sc.ds


def test_sweep_smoke_artifact():
    """The sweep harness must produce a green perf/accuracy payload."""
    scen = sweep_lib.smoke_matrix(n_ops=1500)[:12]
    payload = sweep_lib.bench(scen, compare=True)
    assert payload["matrix"]["n_scenarios"] == len(scen)
    assert payload["accuracy"]["pass"] is True
    assert payload["accuracy"]["max_rel_err"] <= TOL
    assert payload["perf"]["vector_s"] > 0
    rows = payload["results"]
    assert len(rows) == len(scen)
    for row in rows.values():
        assert row["exec_ns"] > 0


def test_sweep_fanout_matches_inprocess():
    scen = sweep_lib.matrix(("cxl", "cxl-sr"), ("rsum",), ("znand",),
                            n_ops=1500)
    a = sweep_lib.run_sweep(scen, workers=0)
    b = sweep_lib.run_sweep(scen, workers=2)
    assert a.keys() == b.keys()
    for k in a:
        assert a[k]["exec_ns"] == pytest.approx(b[k]["exec_ns"], rel=1e-12)


# ----------------------------------------------- oracle strictness

def test_closed_form_rejects_fault_annotated_kinds():
    """Fault-annotated ops price retries/backoff off the recording run's
    FaultSchedule — event-loop state the per-op algebra cannot see, so
    the closed form must refuse loudly rather than misprice silently."""
    for kind in scalar_engine.PAGE_FAULT_KINDS:
        with pytest.raises(ValueError, match="fault-annotated"):
            vector.page_trace_closed_form([(kind, 0, 4096)], "dram")


def test_closed_form_rejects_unknown_kinds():
    with pytest.raises(ValueError, match="unknown page-op kind"):
        vector.page_trace_closed_form([(42, 0, 4096)], "dram")
