"""Tier-1 enforcement of the documentation surface.

Runs the same three checks as the CI ``docs`` job
(``tools/check_docs.py``): no dead intra-repo links/anchors, full
docstring coverage of the public API in ``repro.sim`` / ``repro.core``
/ ``repro.serving`` (pydocstyle-lite), and no drift between the
``BENCH_serve.json`` schema documented in docs/ARCHITECTURE.md and the
keys ``benchmarks/serve_bench.py`` actually emits.
"""
import importlib.util
import os

_SPEC = importlib.util.spec_from_file_location(
    "check_docs",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "tools", "check_docs.py"))
check_docs = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_docs)


def test_no_dead_links_or_anchors():
    assert check_docs.check_links() == []


def test_public_api_docstring_coverage():
    assert check_docs.check_docstrings() == []


def test_bench_serve_schema_matches_docs():
    assert check_docs.check_bench_schema() == []
