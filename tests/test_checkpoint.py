"""Round-trip coverage for the two previously untested persistence paths:
the async sharded checkpointer and the int8+error-feedback compressor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.optim import compression as C


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "b": np.arange(16, dtype=np.float32),
        "nested": {"m": jnp.ones((4,), jnp.bfloat16), "skip": None},
    }


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert [p for p, _ in la] == [p for p, _ in lb]
    for (_, x), (_, y) in zip(la, lb):
        # float32 view: bf16 numpy arrays lack the `equal` ufunc here
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


# ------------------------------------------------------------ checkpointer

def test_checkpoint_round_trip(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=3)
    state = _state()
    ckpt.save(7, state, extra={"lr": 0.1}, blocking=True)
    step, restored, extra = ckpt.restore()
    assert step == 7 and extra == {"lr": 0.1}
    _assert_tree_equal(state, restored)
    assert restored["nested"]["skip"] is None       # None leaves survive


def test_checkpoint_async_commit_and_latest(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=3)
    ckpt.save(1, _state(1))          # async: returns before the write
    ckpt.wait()
    assert ckpt.latest_step() == 1
    assert os.path.exists(os.path.join(str(tmp_path), "step_1",
                                       "manifest.json"))
    # no half-written .tmp dirs after the atomic rename
    assert not [d for d in os.listdir(str(tmp_path)) if d.startswith(".tmp")]


def test_checkpoint_keep_gc_and_specific_step(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    states = {s: _state(s) for s in (1, 2, 3, 4)}
    for s in (1, 2, 3, 4):
        ckpt.save(s, states[s], blocking=True)
    assert ckpt.steps() == [3, 4]                   # keep=2 pruned 1, 2
    step, restored, _ = ckpt.restore(3)             # explicit older step
    assert step == 3
    _assert_tree_equal(states[3], restored)


def test_checkpoint_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        Checkpointer(str(tmp_path)).restore()


def test_checkpoint_elastic_restore_resharding(tmp_path):
    """`shardings` re-places restored leaves via device_put (the elastic
    path); device-committed arrays must equal the host originals."""
    ckpt = Checkpointer(str(tmp_path))
    state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    ckpt.save(0, state, blocking=True)
    dev = jax.devices()[0]
    _, restored, _ = ckpt.restore(shardings={"w": dev})
    assert isinstance(restored["w"], jax.Array)
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])


# ------------------------------------------------------------ compression

def test_quantize_dequantize_exact_on_grid():
    """Values already on the int8 grid (scale * {-127..127}) round-trip
    exactly: encode/decode identity where the codec is lossless."""
    rng = np.random.default_rng(0)
    scale = 0.037
    x = jnp.asarray(rng.integers(-127, 128, size=(7, 64)) * scale,
                    jnp.float32)
    q, s = C._quantize(x)
    out = C._dequantize(q, s, x.shape, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               rtol=1e-6, atol=1e-7)


def test_error_feedback_identity():
    """The EF invariant that makes compression unbiased over steps:
    decompressed + new_residual == gradient + old_residual, exactly."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((5, 300)), jnp.float32)  # pads
    residual = jnp.asarray(rng.standard_normal((5, 300)) * 0.01,
                           jnp.float32)
    deq, new_residual = C.compress_leaf(g, residual)
    np.testing.assert_allclose(np.asarray(deq) + np.asarray(new_residual),
                               np.asarray(g) + np.asarray(residual),
                               rtol=1e-6, atol=1e-6)
    # quantization error is bounded by half a step per block
    step = np.abs(np.asarray(g) + np.asarray(residual)).max() / 127.0
    assert np.abs(np.asarray(new_residual)).max() <= step


def test_compress_grads_treewise_and_residual_init():
    params = {"a": jnp.ones((3, 256)), "b": {"c": jnp.ones((130,))}}
    res = C.init_residuals(params)
    assert all(float(jnp.abs(r).max()) == 0.0
               for r in jax.tree_util.tree_leaves(res))
    grads = jax.tree_util.tree_map(
        lambda p: p * 0.5, params)
    out, new_res = C.compress_grads(grads, res)
    assert jax.tree_util.tree_structure(out) == \
        jax.tree_util.tree_structure(grads)
    for g, o in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(np.asarray(o), np.asarray(g),
                                   rtol=1e-2, atol=1e-2)


def test_compressed_bytes_formula():
    params = {"a": jnp.zeros((256,)), "b": jnp.zeros((300,))}
    # int8 payload + one fp32 scale per 256-block (300 -> 2 blocks)
    assert C.compressed_bytes(params) == (256 + 4 * 1) + (300 + 4 * 2)
