"""Property-style differential parity: every Pallas kernel package vs its
pure-jnp oracle across randomly drawn shapes/dtypes/seeds.

Runs under real hypothesis when installed (CI) and under the seeded
fallback shim otherwise (``repro._compat.hypothesis_fallback``) — either
way each test executes against many drawn examples, complementing the
fixed-case sweep in ``test_kernels.py``. Kernels execute in interpret
mode on CPU (the same code path Mosaic compiles on TPU).

Also covers the serving engine's dispatch split: the single-rank fast
path in ``paged_decode_attention`` vs the shard_map path must be
numerically identical (``force_shard_map`` pins the latter on).
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ops import decode
from repro.kernels.decode_attention.ref import (paged_flash_decode_ref,
                                                paged_flash_decode_quant_ref)
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.hdm_stream.ops import stream_matmul
from repro.kernels.hdm_stream.ref import paged_matmul_ref
from repro.kernels.mamba2_scan.ops import ssd
from repro.kernels.mamba2_scan.ref import ssd_scan_ref
from repro.models import kv_quant as kvq


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


def _key(seed, i=0):
    return jax.random.fold_in(jax.random.PRNGKey(seed), i)


DTYPES = [jnp.float32, jnp.bfloat16]

# (B, S, H, Hkv, D, q_block, kv_block) — tiny so interpret mode stays fast
FLASH_SHAPES = [
    (1, 32, 2, 2, 16, 16, 16),
    (1, 64, 4, 2, 16, 32, 16),
    (2, 32, 4, 1, 16, 16, 32),
]


@settings(max_examples=10, deadline=None)
@given(shape=st.sampled_from(FLASH_SHAPES), dtype=st.sampled_from(DTYPES),
       causal=st.booleans(), seed=st.integers(0, 2 ** 16))
def test_flash_attention_parity(shape, dtype, causal, seed):
    B, S, H, Hkv, D, qb, kb = shape
    q = jax.random.normal(_key(seed, 0), (B, S, H, D), dtype)
    k = jax.random.normal(_key(seed, 1), (B, S, Hkv, D), dtype)
    v = jax.random.normal(_key(seed, 2), (B, S, Hkv, D), dtype)
    out = attention(q, k, v, causal=causal, q_block=qb, kv_block=kb)
    g = H // Hkv
    qr = jnp.moveaxis(q.reshape(B, S, Hkv, g, D), 1, 3)
    ref = flash_attention_ref(qr, jnp.moveaxis(k, 1, 2),
                              jnp.moveaxis(v, 1, 2), causal=causal)
    ref = jnp.moveaxis(ref, 3, 1).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# (B, H, Hkv, D, P, page)
DECODE_SHAPES = [
    (1, 4, 4, 16, 2, 8),
    (2, 4, 2, 16, 4, 8),
    (1, 4, 1, 32, 3, 8),
]


@settings(max_examples=10, deadline=None)
@given(shape=st.sampled_from(DECODE_SHAPES), dtype=st.sampled_from(DTYPES),
       lendraw=st.integers(0, 2 ** 16), seed=st.integers(0, 2 ** 16))
def test_paged_flash_decode_parity(shape, dtype, lendraw, seed):
    B, H, Hkv, D, P, page = shape
    kv_len = 1 + lendraw % (P * page)          # every fill level reachable
    q = jax.random.normal(_key(seed, 0), (B, 1, H, D), dtype)
    kp = jax.random.normal(_key(seed, 1), (B, P, page, Hkv, D), dtype)
    vp = jax.random.normal(_key(seed, 2), (B, P, page, Hkv, D), dtype)
    out = decode(q, kp, vp, jnp.int32(kv_len))
    g = H // Hkv
    ref = paged_flash_decode_ref(
        q.reshape(B, Hkv, g, D), jnp.moveaxis(kp, 3, 1),
        jnp.moveaxis(vp, 3, 1), kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32).reshape(B, Hkv, g, D),
        np.asarray(ref, np.float32), **_tol(dtype))


# (B, S, H, P, N, chunk) — chunk divides S
SSD_SHAPES = [
    (1, 32, 2, 8, 16, 16),
    (2, 32, 3, 8, 8, 32),
    (1, 64, 1, 16, 8, 16),
]


@settings(max_examples=10, deadline=None)
@given(shape=st.sampled_from(SSD_SHAPES), seed=st.integers(0, 2 ** 16))
def test_ssd_scan_parity(shape, seed):
    B, S, H, P, N, chunk = shape
    xdt = jax.random.normal(_key(seed, 0), (B, S, H, P))
    bm = jax.random.normal(_key(seed, 1), (B, S, N)) * 0.5
    cm = jax.random.normal(_key(seed, 2), (B, S, N)) * 0.5
    la = -jnp.abs(jax.random.normal(_key(seed, 3), (B, S, H))) * 0.1
    y = ssd(xdt, bm, cm, la, chunk=chunk)
    c = S // chunk
    lac = jnp.moveaxis(jnp.cumsum(la.reshape(B, c, chunk, H), axis=2), 3, 1)
    ref = ssd_scan_ref(jnp.moveaxis(xdt.reshape(B, c, chunk, H, P), 3, 1),
                       bm.reshape(B, c, chunk, N),
                       cm.reshape(B, c, chunk, N), lac)
    ref = jnp.moveaxis(ref, 1, 3).reshape(B, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# (M, K, N, page_k, n_pages, block_m, block_n)
HDM_SHAPES = [
    (32, 64, 64, 16, 8, 32, 32),
    (32, 64, 32, 32, 4, 32, 32),
    (64, 32, 32, 16, 4, 32, 32),
]


@settings(max_examples=10, deadline=None)
@given(shape=st.sampled_from(HDM_SHAPES), dtype=st.sampled_from(DTYPES),
       seed=st.integers(0, 2 ** 16))
def test_hdm_stream_matmul_parity(shape, dtype, seed):
    M, K, N, page_k, n_pages, bm, bn = shape
    x = jax.random.normal(_key(seed, 0), (M, K), dtype)
    wp = jax.random.normal(_key(seed, 1), (n_pages, page_k, N), dtype)
    rng = np.random.default_rng(seed)
    pids = jnp.asarray(rng.permutation(n_pages)[:K // page_k], jnp.int32)
    y = stream_matmul(x, wp, pids, block_m=bm, block_n=bn)
    ref = paged_matmul_ref(x, wp, pids)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ------------------------------------------- fast path vs shard_map path

@settings(max_examples=8, deadline=None)
@given(shape=st.sampled_from(DECODE_SHAPES), lendraw=st.integers(0, 2 ** 16),
       seed=st.integers(0, 2 ** 16))
def test_paged_decode_fast_path_matches_shard_map(shape, lendraw, seed):
    """The serving decode tick picks the single-rank fast path when the
    mesh axes are degenerate; both it and the rank-masked shard_map body
    must produce identical outputs AND identical updated page buffers."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.attention import paged_decode_attention

    B, H, Hkv, D, P, page = shape
    q = jax.random.normal(_key(seed, 0), (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(_key(seed, 1), (B, P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(_key(seed, 2), (B, P, page, Hkv, D), jnp.float32)
    nk = jax.random.normal(_key(seed, 3), (B, 1, Hkv, D), jnp.float32)
    nv = jax.random.normal(_key(seed, 4), (B, 1, Hkv, D), jnp.float32)
    # per-slot positions in [0, P*page): continuous batching leaves every
    # slot at a different fill level
    pos = jnp.asarray([(lendraw + 7 * i) % (P * page) for i in range(B)],
                      jnp.int32)
    with jax.set_mesh(make_host_mesh()):
        fast = paged_decode_attention(q, kp, vp, nk, nv, pos,
                                      batch_axes="data", page_axes="model")
        smap = paged_decode_attention(q, kp, vp, nk, nv, pos,
                                      batch_axes="data", page_axes="model",
                                      force_shard_map=True)
    for a, b, name in zip(fast, smap, ("out", "k_pages", "v_pages")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5, err_msg=name)


# ----------------------------------------------- int8 KV page parity

def _quantized_pages(x):
    """Model-layout pages [B, P, page, Hkv, D] -> (int8 pages, fp32 scales)."""
    s = kvq.page_scales(x)
    return kvq.quantize_pages(x, s), s


def _qdq(x, page_shape):
    """Quantize-dequantize roundtrip through the int8 page format.

    Views ``x`` in the kv_quant page layout [..., P, page, Hkv, D],
    roundtrips it to int8 codes and back, and returns the dequantized
    array in the original shape/dtype. Feeding the SAME roundtripped
    array to kernel and oracle checks that int8-representable inputs
    (exact multiples of the per-page scale) keep kernel parity — any
    divergence is a kernel bug, not a quantization artifact.
    """
    xr = x.reshape(page_shape)
    s = kvq.page_scales(xr)
    q = kvq.quantize_pages(xr, s)
    return kvq.dequantize_pages(q, s).astype(x.dtype).reshape(x.shape)


@settings(max_examples=10, deadline=None)
@given(shape=st.sampled_from(DECODE_SHAPES), lendraw=st.integers(0, 2 ** 16),
       seed=st.integers(0, 2 ** 16))
def test_paged_flash_decode_int8_parity(shape, lendraw, seed):
    """True int8 kernel path: the Pallas kernel dequantizes in-VMEM from
    int8 codes + per-(page, head) scales; the oracle dequantizes in fp32
    then runs the exact-softmax reference. Both see the same codes, so
    the tolerance is kernel-math tolerance, not quantization error."""
    B, H, Hkv, D, P, page = shape
    kv_len = 1 + lendraw % (P * page)
    q = jax.random.normal(_key(seed, 0), (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(_key(seed, 1), (B, P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(_key(seed, 2), (B, P, page, Hkv, D), jnp.float32)
    kq, ks = _quantized_pages(kp)
    vq, vs = _quantized_pages(vp)
    out = decode(q, kq, vq, jnp.int32(kv_len), k_scale=ks, v_scale=vs)
    g = H // Hkv
    ref = paged_flash_decode_quant_ref(
        q.reshape(B, Hkv, g, D), jnp.moveaxis(kq, 3, 1),
        jnp.moveaxis(vq, 3, 1), jnp.moveaxis(ks, 2, 1),
        jnp.moveaxis(vs, 2, 1), kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32).reshape(B, Hkv, g, D),
        np.asarray(ref, np.float32), atol=3e-5, rtol=3e-5)


@settings(max_examples=8, deadline=None)
@given(shape=st.sampled_from(FLASH_SHAPES), causal=st.booleans(),
       seed=st.integers(0, 2 ** 16))
def test_flash_attention_int8_qdq_parity(shape, causal, seed):
    B, S, H, Hkv, D, qb, kb = shape
    q = jax.random.normal(_key(seed, 0), (B, S, H, D), jnp.float32)
    k = _qdq(jax.random.normal(_key(seed, 1), (B, S, Hkv, D), jnp.float32),
             (B, 1, S, Hkv, D))
    v = _qdq(jax.random.normal(_key(seed, 2), (B, S, Hkv, D), jnp.float32),
             (B, 1, S, Hkv, D))
    out = attention(q, k, v, causal=causal, q_block=qb, kv_block=kb)
    g = H // Hkv
    qr = jnp.moveaxis(q.reshape(B, S, Hkv, g, D), 1, 3)
    ref = flash_attention_ref(qr, jnp.moveaxis(k, 1, 2),
                              jnp.moveaxis(v, 1, 2), causal=causal)
    ref = jnp.moveaxis(ref, 3, 1).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@settings(max_examples=8, deadline=None)
@given(shape=st.sampled_from(HDM_SHAPES), seed=st.integers(0, 2 ** 16))
def test_hdm_stream_matmul_int8_qdq_parity(shape, seed):
    M, K, N, page_k, n_pages, bm, bn = shape
    x = jax.random.normal(_key(seed, 0), (M, K), jnp.float32)
    wp = _qdq(jax.random.normal(_key(seed, 1), (n_pages, page_k, N),
                                jnp.float32),
              (n_pages, page_k, N, 1))
    rng = np.random.default_rng(seed)
    pids = jnp.asarray(rng.permutation(n_pages)[:K // page_k], jnp.int32)
    y = stream_matmul(x, wp, pids, block_m=bm, block_n=bn)
    ref = paged_matmul_ref(x, wp, pids)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@settings(max_examples=8, deadline=None)
@given(shape=st.sampled_from(SSD_SHAPES), seed=st.integers(0, 2 ** 16))
def test_ssd_scan_int8_qdq_parity(shape, seed):
    B, S, H, P, N, chunk = shape
    xdt = _qdq(jax.random.normal(_key(seed, 0), (B, S, H, P)),
               (B, 1, S, H, P))
    bm = _qdq(jax.random.normal(_key(seed, 1), (B, S, N)) * 0.5,
              (B, 1, S, N, 1))
    cm = _qdq(jax.random.normal(_key(seed, 2), (B, S, N)) * 0.5,
              (B, 1, S, N, 1))
    la = -jnp.abs(jax.random.normal(_key(seed, 3), (B, S, H))) * 0.1
    y = ssd(xdt, bm, cm, la, chunk=chunk)
    c = S // chunk
    lac = jnp.moveaxis(jnp.cumsum(la.reshape(B, c, chunk, H), axis=2), 3, 1)
    ref = ssd_scan_ref(jnp.moveaxis(xdt.reshape(B, c, chunk, H, P), 3, 1),
                       bm.reshape(B, c, chunk, N),
                       cm.reshape(B, c, chunk, N), lac)
    ref = jnp.moveaxis(ref, 1, 3).reshape(B, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=6, deadline=None)
@given(shape=st.sampled_from(DECODE_SHAPES), lendraw=st.integers(0, 2 ** 16),
       seed=st.integers(0, 2 ** 16))
def test_paged_decode_int8_fast_path_matches_shard_map(shape, lendraw, seed):
    """Quantized dispatch split: fast path and rank-masked shard_map body
    must agree on all five outputs — the attention result bitwise-close,
    the requantized int8 page buffers and the grown scales exactly (the
    monotone-scale requantization makes non-owner ranks' masked writes
    round-trip bit-exactly, so the combine cannot drift)."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.attention import paged_decode_attention

    B, H, Hkv, D, P, page = shape
    q = jax.random.normal(_key(seed, 0), (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(_key(seed, 1), (B, P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(_key(seed, 2), (B, P, page, Hkv, D), jnp.float32)
    nk = jax.random.normal(_key(seed, 3), (B, 1, Hkv, D), jnp.float32)
    nv = jax.random.normal(_key(seed, 4), (B, 1, Hkv, D), jnp.float32)
    kq, ks = _quantized_pages(kp)
    vq, vs = _quantized_pages(vp)
    pos = jnp.asarray([(lendraw + 7 * i) % (P * page) for i in range(B)],
                      jnp.int32)
    with jax.set_mesh(make_host_mesh()):
        fast = paged_decode_attention(q, kq, vq, nk, nv, pos,
                                      batch_axes="data", page_axes="model",
                                      k_scale=ks, v_scale=vs)
        smap = paged_decode_attention(q, kq, vq, nk, nv, pos,
                                      batch_axes="data", page_axes="model",
                                      force_shard_map=True,
                                      k_scale=ks, v_scale=vs)
    assert len(fast) == 5 and len(smap) == 5
    names = ("out", "k_pages", "v_pages", "k_scale", "v_scale")
    for a, b, name in zip(fast, smap, names):
        if a.dtype == jnp.int8:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5, err_msg=name)


# ------------------------------------- real >1-rank mesh vs single rank

# shapes whose page axis divides a 2-rank model axis (P % 2 == 0) — the
# others fall back to unsharded pages under shard_map by design
MULTI_RANK_SHAPES = [s for s in DECODE_SHAPES if s[4] % 2 == 0]

_multirank = __import__("pytest").mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=4)")


@_multirank
@settings(max_examples=6, deadline=None)
@given(shape=st.sampled_from(MULTI_RANK_SHAPES),
       lendraw=st.integers(0, 2 ** 16), seed=st.integers(0, 2 ** 16))
def test_paged_decode_two_rank_mesh_matches_single_rank_and_ref(
        shape, lendraw, seed):
    """The shard_map body on a REAL (1, 2) mesh — pages physically split
    over two model-axis ranks — must match both the single-rank fast
    path and the exact-softmax oracle on the updated pages. This is the
    sharded serving engine's decode tick, minus the engine."""
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models.attention import paged_decode_attention

    B, H, Hkv, D, P, page = shape
    q = jax.random.normal(_key(seed, 0), (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(_key(seed, 1), (B, P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(_key(seed, 2), (B, P, page, Hkv, D), jnp.float32)
    nk = jax.random.normal(_key(seed, 3), (B, 1, Hkv, D), jnp.float32)
    nv = jax.random.normal(_key(seed, 4), (B, 1, Hkv, D), jnp.float32)
    pos = jnp.asarray([(lendraw + 7 * i) % (P * page) for i in range(B)],
                      jnp.int32)
    with jax.set_mesh(make_production_mesh(shape=(1, 2))):
        two = paged_decode_attention(q, kp, vp, nk, nv, pos,
                                     batch_axes="data", page_axes="model",
                                     force_shard_map=True)
    with jax.set_mesh(make_host_mesh()):
        one = paged_decode_attention(q, kp, vp, nk, nv, pos,
                                     batch_axes="data", page_axes="model")
    for a, b, name in zip(two, one, ("out", "k_pages", "v_pages")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5, err_msg=name)
    # oracle on the updated pages: new_k/new_v land at pos, attention
    # spans kv_len = pos + 1 (per-slot, so check slot by slot)
    out2, kp2, vp2 = (np.asarray(x) for x in two)
    g = H // Hkv
    for b in range(B):
        p_idx, s_idx = int(pos[b]) // page, int(pos[b]) % page
        np.testing.assert_allclose(kp2[b, p_idx, s_idx],
                                   np.asarray(nk)[b, 0], atol=1e-6)
        ref = paged_flash_decode_ref(
            jnp.asarray(q[b:b + 1]).reshape(1, Hkv, g, D),
            jnp.moveaxis(jnp.asarray(kp2[b:b + 1]), 3, 1),
            jnp.moveaxis(jnp.asarray(vp2[b:b + 1]), 3, 1),
            int(pos[b]) + 1)
        np.testing.assert_allclose(
            out2[b].reshape(Hkv, g, D), np.asarray(ref)[0],
            atol=1e-5, rtol=1e-5, err_msg=f"slot {b} vs oracle")


@_multirank
@settings(max_examples=6, deadline=None)
@given(shape=st.sampled_from(MULTI_RANK_SHAPES),
       lendraw=st.integers(0, 2 ** 16), seed=st.integers(0, 2 ** 16))
def test_paged_decode_int8_two_rank_mesh_matches_single_rank(
        shape, lendraw, seed):
    """Quantized 5-output path on a real (1, 2) mesh: per-page int8
    scales are sharded alongside the pages, and the sharded combine must
    reproduce the single-rank fast path bit-for-bit on the int8 buffers
    (monotone-scale requantization) and bitwise-close on the floats."""
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models.attention import paged_decode_attention

    B, H, Hkv, D, P, page = shape
    q = jax.random.normal(_key(seed, 0), (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(_key(seed, 1), (B, P, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(_key(seed, 2), (B, P, page, Hkv, D), jnp.float32)
    nk = jax.random.normal(_key(seed, 3), (B, 1, Hkv, D), jnp.float32)
    nv = jax.random.normal(_key(seed, 4), (B, 1, Hkv, D), jnp.float32)
    kq, ks = _quantized_pages(kp)
    vq, vs = _quantized_pages(vp)
    pos = jnp.asarray([(lendraw + 7 * i) % (P * page) for i in range(B)],
                      jnp.int32)
    with jax.set_mesh(make_production_mesh(shape=(1, 2))):
        two = paged_decode_attention(q, kq, vq, nk, nv, pos,
                                     batch_axes="data", page_axes="model",
                                     force_shard_map=True,
                                     k_scale=ks, v_scale=vs)
    with jax.set_mesh(make_host_mesh()):
        one = paged_decode_attention(q, kq, vq, nk, nv, pos,
                                     batch_axes="data", page_axes="model",
                                     k_scale=ks, v_scale=vs)
    assert len(two) == 5 and len(one) == 5
    names = ("out", "k_pages", "v_pages", "k_scale", "v_scale")
    for a, b, name in zip(two, one, names):
        if a.dtype == jnp.int8:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5, err_msg=name)
