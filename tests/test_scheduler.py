"""Request-lifecycle scheduler: state machine, async restore overlap,
preemption fidelity and the blocking-path bit-identity gate.

The acceptance contracts from the issue: with ``cxl_async`` off the
engine is bit-identical to the blocking path (same tokens, same tier
trace, no async op kinds); with it on, aggregate restore stall is
strictly lower on identical traffic while the token streams stay
greedy-identical; a preempted-and-resumed request generates exactly the
tokens of an uninterrupted run under both swap and recompute policies;
and under pressure preempt+swap completes strictly more requests per
simulated second than FIFO.
"""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.core.tier import CxlTier, TierConfig
from repro.models import model as M
from repro.serving import scheduler as sched
from repro.serving.engine import Request, ServingEngine
from repro.sim.engine import (PAGE_READ, PAGE_READ_ASYNC, PAGE_WRITE_ASYNC,
                              replay_page_trace)

PROMPTS = [[i + 1, 2, 3, 4, 5] for i in range(4)]


def _make(arch="qwen3-1.7b", **kw):
    cfg = registry.smoke(arch)
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return ServingEngine(params, cfg, rc, **kw)


def _serve_settle_resubmit(eng, max_new=4, resubmit_new=3):
    """Serve PROMPTS, settle staging into the cold tier, resubmit."""
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    eng.run(max_ticks=300)
    for _ in range(300):
        if not eng.flusher.pending:
            break
        eng.tier.advance(eng.tier_step_ns)
        eng.flusher.maybe_flush()
    assert not eng.flusher.pending
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(rid=100 + i, prompt=p,
                           max_new_tokens=resubmit_new))
    eng.run(max_ticks=300)
    return eng


def _replay(tier):
    return replay_page_trace(
        tier.ops, media=tier.cfg.media_name,
        topology=tier.cfg.port_medias if tier.cfg.tagged else None,
        sr=tier.cfg.sr_enabled, ds=tier.cfg.ds_enabled,
        req_bytes=tier.cfg.req_bytes,
        dram_cache_bytes=tier.cfg.dram_cache_bytes,
        max_inflight=tier.cfg.max_inflight)


# ------------------------------------------------ blocking bit-identity

def test_async_off_is_bit_identical_blocking_path(mesh_ctx):
    """The acceptance gate: with cxl_async off the refactored engine
    reproduces the blocking path exactly — every tier op is a blocking
    kind, the restore stall equals the sum of charged demand reads, and
    the trace replays; async mode on identical traffic emits async reads
    and strictly less aggregate stall, with identical greedy tokens."""
    outs, stalls, tiers = {}, {}, {}
    for mode in (False, True):
        tier = CxlTier(TierConfig(media="ssd-fast"))
        eng = _make(n_slots=2, max_seq=32, prefill_chunk=4, cxl_tier=tier,
                    cxl_async=mode)
        _serve_settle_resubmit(eng)
        assert eng.stats["prefix_hits"] == len(PROMPTS)
        outs[mode] = {r.rid: r.generated for r in eng.finished}
        stalls[mode] = eng.stats["restore_stall_ns"]
        tiers[mode] = tier
    kinds_off = {op[0] for op in tiers[False].ops}
    assert PAGE_READ_ASYNC not in kinds_off
    assert PAGE_WRITE_ASYNC not in kinds_off
    assert stalls[False] == pytest.approx(
        tiers[False].counters["read_ns"])      # blocking = charged reads
    kinds_on = {op[0] for op in tiers[True].ops}
    assert PAGE_READ_ASYNC in kinds_on and PAGE_WRITE_ASYNC in kinds_on
    assert PAGE_READ not in kinds_on           # every restore went async
    assert stalls[True] < stalls[False]        # the tentpole gate
    assert outs[False] == outs[True]           # greedy tokens unchanged
    for tier in tiers.values():
        np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                                   rtol=0.01, atol=1e-6)


# ----------------------------------------------------- async lifecycle

def test_async_restore_overlaps_decode_and_states_walk(mesh_ctx):
    """A slot whose restore is in flight must not stall the batch: with
    one slot decoding fresh work and one restoring, decode ticks keep
    landing while the fetch flies, and the restored request walks
    QUEUED -> RESTORING -> RUNNING -> RETIRED."""
    tier = CxlTier(TierConfig(media="ssd-slow", sr_enabled=False))
    eng = _make(n_slots=2, max_seq=32, prefill_chunk=4, cxl_tier=tier,
                cxl_async=True)
    for i, p in enumerate(PROMPTS[:2]):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    eng.run(max_ticks=300)
    for _ in range(300):
        if not eng.flusher.pending:
            break
        tier.advance(eng.tier_step_ns)
        eng.flusher.maybe_flush()
    assert not eng.flusher.pending

    resub = Request(rid=100, prompt=PROMPTS[0], max_new_tokens=3)
    fresh = Request(rid=101, prompt=[7, 7, 7, 7], max_new_tokens=12)
    assert resub.state == sched.QUEUED
    eng.submit(fresh)
    eng.submit(resub)
    eng.step()
    assert resub.state == sched.RESTORING     # fetch in flight
    assert fresh.state == sched.RUNNING
    decoded_during = 0
    while resub.state == sched.RESTORING:
        d0 = eng.stats["decode_tokens"]
        eng.step()
        decoded_during += eng.stats["decode_tokens"] - d0
    assert resub.state == sched.RUNNING
    assert decoded_during > 0                 # the batch kept decoding
    eng.run(max_ticks=300)
    assert resub.state == sched.RETIRED and resub.done
    assert eng.stats["restore_inflight_ns"] > 0
    assert eng.stats["restore_overlap_ratio"] > 0


# -------------------------------------------------------- preemption

@pytest.mark.parametrize("policy", ["swap", "recompute"])
def test_preempted_request_tokens_identical(mesh_ctx, policy):
    """A preempted request, swapped out and resumed, must generate
    exactly the tokens of an uninterrupted solo run (greedy)."""
    solo = _make(n_slots=1, max_seq=32, prefill_chunk=4)
    solo.submit(Request(rid=0, prompt=[9, 8, 7, 6, 5], max_new_tokens=8))
    ref = solo.run(max_ticks=100)[0].generated

    tier = CxlTier(TierConfig(media="ssd-fast"))
    eng = _make(n_slots=1, max_seq=32, prefill_chunk=4, cxl_tier=tier,
                cxl_async=True, preempt_policy=policy)
    victim = Request(rid=0, prompt=[9, 8, 7, 6, 5], max_new_tokens=8,
                     priority=0)
    eng.submit(victim)
    eng.step()
    eng.step()                                 # victim decoding
    eng.submit(Request(rid=1, prompt=[1, 2, 3], max_new_tokens=2,
                       priority=5))
    eng.step()
    assert victim.state == (sched.SWAPPED if policy == "swap"
                            else sched.PREEMPTED)
    done = eng.run(max_ticks=400)
    assert eng.stats["preemptions"] >= 1
    outs = {r.rid: r.generated for r in done}
    assert outs[0] == ref
    assert len(outs[1]) == 2
    if policy == "swap":
        assert eng.stats["swap_out_bytes"] > 0
        assert eng.stats["swap_in_bytes"] == eng.stats["swap_out_bytes"]
        np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                                   rtol=0.01, atol=1e-6)
    else:
        assert eng.stats["swap_out_bytes"] == 0


def test_equal_priority_never_preempts(mesh_ctx):
    """Preemption needs strictly higher queued priority — an all-equal
    workload degenerates to plain continuous batching (no thrash)."""
    eng = _make(n_slots=1, max_seq=32, prefill_chunk=4,
                preempt_policy="swap")
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=[rid + 1, 2, 3],
                           max_new_tokens=3))
    eng.run(max_ticks=100)
    assert eng.stats["preemptions"] == 0
    assert len(eng.finished) == 3


def test_pressure_preempt_swap_beats_fifo_throughput(mesh_ctx):
    """The bench gate, engine-level: under slot pressure preempt+swap
    completes strictly more requests per simulated second than FIFO on
    identical traffic and an identical tick horizon."""
    done = {}
    for policy in ("none", "swap"):
        tier = CxlTier(TierConfig(media="ssd-fast"))
        eng = _make(n_slots=2, max_seq=32, prefill_chunk=4, cxl_tier=tier,
                    cxl_async=True, preempt_policy=policy)
        for i in range(2):
            eng.submit(Request(rid=i, prompt=[i + 1, 2, 3], priority=0,
                               max_new_tokens=24))
        eng.step()
        eng.step()
        for i in range(4):
            eng.submit(Request(rid=100 + i, prompt=[9, 8, i + 1],
                               priority=1, max_new_tokens=2))
        eng.run(max_ticks=12)
        done[policy] = (len(eng.finished), eng.stats["sim_time_ns"])
    n_fifo, t_fifo = done["none"]
    n_swap, t_swap = done["swap"]
    assert n_swap / t_swap > n_fifo / t_fifo
    assert n_swap > n_fifo


def test_legacy_path_rejects_scheduler_features(mesh_ctx):
    with pytest.raises(ValueError):
        _make(n_slots=1, legacy_host_path=True, cxl_async=True)
    with pytest.raises(ValueError):
        _make(n_slots=1, legacy_host_path=True, preempt_policy="swap")
    with pytest.raises(ValueError):
        _make(n_slots=1, preempt_policy="bogus")
