"""Edge-case + differential tests for the multi-root-port tier topology.

Pins the topology layer's contracts: a 1-port topology is bit-identical
to the pre-topology single-port tier (backwards compat), hashed placement
is stable across runs, hotness promotion/demotion never strands an entry,
per-restore fan-out across ports strictly reduces stall vs one port on
identical traffic, the ``name@mult`` media multiplier is applied
consistently (regression for the silently-ignored-on-hits bug), and the
port-tagged op trace replays within 1% of the scalar oracle — including
with the serving engine in the loop.
"""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.core.tier import CxlTier, TierConfig, resolve_bin
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.sim import vector
from repro.sim.engine import PageStream, Topology, replay_page_trace
from repro.sim.media import Endpoint, resolve_media

ENTRY = 32 << 10          # synthetic page-entry size (bytes)


def _replay(tier: CxlTier) -> np.ndarray:
    return replay_page_trace(
        tier.ops, media=tier.cfg.media_name,
        topology=tier.cfg.port_medias if tier.cfg.tagged else None,
        sr=tier.cfg.sr_enabled, ds=tier.cfg.ds_enabled,
        req_bytes=tier.cfg.req_bytes,
        dram_cache_bytes=tier.cfg.dram_cache_bytes)


def _churn(tier: CxlTier, n: int = 8) -> float:
    """Write + SR + read every entry; returns the total restore stall."""
    for i in range(n):
        tier.write_entry(i, ENTRY)
        tier.advance(50_000.0)
    stall = 0.0
    for i in range(n):
        tier.speculative_read(i, ENTRY)
        stall += tier.read_entry(i, ENTRY)
    return stall


# ------------------------------------------------- backwards compatibility

def test_one_port_topology_bit_identical_to_legacy_tier():
    """The 1-port topology must reproduce the pre-topology single-port
    tier exactly: same charged latencies, same ops modulo the port tag."""
    legacy = CxlTier(TierConfig(media="ssd-fast"))
    one = CxlTier(TierConfig(topology=("ssd-fast",)))
    _churn(legacy)
    _churn(one)
    assert legacy.op_ns == one.op_ns            # bit-identical, not approx
    assert legacy.ops == [(k, a, n) for _, k, a, n in one.ops]
    assert [p for p, _, _, _ in one.ops if p >= 0] == \
        [0] * sum(p >= 0 for p, _, _, _ in one.ops)


def test_legacy_trace_stays_untagged():
    tier = CxlTier(TierConfig(media="ssd-fast"))
    tier.write_entry("a", ENTRY)
    assert all(len(op) == 3 for op in tier.ops)


# --------------------------------------------------------- overlap gates

def test_multi_port_overlap_strictly_reduces_stall():
    """Striping an entry's pages across ports fans the demand fetch out:
    the restore stalls for the slowest lane only, strictly less than the
    serialized single-port stream on identical traffic."""
    s1 = _churn(CxlTier(TierConfig(topology=("ssd-fast",))))
    s2 = _churn(CxlTier(TierConfig(topology=("dram", "ssd-fast"))))
    assert s2 < s1


def test_flushes_to_distinct_ports_overlap():
    """Writer-held time for a striped flush is the max lane, not the sum:
    with DS off (writes block), two equal lanes take about half the
    single-port time."""
    one = CxlTier(TierConfig(topology=("dram",), ds_enabled=False))
    two = CxlTier(TierConfig(topology=("dram", "dram"), ds_enabled=False))
    h1 = one.write_entry("a", ENTRY)
    h2 = two.write_entry("a", ENTRY)
    assert h2 < 0.75 * h1


def test_advance_is_the_drain_barrier():
    topo = Topology(["dram", "znand"])
    topo.ports[1].write(0, ENTRY)
    assert topo.ports[0].now != topo.ports[1].now
    topo.advance(1000.0)
    assert topo.ports[0].now == topo.ports[1].now


# ------------------------------------------------------ hashed placement

def test_hashed_placement_stable_across_runs():
    """Same keys -> same ports -> identical op traces on fresh tiers (the
    hash is blake2b of repr, not the per-process-salted builtin)."""
    cfg = TierConfig(topology=("dram", "ssd-fast", "ssd-slow"),
                     placement="hashed")
    t1, t2 = CxlTier(cfg), CxlTier(cfg)
    keys = [0, 1, 17, "prompt-a", ("warm", 3)]
    for t in (t1, t2):
        for k in keys:
            t.write_entry(k, ENTRY)
            t.read_entry(k, ENTRY)
    assert t1.ops == t2.ops
    assert t1.op_ns == t2.op_ns
    ports_used = {p for p, _, _, _ in t1.ops}
    assert len(ports_used) > 1          # keys actually spread across ports


# ----------------------------------------------------- hotness placement

def test_hotness_promotes_hot_and_demotes_cold():
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast", "ssd-slow"),
                              placement="hotness",
                              hot_budget_bytes=2 * ENTRY))
    for i in range(4):
        tier.write_entry(i, ENTRY)
    assert tier.counters["promotions"] == 0
    for _ in range(tier.cfg.hot_promote_after):
        tier.read_entry(0, ENTRY)       # heat 0 past the threshold
    assert tier.counters["promotions"] == 1
    fast = tier._fast_port
    assert all(p == fast for p, _, _ in tier._segments[0])
    for _ in range(tier.cfg.hot_promote_after):
        for i in (1, 2, 3):
            tier.read_entry(i, ENTRY)   # budget 2 entries: evictions follow
    assert tier.counters["demotions"] >= 1


def test_hotness_never_strands_an_entry():
    """Arbitrary promote/demote interleavings must leave every rid
    restorable — segments always map to live, readable ranges — and the
    recorded trace must still replay within 1%."""
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast", "ssd-slow"),
                              placement="hotness",
                              hot_budget_bytes=2 * ENTRY))
    rng = np.random.default_rng(7)
    keys = list(range(10))
    sizes = {k: int(rng.integers(1 << 10, 3 * ENTRY)) for k in keys}
    for k in keys:
        tier.write_entry(k, sizes[k])
    for _ in range(120):                # skewed churn: heavy promote/demote
        k = keys[int(rng.zipf(1.7)) % len(keys)]
        if rng.random() < 0.25:
            tier.write_entry(k, sizes[k])
        else:
            assert tier.read_entry(k, sizes[k]) > 0.0
    assert tier.counters["promotions"] >= 1
    assert tier.counters["demotions"] >= 1
    for k in keys:                      # nothing stranded
        segs = tier._segments[k]
        assert sum(c for _, _, c in segs) >= min(sizes[k], 1)
        assert tier.read_entry(k, sizes[k]) > 0.0
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)


def test_hotness_grown_relocation_keeps_fast_residency_honest():
    """Regression: a promoted entry that grows gets relocated by the
    placement layer onto a capacity port; it must leave the fast-port
    residency set with it, or a later demotion charges its pull-back
    reads on the fast port at addresses belonging to another port's bump
    space. Invariant: every charged op lands inside its own port's
    allocated range."""
    from repro.sim.engine import PAGE_ADVANCE, PAGE_READ

    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast", "ssd-slow"),
                              placement="hotness",
                              hot_budget_bytes=2 * ENTRY))
    for k in ("a", "b", "c"):
        tier.write_entry(k, ENTRY)
    for _ in range(tier.cfg.hot_promote_after):
        tier.read_entry("a", ENTRY)
        tier.read_entry("b", ENTRY)
    assert "a" in tier._fast_resident and "b" in tier._fast_resident
    tier.write_entry("a", 3 * ENTRY)     # grown -> relocates off fast port
    assert "a" not in tier._fast_resident
    for _ in range(tier.cfg.hot_promote_after):
        tier.read_entry("c", ENTRY)      # promote c
        tier.read_entry("a", 3 * ENTRY)  # re-promote grown a: forces demotion
    assert tier.counters["demotions"] >= 1
    for port, kind, addr, n in tier.ops:
        if kind != PAGE_ADVANCE:
            assert addr + n <= tier._base[port], \
                f"op on port {port} outside its bump space"
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)


def test_hotness_on_homogeneous_topology_is_inert():
    tier = CxlTier(TierConfig(topology=("ssd-fast", "ssd-fast"),
                              placement="hotness"))
    for i in range(4):
        tier.write_entry(i, ENTRY)
        for _ in range(4):
            tier.read_entry(i, ENTRY)
    assert tier.counters["promotions"] == 0
    assert tier.counters["demotions"] == 0


# ------------------------------------------- media multiplier regression

def test_bin_multiplier_survives_bin_mapping():
    """Regression: "ssd-fast@2" used to KeyError in resolve_media because
    the bin name never mapped; the multiplier must ride along."""
    assert resolve_bin("ssd-fast@2") == "znand@2"
    assert TierConfig(media="ssd-fast@2").media_name == "znand@2"
    assert TierConfig(topology=("dram@2", "ssd-slow@1.5")).port_medias == \
        ("dram@2", "nand@1.5")
    tier = CxlTier(TierConfig(media="ssd-fast@2"))
    assert tier.stream.ep.media.read_ns == \
        2 * resolve_media("znand").read_ns


def test_scaled_dram_multiplier_charged_consistently():
    """Regression: a scaled DRAM bin ("dram@2") fell off the DRAM-class
    path and billed internal-cache hits at the *unscaled* DRAM latency —
    the multiplier was silently ignored. It must now charge the scaled
    latency on every access, agreeing with the closed form."""
    assert Endpoint(resolve_media("dram@2")).is_dram
    base = PageStream("dram")
    scaled = PageStream("dram@2")
    l1 = base.read(0, ENTRY)
    l2 = scaled.read(0, ENTRY)
    assert l2 > l1                       # 2x media latency actually billed
    tier = CxlTier(TierConfig(media="dram@2"))
    tier.write_entry(0, ENTRY)
    tier.read_entry(0, ENTRY)
    cf = vector.page_trace_closed_form(tier.ops, "dram@2", ds=True,
                                       req_bytes=tier.cfg.req_bytes)
    np.testing.assert_allclose(np.asarray(tier.op_ns), cf, rtol=1e-9)


def test_multi_port_closed_form_on_dram_lanes():
    """The vectorized closed form extends per-port: DRAM lanes never
    queue, so port-tagged ops cost the same algebra per lane."""
    tier = CxlTier(TierConfig(topology=("dram", "dram@2")))
    for i in range(4):
        tier.write_entry(i, ENTRY)
        tier.speculative_read(i, ENTRY)
        tier.read_entry(i, ENTRY)
        tier.advance(10_000.0)
    cf = vector.page_trace_closed_form(tier.ops, tier.cfg.port_medias,
                                       ds=True,
                                       req_bytes=tier.cfg.req_bytes)
    np.testing.assert_allclose(np.asarray(tier.op_ns), cf, rtol=1e-9)
    with pytest.raises(ValueError):
        vector.page_trace_closed_form(tier.ops, ("dram", "znand"))


# ------------------------------------------------- serving differential

def test_serving_run_port_tagged_trace_matches_oracle(mesh_ctx):
    """Engine in the loop on a 2-port heterogeneous topology: charged
    per-op latencies must replay within 1%, restores must be charged, and
    per-port telemetry must surface in engine.stats."""
    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    tier = CxlTier(TierConfig(topology=("dram", "ssd-fast"),
                              placement="striped"))
    eng = ServingEngine(params, cfg, rc, n_slots=2, max_seq=32,
                        prefill_chunk=4, cxl_tier=tier)
    prompts = [[i + 1, 2, 3, 4, 5] for i in range(4)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.run(max_ticks=200)
    for _ in range(300):
        if not eng.flusher.pending:
            break
        tier.advance(eng.tier_step_ns)
        eng.flusher.maybe_flush()
    assert not eng.flusher.pending
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=100 + i, prompt=p, max_new_tokens=3))
    eng.run(max_ticks=200)

    assert eng.stats["prefix_hits"] == len(prompts)
    assert eng.stats["restore_stall_ns"] > 0
    ports = eng.stats["tier_ports"]
    assert [p["media"] for p in ports] == ["DRAM", "Z-NAND"]
    assert all(p["ep_writes"] > 0 for p in ports)   # striping hit both
    assert all(len(op) == 4 for op in tier.ops)
    np.testing.assert_allclose(np.asarray(tier.op_ns), _replay(tier),
                               rtol=0.01)
