"""Open-loop load-harness tests: seeded trace determinism, zipf prompt
popularity against the analytic distribution, the redesigned ServeConfig
/ RequestHandle / EngineStats API surface, the run() horizon drain, and
the degenerate one-arrival case where continuous and closed admission
must produce bit-identical tokens."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.core.tier import CxlTier, TierConfig
from repro.models import model as M
from repro.serving import loadgen
from repro.serving.config import ServeConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.loadgen import LoadConfig
from repro.serving.stats import EngineStats


def _make(arch="qwen3-1.7b", *, tier=None, **kw):
    cfg = registry.smoke(arch)
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return ServingEngine(params, cfg, rc, cxl_tier=tier,
                         config=ServeConfig(**kw))


# ------------------------------------------------------- trace generation

@pytest.mark.parametrize("arrival", ["poisson", "bursty"])
def test_trace_deterministic_in_seed(arrival):
    cfg = LoadConfig(n_arrivals=64, arrival=arrival, hi_prio_frac=0.3,
                     seed=7)
    a, b = loadgen.make_trace(cfg), loadgen.make_trace(cfg)
    assert a == b                         # bit-identical, field for field
    c = loadgen.make_trace(LoadConfig(n_arrivals=64, arrival=arrival,
                                      hi_prio_frac=0.3, seed=8))
    assert a != c                         # the seed actually matters


@pytest.mark.parametrize("arrival", ["poisson", "bursty"])
def test_trace_timestamps_nondecreasing_and_rids_unique(arrival):
    trace = loadgen.make_trace(LoadConfig(n_arrivals=128, arrival=arrival))
    ts = [a.t_ns for a in trace]
    assert all(t1 >= t0 for t0, t1 in zip(ts, ts[1:]))
    assert sorted(a.rid for a in trace) == list(range(128))
    assert all(a.max_new in (4, 8, 16) for a in trace)
    assert all(len(a.prompt) in (8, 16, 32) for a in trace)


def test_zipf_popularity_matches_analytic_distribution():
    cfg = LoadConfig(n_arrivals=20_000, n_prompts=8, zipf_s=1.2, seed=3)
    trace = loadgen.make_trace(cfg)
    p = loadgen.zipf_probs(cfg)
    counts = np.bincount([a.prompt_id for a in trace],
                         minlength=cfg.n_prompts)
    n = cfg.n_arrivals
    # each rank's count is Binomial(n, p_k): stay within 4 sigma
    sigma = np.sqrt(n * p * (1 - p))
    assert np.all(np.abs(counts - n * p) < 4 * sigma + 1)
    # and the skew is real: rank 0 strictly dominates the tail rank
    assert counts[0] > counts[-1] * 2


def test_trace_prompts_shared_across_arrivals():
    cfg = LoadConfig(n_arrivals=200, n_prompts=4, zipf_s=1.5, seed=0)
    trace = loadgen.make_trace(cfg)
    assert len({a.prompt for a in trace}) <= cfg.n_prompts
    same_id = {}
    for a in trace:
        assert same_id.setdefault(a.prompt_id, a.prompt) == a.prompt


def test_load_config_validation():
    with pytest.raises(ValueError, match="arrival mode"):
        LoadConfig(arrival="uniform")
    with pytest.raises(ValueError, match="rate_rps"):
        LoadConfig(rate_rps=0.0)
    with pytest.raises(ValueError, match="zipf_s"):
        LoadConfig(zipf_s=-1.0)
    with pytest.raises(ValueError, match="burst_factor"):
        LoadConfig(arrival="bursty", burst_factor=1.0)
    with pytest.raises(ValueError, match="choice sets"):
        LoadConfig(prompt_len_choices=())


# ------------------------------------------------------- redesigned API

def test_serve_config_rejects_conflicting_knobs(mesh_ctx):
    with pytest.raises(ValueError, match="admit_mode"):
        ServeConfig(admit_mode="waves")
    with pytest.raises(ValueError):
        ServeConfig(admit_mode="closed", preempt_policy="swap")
    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(TypeError, match="not both"):
        ServingEngine(params, cfg, rc, config=ServeConfig(), n_slots=2)


def test_engine_stats_rejects_unknown_keys():
    st = EngineStats()
    with pytest.raises(KeyError):
        st["not_a_stat"]
    with pytest.raises(KeyError):
        st["not_a_stat"] = 1
    st["decode_tokens"] += 3              # known keys keep dict ergonomics
    assert st.as_dict()["decode_tokens"] == 3
    assert set(st.as_dict()) == set(EngineStats.field_names())


def test_request_handle_lifecycle(mesh_ctx):
    eng = _make(n_slots=2, max_seq=64, prefill_chunk=8)
    h = eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4))
    assert h.rid == 0 and not h.done()
    with pytest.raises(RuntimeError):
        h.result()
    eng.run()
    assert h.done()
    assert h.result() == h.request.generated and len(h.result()) == 4
    assert h.ttft_ns is not None and h.ttft_ns >= 0
    assert h.tpot_ns is not None and h.tpot_ns > 0
    assert h.restore_stall_ns == 0.0


def test_run_drains_async_tier_ops_at_horizon(mesh_ctx):
    tier = CxlTier(TierConfig(media="ssd-slow"))
    eng = _make(n_slots=2, max_seq=64, prefill_chunk=8, tier=tier,
                cxl_async=True)
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[rid + 1, 2, 3],
                           max_new_tokens=4))
    eng.run()
    assert tier.inflight_ops() == 0       # background flushes retired
    assert not eng.scheduler.busy()
    assert eng._async_writes == []


def test_tier_free_entry_recycles_segments():
    tier = CxlTier(TierConfig(media="dram"))
    tier.write_entry("a", 8192)
    base0 = tier._segments["a"][0][1]
    freed = tier.free_entry("a")
    assert freed == 8192 and "a" not in tier._segments
    assert tier.port_stats()[0]["free_bytes"] == 8192
    tier.write_entry("b", 8192)           # exact fit: recycles a's pages
    assert tier._segments["b"][0][1] == base0
    assert tier.counters["frees"] == 1
    assert tier.counters["reused_segments"] == 1
    assert tier.port_stats()[0]["free_bytes"] == 0
    assert tier.free_entry("missing") == 0


# ------------------------------------------------- open-loop degenerate

def test_one_arrival_continuous_equals_closed(mesh_ctx):
    lc = LoadConfig(n_arrivals=1, prompt_len_choices=(8,),
                    max_new_choices=(6,), seed=11)
    trace = loadgen.make_trace(lc)
    tokens = {}
    for mode in ("continuous", "closed"):
        eng = _make(n_slots=2, max_seq=64, prefill_chunk=8,
                    admit_mode=mode)
        handles, depths = loadgen.drive_open_loop(eng, trace)
        m = loadgen.summarize(eng, handles, depths, lc)
        assert m.completed == m.arrivals == 1
        tokens[mode] = handles[0].result()
    assert tokens["continuous"] == tokens["closed"]
    # and both match a direct submit()+run() of the same request
    eng = _make(n_slots=2, max_seq=64, prefill_chunk=8)
    a = trace[0]
    h = eng.submit(Request(rid=a.rid, prompt=list(a.prompt),
                           max_new_tokens=a.max_new))
    eng.run()
    assert h.result() == tokens["continuous"]
