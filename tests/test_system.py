"""End-to-end system tests: serving engine, sharding rules, small dry-run.

The distributed-equivalence test (paged decode on a real 2x4 device mesh
vs single device) runs in a subprocess because the forced device count
must be set before the first jax import.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.parallel import sharding as shlib
from repro.serving.engine import Request, ServingEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------------ serving

def test_serving_engine_completes_requests(mesh_ctx):
    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                   mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, rc, n_slots=2, max_seq=32)
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                           max_new_tokens=4))
    done = eng.run(max_ticks=200)
    assert len(done) == 4
    assert all(len(r.generated) == 4 for r in done)
    assert len(eng.store.pages) == 4       # retired pages reached the tier


def test_serving_batching_matches_solo(mesh_ctx):
    """Continuous batching must not change a request's tokens vs running
    it alone (slot isolation, greedy sampling)."""
    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"],
                   mesh=MeshConfig())
    params = M.init_model(jax.random.PRNGKey(0), cfg)

    solo = ServingEngine(params, cfg, rc, n_slots=1, max_seq=32)
    solo.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=5))
    ref = solo.run(max_ticks=100)[0].generated

    batched = ServingEngine(params, cfg, rc, n_slots=3, max_seq=32)
    batched.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=5))
    batched.submit(Request(rid=1, prompt=[9, 9], max_new_tokens=3))
    batched.submit(Request(rid=2, prompt=[1], max_new_tokens=6))
    outs = {r.rid: r.generated for r in batched.run(max_ticks=200)}
    assert outs[0] == ref


# ----------------------------------------------------------------- sharding

def test_param_specs_rules():
    shapes = {
        "blocks": {"attn": {"wq": jax.ShapeDtypeStruct((4, 64, 128),
                                                        jnp.bfloat16)},
                   "mlp": {"w_down": jax.ShapeDtypeStruct((4, 256, 64),
                                                          jnp.bfloat16)}},
        "embed": {"embedding": jax.ShapeDtypeStruct((1600, 64),
                                                    jnp.bfloat16)},
    }
    specs = shlib.param_specs(shapes, tier="pool")
    assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["blocks"]["mlp"]["w_down"] == P(None, "model", "data")
    assert specs["embed"]["embedding"] == P("model", "data")
    # device tier strips the FSDP axis
    dev = shlib.param_specs(shapes, tier="device")
    assert dev["blocks"]["attn"]["wq"] == P(None, None, "model")


def test_divisibility_guard():
    shapes = {"blocks": {"attn": {"wq": jax.ShapeDtypeStruct(
        (4, 60, 100), jnp.bfloat16)}}}    # 60 % 16 != 0, 100 % 16 != 0
    specs = shlib.param_specs(shapes, tier="pool")
    assert specs["blocks"]["attn"]["wq"] == P(None, None, None)


def test_gathered_specs_strips_fsdp():
    specs = {"w": P("data", "model"), "b": P(("pod", "data"),)}
    g = shlib.gathered_specs(specs)
    assert g["w"] == P(None, "model")
    assert g["b"] == P(None)


# ------------------------------------------------------- small-mesh dry-run

@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_assemble_compiles_on_host_mesh(mesh_ctx, shape_name):
    """steps.assemble lower+compile on the 1x1 mesh with a reduced shape
    — the same path the 512-device dry-run exercises."""
    import dataclasses
    cfg = registry.smoke("qwen3-1.7b")
    shape = dataclasses.replace(SHAPES[shape_name], global_batch=2,
                                seq_len=64)
    rc = RunConfig(model=cfg, shape=shape, mesh=MeshConfig())
    cell = steps_lib.assemble(cfg, shape, rc, mesh_ctx)
    compiled = cell.jitted.lower(*cell.args).compile()
    assert compiled.cost_analysis() is not None


# --------------------------------------------------- distributed (8 device)

_DISTRIBUTED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import repro  # installs the jax < 0.5 compat shims (AxisType, set_mesh)
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import AxisType
    from repro.configs import registry
    from repro.configs.base import MeshConfig, RunConfig, SHAPES
    from repro.models import model as M
    from repro.parallel import sharding as shlib

    cfg = registry.smoke("qwen3-1.7b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    mesh8 = jax.make_mesh((2, 4), ("data", "model"),
                          axis_types=(AxisType.Auto,) * 2)
    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          axis_types=(AxisType.Auto,) * 2,
                          devices=jax.devices()[:1])
    outs = {}
    for name, mesh in (("m8", mesh8), ("m1", mesh1)):
        with jax.set_mesh(mesh):
            params = M.init_model(jax.random.PRNGKey(0), cfg)
            specs = shlib.param_specs(jax.eval_shape(lambda: params))
            cache = M.cache_init(cfg, rc, 2, max_seq=64)
            cache["pos"] = jnp.array([3, 1], jnp.int32)
            toks = jnp.array([[5], [7]], jnp.int32)
            logits, cache2 = M.decode_step(params, cfg, rc, toks, cache,
                                           specs)
            outs[name] = np.asarray(logits.astype(jnp.float32))
    np.testing.assert_allclose(outs["m8"], outs["m1"], atol=2e-2, rtol=2e-2)
    print("DISTRIBUTED_OK")
""")


def test_paged_decode_distributed_equivalence():
    """The page-sharded decode on a (2,4) mesh must match 1 device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _DISTRIBUTED_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert "DISTRIBUTED_OK" in res.stdout, res.stderr[-3000:]
