"""Serving example: continuous batching + CXL-timed KV page lifecycle.

Shows the device-resident hot path (chunked prefill, fused on-device
sampling), the deterministic-store page retirement (slots free
immediately, pages flush to the host tier in the background under QoS
control) and prefix reuse from the cold tier: resubmitted requests are
restored from retired pages — the speculative-read fetch — with zero
prefill dispatches. The attached ``CxlTier`` (Z-NAND media bin) charges
every page movement against the simulated CXL endpoint, so the example
also reports how long the restores *would have* stalled on real
expansion hardware and how much of that the SR engine hid. A second
act hot-removes a root port mid-decode: the pages striped onto it are
lost, the affected requests pass through RECOVERING, and every request
still completes.

  PYTHONPATH=src python examples/serve_kv_offload.py
"""
import jax

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serving.config import ServeConfig
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = registry.smoke("gemma-2b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    # one config object carries every engine knob, tier included
    sc = ServeConfig(n_slots=3, max_seq=64, prefill_chunk=8,
                     tier_media="ssd-fast")
    with jax.set_mesh(make_host_mesh()):
        params = M.init_model(jax.random.PRNGKey(0), cfg)
        engine = ServingEngine(params, cfg, rc, config=sc)
        tier = engine.tier
        handles = [engine.submit(Request(rid=rid, prompt=[rid + 1, 5, 9],
                                         max_new_tokens=8))
                   for rid in range(7)]
        engine.run()

        # prefix reuse: resubmit two of the finished rids — their pages
        # come back from the tiered store instead of re-prefilling
        prefill_before = engine.stats["prefill_dispatches"]
        for rid in (0, 3):
            handles.append(engine.submit(
                Request(rid=rid, prompt=[rid + 1, 5, 9],
                        max_new_tokens=4)))
        finished = engine.run()      # returns the cumulative finished list

    # submit() returns a RequestHandle: completion, tokens and per-request
    # SLO timings (simulated-clock TTFT / TPOT) without touching slots
    for h in handles[:3]:
        ttft = f"{h.ttft_ns / 1e3:.0f}us" if h.ttft_ns is not None else "-"
        print(f"request {h.rid}: done={h.done()} -> {h.result()} "
              f"(TTFT {ttft}, TPOT {h.tpot_ns / 1e3:.1f}us/tok, "
              f"restore stall {h.restore_stall_ns / 1e3:.0f}us)")
    restored = [r for r in finished if r.restored]
    print(f"{len(finished)} requests served, "
          f"{engine.stats['decode_tokens']} tokens in "
          f"{engine.stats['prefill_dispatches']} prefill + "
          f"{engine.stats['decode_dispatches']} decode dispatches; "
          f"{engine.stats['flushes']} page sets flushed to the cold tier "
          f"({engine.store.bytes / 1024:.0f} KiB held, "
          f"{engine.store.evictions} LRU evictions); "
          f"staging never blocked: {engine.flusher.suppressed} flush "
          f"windows deferred by QoS")
    print(f"prefix reuse: {len(restored)} resubmits restored from retired "
          f"pages with {engine.stats['prefill_dispatches'] - prefill_before}"
          f" extra prefill dispatches "
          f"(rids {[r.rid for r in restored]}, "
          f"hits={engine.stats['prefix_hits']})")
    snap = tier.snapshot()
    cold = [r for r in restored if r.restore_stall_ns > 0]
    print(f"cxl tier ({snap['media']}): {snap['writes']} page flushes to "
          f"the EP, {len(cold)} cold restores stalling "
          f"{engine.stats['restore_stall_ns'] / 1e3:.0f}us simulated "
          f"(SR hit rate {snap['sr_hit_rate']:.2f}, "
          f"{snap['prefetches']} MemSpecRd streams, "
          f"{engine.stats['flushes_deferred']} flush windows deferred)")

    # ---- act two: serve through a hot-removed port ------------------
    # the same engine shape on a 2-port tier, with port 1 scheduled to
    # die mid-decode; its striped KV pages are invalidated, the engine
    # sweeps the lost keys, and requests whose fetch failed re-queue
    # through RECOVERING (recompute policy re-prefills when no host
    # copy survives). The fault-annotated page trace still replays
    # against the scalar oracle within 1%.
    sc = ServeConfig(n_slots=3, max_seq=64, prefill_chunk=8,
                     cxl_async=True, preempt_policy="recompute",
                     tier_topology=("dram", "ssd-fast"),
                     tier_faults=(("hot_remove", 1.0e6, 1),))
    with jax.set_mesh(make_host_mesh()):
        engine = ServingEngine(params, cfg, rc, config=sc)
        handles = [engine.submit(Request(rid=rid, prompt=[rid + 1, 5, 9],
                                         max_new_tokens=8))
                   for rid in range(7)]
        engine.run()
        for rid in (0, 3):           # restores race the port removal
            handles.append(engine.submit(
                Request(rid=rid, prompt=[rid + 1, 5, 9],
                        max_new_tokens=4)))
        engine.run()
    st = engine.stats
    assert all(h.done() for h in handles)
    print(f"hot-remove mid-decode: port 1 died at 1.0ms simulated — "
          f"{st['tier_lost_entries']} tier entries "
          f"({st['tier_lost_bytes'] / 1024:.0f} KiB) lost, "
          f"{st['recoveries']} requests recovered via RECOVERING, "
          f"{len(handles)}/{len(handles)} requests still completed "
          f"({st['tier_ports_down']} port down at drain)")


if __name__ == "__main__":
    main()
