"""Serving example: continuous batching + tiered KV page lifecycle.

Shows the deterministic-store page retirement (slots free immediately,
pages flush to the host tier in the background under QoS control) and
prefix reuse from the cold tier.

  PYTHONPATH=src python examples/serve_kv_offload.py
"""
import jax

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = registry.smoke("gemma-2b")
    rc = RunConfig(model=cfg, shape=SHAPES["decode_32k"], mesh=MeshConfig())
    with jax.set_mesh(make_host_mesh()):
        params = M.init_model(jax.random.PRNGKey(0), cfg)
        engine = ServingEngine(params, cfg, rc, n_slots=3, max_seq=64)
        for rid in range(7):
            engine.submit(Request(rid=rid, prompt=[rid + 1, 5, 9],
                                  max_new_tokens=8))
        finished = engine.run()
    for r in finished[:3]:
        print(f"request {r.rid}: prompt={r.prompt} -> {r.generated}")
    print(f"{len(finished)} requests served, "
          f"{engine.stats['decode_tokens']} tokens; "
          f"{engine.stats['flushes']} page sets flushed to the cold tier "
          f"({engine.store.bytes / 1024:.0f} KiB); "
          f"staging never blocked: {engine.flusher.suppressed} flush "
          f"windows deferred by QoS")


if __name__ == "__main__":
    main()
