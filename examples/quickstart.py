"""Quickstart: the paper's technique in five minutes (CPU, smoke scale).

1. builds a reduced qwen3-style model with the HDM tier map (params in
   the POOL tier = the CXL DRAM-EP analogue),
2. runs a few training steps under the speculative-read layer stream with
   deterministic-store gradients,
3. decodes a few tokens through the page-sharded distributed cache,
4. runs the paper's own evaluation simulator for one workload.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import MeshConfig, RunConfig, SHAPES
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.parallel import sharding as shlib


def main():
    cfg = registry.smoke("qwen3-1.7b")
    shape = dataclasses.replace(SHAPES["train_4k"], global_batch=4,
                                seq_len=64)
    rc = RunConfig(model=cfg, shape=shape, mesh=MeshConfig(),
                   sr_prefetch_depth=1, ds_enabled=True)
    mesh = make_host_mesh()

    with jax.set_mesh(mesh):
        # --- train a few steps under SR/DS --------------------------------
        params = M.init_model(jax.random.PRNGKey(0), cfg)
        opt_cfg = adamw.AdamWConfig(learning_rate=1e-2, warmup_steps=0)
        step = jax.jit(steps_lib.build_train_step(cfg, rc, opt_cfg))
        state = steps_lib.TrainState(params, adamw.init(params, opt_cfg),
                                     None)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        for i in range(5):
            state, metrics = step(state, batch)
            print(f"train step {i}: loss={float(metrics['loss']):.4f}")

        # --- decode through the page-sharded cache ------------------------
        specs = shlib.param_specs(jax.eval_shape(lambda: params))
        cache = M.cache_init(cfg, rc, 2, max_seq=32)
        tok = jnp.array([[1], [2]], jnp.int32)
        for i in range(3):
            logits, cache = M.decode_step(state.params, cfg, rc, tok,
                                          cache, specs)
            tok = logits.argmax(-1).astype(jnp.int32)
            print(f"decode step {i}: tokens={tok.ravel().tolist()}")

    # --- the paper's simulator -------------------------------------------
    from repro.sim import run
    base = run("gpu-dram", "vadd", "dram", n_ops=5000).exec_ns
    for config in ("uvm", "cxl"):
        r = run(config, "vadd", "dram", n_ops=5000)
        print(f"sim {config:4s}: {r.exec_ns / base:6.1f}x ideal")
    c = run("cxl", "vadd", "znand", n_ops=5000)
    s = run("cxl-sr", "vadd", "znand", n_ops=5000)
    print(f"sim cxl-sr over cxl on Z-NAND: {c.exec_ns / s.exec_ns:.2f}x")


if __name__ == "__main__":
    main()
