"""End-to-end training driver example: ~100M-class model, few hundred
steps on CPU, with checkpoint/restart and the QoS variant ladder.

  PYTHONPATH=src python examples/train_lm.py --steps 200
(Use --steps 30 for a fast demo.)
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    # xlstm-125m's smoke config is a ~100M-class recurrent LM at full
    # width scale-down; swap --arch for any of the 10 assigned configs
    out = train(args.arch, smoke=True, steps=args.steps,
                ckpt_dir=args.ckpt, global_batch=8, seq_len=128,
                log_every=10)
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(checkpoints in {args.ckpt})")
    assert last < first, "loss should decrease"


if __name__ == "__main__":
    main()
