"""Design-space exploration with the paper's simulator.

Sweeps backend media and controller features for one workload and prints
the latency landscape — the experiment a systems designer would run
before committing silicon (the paper's own methodology).

  PYTHONPATH=src python examples/cxl_sim_explore.py --workload bfs
"""
import argparse

from repro.sim import run
from repro.sim.workloads import TABLE_1B


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="bfs",
                    choices=sorted(TABLE_1B))
    ap.add_argument("--ops", type=int, default=8000)
    args = ap.parse_args()
    w = args.workload
    base = run("gpu-dram", w, "dram", n_ops=args.ops).exec_ns
    print(f"workload={w} (pattern {TABLE_1B[w].pattern}), ideal GPU-DRAM "
          f"baseline normalized to 1.0\n")
    print(f"{'config':10s} " + " ".join(f"{m:>9s}" for m in
                                        ("dram", "optane", "znand",
                                         "nand")))
    for cfg in ("uvm", "gds", "cxl", "cxl-naive", "cxl-dyn", "cxl-sr",
                "cxl-ds"):
        row = []
        for med in ("dram", "optane", "znand", "nand"):
            if cfg in ("uvm",) and med != "dram":
                row.append("     -")
                continue
            r = run(cfg, w, med, n_ops=args.ops)
            row.append(f"{r.exec_ns / base:8.1f}x")
        print(f"{cfg:10s} " + " ".join(f"{v:>9s}" for v in row))
    print("\n(x = slowdown vs GPU-DRAM; lower is better. SR recovers the "
          "read gap, DS the write/GC tail — Fig. 9 in the paper.)")


if __name__ == "__main__":
    main()
