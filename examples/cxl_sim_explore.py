"""Design-space exploration with the paper's simulator.

Sweeps backend media and controller features for one workload and prints
the latency landscape — the experiment a systems designer would run
before committing silicon (the paper's own methodology). Runs on the
vectorized sweep engine; ``--engine scalar`` replays on the per-access
reference oracle instead (same numbers, slower).

  PYTHONPATH=src python examples/cxl_sim_explore.py --workload bfs
  PYTHONPATH=src python examples/cxl_sim_explore.py --media-scale 2 \
      --mlp 16   # 2x-latency media bins, narrow GPU load queue
"""
import argparse
import time

from repro.sim import run, run_vectorized
from repro.sim.workloads import TABLE_1B


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="bfs",
                    choices=sorted(TABLE_1B))
    ap.add_argument("--ops", type=int, default=8000)
    ap.add_argument("--engine", default="vector",
                    choices=("vector", "scalar"))
    ap.add_argument("--media-scale", type=float, default=1.0,
                    help="latency multiplier for the SSD media bins "
                         "(the sweep's media-latency axis)")
    ap.add_argument("--mlp", type=int, default=64,
                    help="GPU outstanding-load (MLP) depth")
    ap.add_argument("--store-q", type=int, default=16,
                    help="GPU store-queue depth")
    args = ap.parse_args()
    engine = run_vectorized if args.engine == "vector" else run
    w = args.workload

    def sim(cfg, med):
        return engine(cfg, w, med, n_ops=args.ops, mlp=args.mlp,
                      store_q=args.store_q).exec_ns

    t0 = time.perf_counter()
    base = sim("gpu-dram", "dram")
    media = ["dram"] + [
        m if args.media_scale == 1.0 else f"{m}@{args.media_scale:g}"
        for m in ("optane", "znand", "nand")]
    print(f"workload={w} (pattern {TABLE_1B[w].pattern}), ideal GPU-DRAM "
          f"baseline normalized to 1.0  [engine={args.engine}, "
          f"mlp={args.mlp}, store_q={args.store_q}]\n")
    print(f"{'config':10s} " + " ".join(f"{m:>10s}" for m in media))
    for cfg in ("uvm", "gds", "cxl", "cxl-naive", "cxl-dyn", "cxl-sr",
                "cxl-ds"):
        row = []
        for med in media:
            if cfg == "uvm" and med != "dram":
                row.append("      -")
                continue
            row.append(f"{sim(cfg, med) / base:9.1f}x")
        print(f"{cfg:10s} " + " ".join(f"{v:>10s}" for v in row))
    print(f"\n(x = slowdown vs GPU-DRAM; lower is better. SR recovers the "
          f"read gap, DS the write/GC tail — Fig. 9 in the paper. "
          f"{time.perf_counter()-t0:.2f}s)")


if __name__ == "__main__":
    main()
